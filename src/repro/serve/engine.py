"""Batched serving engine: prefill + decode with the family-appropriate
cache (ring-buffer SWA, full KV, SSD state, enc-dec cross-memory).

``generate`` drives jitted single-token steps; prefill is performed by
feeding the prompt through ``decode_step`` token-by-token (correct for all
families, including ring buffers — throughput prefill via ``forward`` is a
dry-run/roofline concern, not a CPU-example concern).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import (decode_step, encode_memory, init_cache,
                                      ENC_MEMORY_LEN)
from repro.serve import sampler as samplers


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 window: Optional[int] = None, moe_impl: str = "dense"):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.window = window if window is not None else cfg.sliding_window
        self.moe_impl = moe_impl
        self._step = jax.jit(functools.partial(
            decode_step, cfg, moe_impl=moe_impl))

    def new_cache(self, batch_size: int):
        return init_cache(self.cfg, batch_size, self.max_len,
                          window=self.window)

    def prefill(self, cache, prompts: jnp.ndarray):
        """prompts: [B, S_prompt] — feed through decode steps; returns
        (cache, last_logits)."""
        logits = None
        for t in range(prompts.shape[1]):
            logits, cache = self._step(self.params,
                                       {"tokens": prompts[:, t:t + 1]}, cache)
        return cache, logits

    def generate(self, prompts: jnp.ndarray, num_tokens: int, *,
                 sampler: str = "greedy", key=None, temp: float = 1.0,
                 src_embeds: Optional[jnp.ndarray] = None) -> np.ndarray:
        """Returns [B, num_tokens] generated ids."""
        B = prompts.shape[0]
        cache = self.new_cache(B)
        if self.cfg.is_encoder_decoder:
            if src_embeds is None:
                src_embeds = jnp.zeros((B, ENC_MEMORY_LEN, self.cfg.d_model))
            ck, cv = encode_memory(self.cfg, self.params,
                                   {"src_embeds": src_embeds})
            cache = dict(cache)
            cache["cross_k"], cache["cross_v"] = ck, cv
        cache, logits = self.prefill(cache, prompts)
        key = key if key is not None else jax.random.PRNGKey(0)
        out = []
        tok = self._sample(logits[:, -1], sampler, key, temp)
        out.append(tok)
        for i in range(1, num_tokens):
            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, {"tokens": tok[:, None]},
                                       cache)
            tok = self._sample(logits[:, -1], sampler, sub, temp)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits, sampler, key, temp):
        if sampler == "greedy":
            return samplers.greedy(logits)
        return samplers.temperature(logits, key, temp)
