from repro.serve.engine import ServeEngine
from repro.serve import sampler
