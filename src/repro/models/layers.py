"""Core neural-net layers, functional style.

Everything here is a pair of functions: ``init_*(key, ...) -> params`` and an
apply function taking ``(params, inputs, ...)``. Params are plain dicts of
jnp arrays so that layer stacks can be initialised with ``vmap`` (leaves get
a leading ``[num_layers, ...]`` axis) and applied with ``lax.scan``.

TPU-adaptation notes (see DESIGN.md §5):
 * Attention is *blockwise* (online-softmax over KV chunks) so the O(S²)
   score matrix never materialises — the pure-JAX analogue of the Pallas
   flash kernel in ``repro.kernels.flash_attention``.
 * Mamba2 uses the SSD chunked form (dense intra-chunk matmuls for the MXU +
   tiny inter-chunk recurrence), not the GPU selective-scan kernel.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def init_dense(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def rmsnorm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(dt) * weight


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, H, D]; positions: [B, S] or [S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, blockwise online softmax)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": init_dense(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": init_dense(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": init_dense(ks[3], cfg.num_heads * hd, cfg.d_model, dtype,
                         scale=1.0 / math.sqrt(cfg.num_heads * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


NEG_INF = -1e30


def _attn_block(q_blk, k_blk, v_blk, q_pos, k_pos, causal, window, kv_valid):
    """One (q-chunk × kv-chunk) tile of online-softmax attention.

    q_blk: [B, Tq, K, G, D]; k_blk/v_blk: [B, Tk, K, D].
    Returns (scores_max [B,K,G,Tq], exp_sum, weighted_v [B,Tq,K,G,D]).
    """
    logits = jnp.einsum("btkgd,bskd->bkgts", q_blk.astype(jnp.float32),
                        k_blk.astype(jnp.float32))
    mask = jnp.ones(logits.shape[-2:], dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    return logits


def blockwise_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                        q_positions=None, k_positions=None, kv_valid=None,
                        q_chunk: int = 512, kv_chunk: int = 1024):
    """Memory-efficient attention: never materialises the [Sq, Sk] matrix.

    q: [B, Sq, H, D]; k, v: [B, Sk, K, D] with H % K == 0 (GQA).
    Positions default to aligned ranges (prefill). Output: [B, Sq, H, D].
    """
    B, Sq, H, D = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = 1.0 / math.sqrt(D)
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if k_positions is None:
        k_positions = jnp.arange(Sk)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to multiples
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    pad_q = nq * q_chunk - Sq
    pad_k = nk * kv_chunk - Sk
    qg = q.reshape(B, Sq, K, G, D) * scale
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad_k), constant_values=2**30)
        if kv_valid is None:
            kv_valid = jnp.arange(nk * kv_chunk) < Sk
        else:
            kv_valid = jnp.pad(kv_valid, (0, pad_k), constant_values=False)

    qg = qg.reshape(B, nq, q_chunk, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_chunk, K, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_chunk, K, D).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nq, q_chunk)
    kp = k_positions.reshape(nk, kv_chunk)
    kvld = None if kv_valid is None else kv_valid.reshape(nk, kv_chunk)

    def q_block_body(args):
        q_blk, q_pos = args
        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, q_chunk, K, G, D), jnp.float32)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_body(carry, inp):
            m, l, acc = carry
            if kvld is None:
                k_blk, v_blk, k_pos = inp
                valid = None
            else:
                k_blk, v_blk, k_pos, valid = inp
            logits = _attn_block(q_blk, k_blk, v_blk, q_pos, k_pos,
                                 causal, window, valid)      # [B,K,G,Tq,Tk]
            blk_max = jnp.max(logits, axis=-1)
            new_m = jnp.maximum(m, blk_max)
            correction = jnp.exp(m - new_m)
            # fully-masked tiles: keep probs exactly 0 (avoid exp(-inf - -inf))
            probs = jnp.where(logits > NEG_INF * 0.5,
                              jnp.exp(logits - new_m[..., None]), 0.0)
            new_l = l * correction + jnp.sum(probs, axis=-1)
            pv = jnp.einsum("bkgts,bskd->btkgd", probs, v_blk.astype(jnp.float32))
            new_acc = acc * correction.transpose(0, 3, 1, 2)[..., None] + pv
            return (new_m, new_l, new_acc), None

        xs = (kb, vb, kp) if kvld is None else (kb, vb, kp, kvld)
        (m, l, acc), _ = lax.scan(kv_body, (m0, l0, acc0), xs)
        denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return acc / denom

    out = lax.map(q_block_body, (qg, qp))                    # [nq,B,Tq,K,G,D]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


def full_attention_1q(q, k, v, k_positions, q_position, *, window=None, kv_valid=None):
    """Single-query decode attention over a (possibly ring-buffer) cache.

    q: [B, 1, H, D]; k/v: [B, C, K, D]; k_positions: [B, C] absolute positions;
    q_position: [B] absolute position of the new token.
    """
    B, _, H, D = q.shape
    _, C, K, _ = k.shape
    G = H // K
    qg = q.reshape(B, K, G, D).astype(jnp.float32) / math.sqrt(D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    mask = k_positions[:, None, None, :] <= q_position[:, None, None, None]
    if window is not None:
        mask &= (q_position[:, None, None, None] - k_positions[:, None, None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention_qkv(p, x, cfg: ModelConfig, kv_x=None):
    """Project hidden states to (q, k, v). ``kv_x`` enables cross-attention."""
    hd = cfg.resolved_head_dim
    kv_src = x if kv_x is None else kv_x
    q = x @ p["wq"]
    k = kv_src @ p["wk"]
    v = kv_src @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    B, Sq = x.shape[:2]
    Skv = kv_src.shape[1]
    q = q.reshape(B, Sq, cfg.num_heads, hd)
    k = k.reshape(B, Skv, cfg.num_kv_heads, hd)
    v = v.reshape(B, Skv, cfg.num_kv_heads, hd)
    return q, k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(ks[0], d_model, d_ff, dtype),
        "w_up": init_dense(ks[1], d_model, d_ff, dtype),
        "w_down": init_dense(ks[2], d_ff, d_model, dtype, scale=1.0 / math.sqrt(d_ff)),
    }


def mlp_apply(p, x):
    return (silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def init_moe(key, d_model: int, moe: MoEConfig, dtype):
    ks = jax.random.split(key, 4)
    E, F = moe.num_experts, moe.d_ff

    def stack(k, ind, outd, scale=None):
        keys = jax.random.split(k, E)
        return jnp.stack([init_dense(kk, ind, outd, dtype, scale) for kk in keys])

    return {
        "router": init_dense(ks[0], d_model, E, dtype, scale=0.02),
        "w_gate": stack(ks[1], d_model, F),
        "w_up": stack(ks[2], d_model, F),
        "w_down": stack(ks[3], F, d_model, 1.0 / math.sqrt(F)),
    }


def moe_apply_dense(p, x, moe: MoEConfig):
    """Paper-faithful-simple MoE: evaluate every expert, combine with sparse
    top-k router weights. HLO FLOPs = num_experts/top_k × the useful FLOPs —
    this shows up in the roofline "useful ratio" and is the baseline the
    dispatch implementation improves on (§Perf).
    """
    B, S, D = x.shape
    t = x.reshape(B * S, D)
    logits = (t @ p["router"]).astype(jnp.float32)           # [T, E]
    topw, topi = lax.top_k(logits, moe.top_k)
    topw = jax.nn.softmax(topw, axis=-1)
    gates = jnp.zeros_like(logits).at[
        jnp.arange(t.shape[0])[:, None], topi].set(topw)     # [T, E]
    h = jnp.einsum("td,edf->tef", t, p["w_gate"])
    u = jnp.einsum("td,edf->tef", t, p["w_up"])
    y = jnp.einsum("tef,efd->ted", silu(h) * u, p["w_down"])
    out = jnp.einsum("te,ted->td", gates.astype(y.dtype), y)
    aux = _load_balance_loss(logits, topi, moe)
    return out.reshape(B, S, D), aux


def moe_apply_dispatch(p, x, moe: MoEConfig, capacity_factor: float = 1.25):
    """Sort-based capacity MoE dispatch (gather → grouped matmul → scatter).

    FLOPs ∝ tokens × top_k × capacity_factor instead of × num_experts, and
    memory is O(T·k·D + E·C·D) — no [T, E, C] one-hot tensor (which is
    O(T²) since C ∝ T and explodes at 65k tokens/device). Tokens over
    capacity are dropped (residual passthrough), the standard TPU
    capacity-based scheme.
    """
    B, S, D = x.shape
    T = B * S
    E, K = moe.num_experts, moe.top_k
    cap = max(int(capacity_factor * T * K / E), 1)
    t = x.reshape(T, D)
    logits = (t @ p["router"]).astype(jnp.float32)
    topw, topi = lax.top_k(logits, K)                        # [T, K]
    topw = jax.nn.softmax(topw, axis=-1)
    aux = _load_balance_loss(logits, topi, moe)

    # flatten (token, slot) pairs and sort by expert
    expert_flat = topi.reshape(T * K)                        # [TK]
    token_flat = jnp.repeat(jnp.arange(T), K)                # [TK]
    gate_flat = topw.reshape(T * K)
    order = jnp.argsort(expert_flat)
    e_sorted = expert_flat[order]
    tok_sorted = token_flat[order]
    gate_sorted = gate_flat[order]

    # rank within expert segment: i − (first index of this expert id);
    # searchsorted on the sorted ids gives segment starts in O(log)
    seg_start = jnp.searchsorted(e_sorted, e_sorted, side="left")
    rank = jnp.arange(T * K) - seg_start
    keep = rank < cap

    # scatter tokens into [E, C, D] buffers
    buf = jnp.zeros((E, cap, D), jnp.float32)
    rows = jnp.where(keep, e_sorted, E - 1)
    cols = jnp.where(keep, rank, cap - 1)
    vals = jnp.where(keep[:, None], t[tok_sorted].astype(jnp.float32), 0.0)
    buf = buf.at[rows, cols].add(vals)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(jnp.float32))
    ye = jnp.einsum("ecf,efd->ecd", silu(h) * u,
                    p["w_down"].astype(jnp.float32))         # [E, C, D]

    # gather results back to (token, slot) order and combine
    contrib = ye[rows, cols] * gate_sorted[:, None]
    out = jnp.zeros((T, D), jnp.float32).at[tok_sorted].add(
        jnp.where(keep[:, None], contrib, 0.0))
    return out.astype(x.dtype).reshape(B, S, D), aux


def _load_balance_loss(router_logits, topi, moe: MoEConfig):
    """Switch-transformer load-balance auxiliary loss."""
    probs = jax.nn.softmax(router_logits, axis=-1)           # [T, E]
    E = moe.num_experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return moe.load_balance_coef * E * jnp.sum(frac_tokens * frac_probs)


def moe_apply_dense_fused(p, x, moe: MoEConfig):
    """Dense-einsum MoE with the gate applied BEFORE the down-projection
    contraction (§Perf lever).

    With expert/FFN-sharded weights, the naive order produces per-expert
    partial outputs [T, E, D] that must be all-reduced across the model
    axis — E× more collective traffic than necessary. Weighting the hidden
    activations by the router gates first lets XLA contract (e, f) in one
    dot, so the cross-shard reduction carries only [T, D].
    """
    B, S, D = x.shape
    t = x.reshape(B * S, D)
    logits = (t @ p["router"]).astype(jnp.float32)           # [T, E]
    topw, topi = lax.top_k(logits, moe.top_k)
    topw = jax.nn.softmax(topw, axis=-1)
    gates = jnp.zeros_like(logits).at[
        jnp.arange(t.shape[0])[:, None], topi].set(topw)     # [T, E]
    h = jnp.einsum("td,edf->tef", t, p["w_gate"])
    u = jnp.einsum("td,edf->tef", t, p["w_up"])
    hu = silu(h) * u
    hu = hu * gates.astype(hu.dtype)[:, :, None]             # gate EARLY
    out = jnp.einsum("tef,efd->td", hu, p["w_down"])         # e,f contracted
    aux = _load_balance_loss(logits, topi, moe)
    return out.reshape(B, S, D), aux


MOE_IMPLS = {"dense": moe_apply_dense, "dispatch": moe_apply_dispatch,
             "dense_fused": moe_apply_dense_fused}


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 5)
    dt = jnp.exp(jax.random.uniform(ks[3], (n_heads,), jnp.float32)
                 * (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
    inv_softplus_dt = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": init_dense(ks[0], cfg.d_model,
                              2 * d_inner + 2 * s.n_groups * s.d_state + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch), jnp.float32)
                   * (1.0 / math.sqrt(s.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": inv_softplus_dt.astype(jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": init_dense(ks[4], d_inner, cfg.d_model, dtype,
                               scale=1.0 / math.sqrt(d_inner)),
    }


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i >= j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(X, A, Bm, Cm, chunk: int, initial_state=None):
    """SSD (state-space duality) chunked scan — Mamba2's parallel form.

    X: [B, S, H, P] (pre-multiplied by dt); A: [B, S, H] log-decay (dt*A_raw,
    negative); Bm, Cm: [B, S, G, N]. Heads are grouped: G divides H.
    Returns (Y: [B, S, H, P], final_state: [B, H, P, N]).
    """
    B, S, H, P = X.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
        A = jnp.pad(A, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_p = S + pad
    nc = S_p // chunk
    Xc = X.reshape(B, nc, chunk, H, P)
    Ac = A.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)    # [B,H,nc,Q]
    Bc = Bm.reshape(B, nc, chunk, G, N)
    Cc = Cm.reshape(B, nc, chunk, G, N)
    Bh = jnp.repeat(Bc, rep, axis=3)                          # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)

    A_cum = jnp.cumsum(Ac, axis=-1)                           # [B,H,nc,Q]

    # 1. intra-chunk (diagonal blocks): dense MXU matmuls
    L = jnp.exp(_segsum(Ac))                                  # [B,H,nc,Q,Q]
    scores = jnp.einsum("bcqhn,bcshn->bhcqs", Ch, Bh)         # [B,H,nc,Q,Q]
    Y_diag = jnp.einsum("bhcqs,bhcqs,bcshp->bcqhp", scores, L, Xc)

    # 2. per-chunk final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)           # [B,H,nc,Q]
    states = jnp.einsum("bcqhn,bhcq,bcqhp->bchpn", Bh, decay_states, Xc)

    # 3. inter-chunk recurrence over nc (tiny scan)
    chunk_decay = jnp.exp(A_cum[..., -1])                     # [B,H,nc]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    def chunk_step(h_prev, inp):
        st, dec = inp                                          # [B,H,P,N], [B,H]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    (h_final, h_prevs) = lax.scan(
        chunk_step, initial_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                 # [B,nc,H,P,N]

    # 4. off-diagonal contribution from carried state
    state_decay = jnp.exp(A_cum)                               # [B,H,nc,Q]
    Y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Ch, h_prevs, state_decay)

    Y = (Y_diag + Y_off).reshape(B, S_p, H, P)[:, :S]
    return Y, h_final


def ssd_decode_step(x, dt, A_raw, Bm, Cm, D, state):
    """Single-token SSD recurrence.

    x: [B, H, P]; dt: [B, H]; A_raw: [H] (negative); Bm, Cm: [B, G, N];
    state: [B, H, P, N]. Returns (y: [B, H, P], new_state).
    """
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                           # [B,H,N]
    Ch = jnp.repeat(Cm, rep, axis=1)
    dA = jnp.exp(dt * A_raw[None, :])                          # [B,H]
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, x)
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + D[None, :, None] * x
    return y, new_state


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: [B, S, C]; w: [W, C]; b: [C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b


def causal_conv1d_step(x_t, conv_state, w, b):
    """One decode step of the depthwise conv.

    x_t: [B, C]; conv_state: [B, W-1, C] (previous inputs). Returns
    (y_t: [B, C], new_conv_state).
    """
    W = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x_t.dtype), window[:, 1:]


def mamba2_split_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def mamba2_apply(p, x, cfg: ModelConfig, initial_state=None,
                 return_state=False, use_pallas=None):
    """Mamba2 block over a full sequence (train / prefill).

    x: [B, S, D] -> [B, S, D].

    ``use_pallas`` routes the inner SSD recurrence to the Pallas
    ``ssd_scan`` kernel under the ``repro.kernels.ops`` dispatch policy
    (fresh-state sequences only — a carried ``initial_state`` stays on the
    chunked jnp path, which the kernel has no entry point for).
    """
    s = cfg.ssm
    d_inner, n_heads, conv_ch = mamba2_split_dims(cfg)
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    xBC = silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xs = xs.reshape(B, S, n_heads, s.head_dim)
    Bm = Bm.reshape(B, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # [B,S,H]
    A_raw = -jnp.exp(p["A_log"])                                       # [H]
    A_log_disc = dt * A_raw[None, None, :]
    Xdt = xs.astype(jnp.float32) * dt[..., None]
    from repro.kernels import ops
    if initial_state is None and ops.kernel_dispatch(use_pallas):
        Y, h_final = ops.ssd(Xdt, A_log_disc, Bm.astype(jnp.float32),
                             Cm.astype(jnp.float32), chunk=s.chunk_size,
                             n_groups=s.n_groups, use_pallas=use_pallas)
    else:
        Y, h_final = ssd_chunked(Xdt, A_log_disc, Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), s.chunk_size,
                                 initial_state)
    Y = Y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    Y = Y.reshape(B, S, d_inner).astype(x.dtype)
    Y = rmsnorm(Y * silu(z), p["norm"], cfg.norm_eps)
    out = Y @ p["out_proj"]
    if return_state:
        return out, h_final
    return out


def mamba2_decode(p, x_t, cfg: ModelConfig, ssm_state, conv_state):
    """One decode step. x_t: [B, D]. Returns (y_t [B, D], ssm_state, conv_state)."""
    s = cfg.ssm
    d_inner, n_heads, conv_ch = mamba2_split_dims(cfg)
    B = x_t.shape[0]
    zxbcdt = x_t @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_ch], axis=-1)
    xBC, conv_state = causal_conv1d_step(xBC, conv_state, p["conv_w"], p["conv_b"])
    xBC = silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    xs = xs.reshape(B, n_heads, s.head_dim).astype(jnp.float32)
    Bm = Bm.reshape(B, s.n_groups, s.d_state).astype(jnp.float32)
    Cm = Cm.reshape(B, s.n_groups, s.d_state).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # [B,H]
    A_raw = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_decode_step(xs, dt, A_raw, Bm, Cm, p["D"], ssm_state)
    y = y.reshape(B, d_inner).astype(x_t.dtype)
    y = rmsnorm(y * silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], ssm_state, conv_state
