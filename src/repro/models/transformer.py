"""Model zoo: all six assigned architecture families, one functional API.

Families
--------
dense   : pre-norm GQA transformer decoder (llama/qwen style)
moe     : dense + mixture-of-experts MLPs (mixtral, granite)
ssm     : Mamba2 / SSD stack (attention-free)
hybrid  : jamba-style 1:7 attention:mamba interleave with periodic MoE
encdec  : encoder-decoder with cross-attention (seamless backbone)
vlm     : dense decoder consuming stubbed image-patch embeddings (phi-3-v)

API
---
init_model(cfg, key, dtype)                          -> params
forward(cfg, params, batch, ...)                     -> (logits, aux)
init_cache(cfg, batch_size, cache_len, dtype, ...)   -> cache
decode_step(cfg, params, batch, cache, ...)          -> (logits, cache)

All layer stacks are ``lax.scan`` over layer-stacked params, so the HLO holds
ONE layer body regardless of depth — essential for the 72B/398B dry-run
compiles (DESIGN.md §7).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.sharding.ctx import constrain

# Fixed encoder-memory length used by decode shapes of encoder-decoder archs.
ENC_MEMORY_LEN = 1024


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stacked_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys) if n > 0 else None


def _init_block(key, cfg: ModelConfig, dtype, *, mixer: str, mlp: str,
                cross: bool = False):
    """One transformer block: {ln1, mixer, ln2?, mlp?, cross?}."""
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    else:
        p["mamba"] = L.init_mamba2(ks[0], cfg, dtype)
    if cross:
        p["ln_cross"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = L.init_attention(ks[2], cfg, dtype)
    if mlp == "dense":
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif mlp == "moe":
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["moe"] = L.init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
    return p


def _layer_plan(cfg: ModelConfig):
    """Static per-layer (mixer, mlp) plan for one stack."""
    plan = []
    for i in range(cfg.num_layers):
        if cfg.family == "ssm":
            mixer = "mamba"
        elif cfg.attn_period:
            mixer = "attn" if (i % cfg.attn_period) == cfg.attn_period - 1 else "mamba"
        else:
            mixer = "attn"
        if cfg.family == "ssm":
            mlp = "none"
        elif cfg.moe is not None and (
                cfg.moe_period == 0 or (i % cfg.moe_period) == cfg.moe_period - 1):
            mlp = "moe"
        elif cfg.d_ff:
            mlp = "dense"
        else:
            mlp = "none"
        plan.append((mixer, mlp))
    return plan


def _homogeneous(cfg: ModelConfig) -> bool:
    plan = _layer_plan(cfg)
    return all(p == plan[0] for p in plan)


def init_model(cfg: ModelConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(ks[1], cfg.d_model, cfg.vocab_size, dtype)

    if cfg.is_encoder_decoder:
        mixer, mlp = "attn", ("moe" if cfg.moe else "dense")
        params["encoder"] = _stacked_init(
            lambda k: _init_block(k, cfg, dtype, mixer="attn", mlp=mlp),
            ks[2], cfg.num_layers)
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
        params["decoder"] = _stacked_init(
            lambda k: _init_block(k, cfg, dtype, mixer="attn", mlp=mlp, cross=True),
            ks[3], cfg.num_layers)
        if cfg.continuous_encoder_input:
            params["enc_in_proj"] = L.init_dense(ks[4], cfg.d_model, cfg.d_model, dtype)
        return params

    plan = _layer_plan(cfg)
    if _homogeneous(cfg):
        mixer, mlp = plan[0]
        params["blocks"] = _stacked_init(
            lambda k: _init_block(k, cfg, dtype, mixer=mixer, mlp=mlp),
            ks[2], cfg.num_layers)
    else:
        # hybrid: stack per (position-in-group) so a 2-level scan works.
        period = cfg.attn_period
        n_groups = cfg.num_layers // period
        group_keys = jax.random.split(ks[2], period)
        positions = {}
        for j in range(period):
            mixer, mlp = plan[j]
            positions[f"pos{j}"] = _stacked_init(
                lambda k, m=mixer, f=mlp: _init_block(k, cfg, dtype, mixer=m, mlp=f),
                group_keys[j], n_groups)
        params["groups"] = positions
    return params


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def _block_apply(p, x, cfg: ModelConfig, *, mixer: str, mlp: str,
                 causal: bool = True, window=None, positions=None,
                 memory=None, moe_impl: str = "dense",
                 q_chunk: int = 512, kv_chunk: int = 1024,
                 use_pallas=None):
    """Full-sequence block application (train / prefill). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        q, k, v = L.attention_qkv(p["attn"], h, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if ops.kernel_dispatch(use_pallas):
            # flash-attention kernel under the dispatch policy (TPU /
            # REPRO_FORCE_PALLAS / explicit opt-in); ops.attention owns
            # the off-TPU interpret-mode warning
            attn_out = ops.attention(q, k, v, causal=causal, window=window,
                                     use_pallas=use_pallas)
        else:
            attn_out = L.blockwise_attention(
                q, k, v, causal=causal, window=window,
                q_positions=None, k_positions=None,
                q_chunk=q_chunk, kv_chunk=kv_chunk)
        B, S = x.shape[:2]
        x = x + attn_out.reshape(B, S, -1) @ p["attn"]["wo"]
    else:
        x = x + L.mamba2_apply(p["mamba"], h, cfg, use_pallas=use_pallas)
    if memory is not None and "cross" in p:
        h = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        q, k, v = L.attention_qkv(p["cross"], h, cfg, kv_x=memory)
        out = L.blockwise_attention(q, k, v, causal=False,
                                    q_chunk=q_chunk, kv_chunk=kv_chunk)
        B, S = x.shape[:2]
        x = x + out.reshape(B, S, -1) @ p["cross"]["wo"]
    if mlp == "dense":
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h)
    elif mlp == "moe":
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        out, a = L.MOE_IMPLS[moe_impl](p["moe"], h, cfg.moe)
        x = x + out
        aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _unembed(cfg: ModelConfig, params, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def _scan_stack(stacked, x, body, unroll: int = 1):
    """Scan ``body(layer_params, x) -> (x, aux)`` over a layer-stacked tree.

    ``unroll``: lax.scan unroll factor. The dry-run/roofline path uses full
    unroll because XLA's cost_analysis counts while-loop bodies ONCE, not
    × trip-count — scanned-layer FLOPs/bytes would under-report ~L×.
    """
    def f(carry, lp):
        x, aux = carry
        x, a = body(lp, x)
        return (x, aux + a), None
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    unroll = n if unroll in (0, -1) or unroll >= n else unroll
    (x, aux), _ = lax.scan(f, (x, jnp.zeros((), jnp.float32)), stacked,
                           unroll=unroll)
    return x, aux


def forward(cfg: ModelConfig, params, batch: Dict[str, Any], *,
            moe_impl: str = "dense", q_chunk: int = 512, kv_chunk: int = 1024,
            remat: bool = False, unroll: int = 1, use_pallas=None):
    """Returns (logits [B, S, V], aux_loss scalar).

    ``use_pallas`` selects the attention / SSD kernel route per the
    ``repro.kernels.ops`` dispatch policy (None = follow the backend)."""
    window = cfg.sliding_window

    if cfg.is_encoder_decoder:
        return _forward_encdec(cfg, params, batch, moe_impl=moe_impl,
                               q_chunk=q_chunk, kv_chunk=kv_chunk, remat=remat,
                               unroll=unroll, use_pallas=use_pallas)

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = constrain(_embed(cfg, params, tokens), "act")
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype)
        n_img = img.shape[1]
        x = jnp.concatenate([img, x[:, n_img:]], axis=1)
    positions = jnp.arange(S)

    def make_body(mixer, mlp):
        def body(lp, x):
            x, aux = _block_apply(lp, x, cfg, mixer=mixer, mlp=mlp,
                                  causal=True, window=window,
                                  positions=positions, moe_impl=moe_impl,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk,
                                  use_pallas=use_pallas)
            return constrain(x, "act"), aux
        if remat:
            return jax.checkpoint(body, prevent_cse=False)
        return body

    plan = _layer_plan(cfg)
    if "blocks" in params:
        mixer, mlp = plan[0]
        x, aux = _scan_stack(params["blocks"], x, make_body(mixer, mlp),
                             unroll=unroll)
    else:
        period = cfg.attn_period
        bodies = [make_body(*plan[j]) for j in range(period)]

        def group_body(gp, x):
            aux = jnp.zeros((), jnp.float32)
            for j in range(period):
                x, a = bodies[j](gp[f"pos{j}"], x)
                aux = aux + a
            return x, aux

        x, aux = _scan_stack(params["groups"], x, group_body, unroll=unroll)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(_unembed(cfg, params, x), "logits"), aux


def _forward_encdec(cfg: ModelConfig, params, batch, *, moe_impl, q_chunk,
                    kv_chunk, remat, unroll: int = 1, use_pallas=None):
    mlp = "moe" if cfg.moe else "dense"
    # --- encoder ---
    if cfg.continuous_encoder_input:
        src = batch["src_embeds"]                        # [B, Ss, D] (stub frontend)
        enc_x = src @ params["enc_in_proj"]
    else:
        enc_x = _embed(cfg, params, batch["src_tokens"])
    Ss = enc_x.shape[1]
    enc_pos = jnp.arange(Ss)

    def enc_body(lp, x):
        x, aux = _block_apply(lp, x, cfg, mixer="attn", mlp=mlp, causal=False,
                              positions=enc_pos, moe_impl=moe_impl,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              use_pallas=use_pallas)
        return constrain(x, "act"), aux

    body = jax.checkpoint(enc_body, prevent_cse=False) if remat else enc_body
    memory, aux_e = _scan_stack(params["encoder"], enc_x, body, unroll=unroll)
    memory = L.rmsnorm(memory, params["enc_final_norm"], cfg.norm_eps)

    # --- decoder ---
    tokens = batch["tokens"]
    St = tokens.shape[1]
    dec_x = _embed(cfg, params, tokens)
    dec_pos = jnp.arange(St)

    def dec_body(lp, x):
        x, aux = _block_apply(lp, x, cfg, mixer="attn", mlp=mlp, causal=True,
                              positions=dec_pos, memory=memory,
                              moe_impl=moe_impl, q_chunk=q_chunk,
                              kv_chunk=kv_chunk, use_pallas=use_pallas)
        return constrain(x, "act"), aux

    body = jax.checkpoint(dec_body, prevent_cse=False) if remat else dec_body
    x, aux_d = _scan_stack(params["decoder"], dec_x, body, unroll=unroll)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(_unembed(cfg, params, x), "logits"), aux_e + aux_d


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def _attn_cache(n_layers, B, C, cfg, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((n_layers, B, C, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, B, C, cfg.num_kv_heads, hd), dtype),
        "k_pos": jnp.full((n_layers, C), -1, jnp.int32),
    }


def _ssm_cache(n_layers, B, cfg, dtype):
    s = cfg.ssm
    d_inner, n_heads, conv_ch = L.mamba2_split_dims(cfg)
    return {
        "ssm_state": jnp.zeros((n_layers, B, n_heads, s.head_dim, s.d_state),
                               jnp.float32),
        "conv_state": jnp.zeros((n_layers, B, s.conv_width - 1, conv_ch), dtype),
    }


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int,
               dtype=jnp.float32, window: Optional[int] = None):
    """Create the decode cache.

    ``window`` (if set) makes attention caches ring buffers of that size —
    the sub-quadratic SWA variant used by ``long_500k`` for full-attention
    families (DESIGN.md §6).
    """
    C = min(cache_len, window) if window else cache_len
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32),
                             "cache_len": jnp.asarray(C, jnp.int32)}
    if cfg.is_encoder_decoder:
        cache["self"] = _attn_cache(cfg.num_layers, batch_size, C, cfg, dtype)
        hd = cfg.resolved_head_dim
        cache["cross_k"] = jnp.zeros(
            (cfg.num_layers, batch_size, ENC_MEMORY_LEN, cfg.num_kv_heads, hd), dtype)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        return cache
    if cfg.family == "ssm":
        cache["ssm"] = _ssm_cache(cfg.num_layers, batch_size, cfg, dtype)
        return cache
    if cfg.attn_period:
        period = cfg.attn_period
        n_groups = cfg.num_layers // period
        cache["attn"] = _attn_cache(n_groups, batch_size, C, cfg, dtype)
        cache["ssm"] = {
            k: v.reshape((n_groups, period - 1) + v.shape[1:])
            for k, v in _ssm_cache(n_groups * (period - 1), batch_size, cfg,
                                   dtype).items()}
        return cache
    cache["attn"] = _attn_cache(cfg.num_layers, batch_size, C, cfg, dtype)
    return cache


def encode_memory(cfg: ModelConfig, params, batch, *, moe_impl: str = "dense",
                  q_chunk: int = 512, kv_chunk: int = 1024):
    """Run the encoder and precompute per-decoder-layer cross-attention K/V.

    Returns (cross_k, cross_v): [L, B, S_enc, K, hd] — plugged into the
    decode cache of encoder-decoder architectures.
    """
    assert cfg.is_encoder_decoder
    mlp = "moe" if cfg.moe else "dense"
    if cfg.continuous_encoder_input:
        enc_x = batch["src_embeds"] @ params["enc_in_proj"]
    else:
        enc_x = _embed(cfg, params, batch["src_tokens"])
    enc_pos = jnp.arange(enc_x.shape[1])

    def enc_body(lp, x):
        return _block_apply(lp, x, cfg, mixer="attn", mlp=mlp, causal=False,
                            positions=enc_pos, moe_impl=moe_impl,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)

    memory, _ = _scan_stack(params["encoder"], enc_x, enc_body)
    memory = L.rmsnorm(memory, params["enc_final_norm"], cfg.norm_eps)

    hd = cfg.resolved_head_dim
    B, Ss = memory.shape[:2]

    def layer_kv(carry, lp):
        h = L.rmsnorm(memory, lp["ln_cross"], cfg.norm_eps)
        k = h @ lp["cross"]["wk"]
        v = h @ lp["cross"]["wv"]
        if "bk" in lp["cross"]:
            k = k + lp["cross"]["bk"]
            v = v + lp["cross"]["bv"]
        k = k.reshape(B, Ss, cfg.num_kv_heads, hd)
        v = v.reshape(B, Ss, cfg.num_kv_heads, hd)
        return carry, (k, v)

    _, (ck, cv) = lax.scan(layer_kv, 0, params["decoder"])
    return ck, cv


def _attn_decode(p, h, cfg, lc, pos, window):
    """One-token attention with ring-buffer cache update.

    h: [B, 1, D]; lc: per-layer cache {k, v, k_pos}. Returns (out, new_lc).
    """
    C = lc["k"].shape[1]
    q, k, v = L.attention_qkv(p, h, cfg)
    pos_b = jnp.full((h.shape[0],), pos, jnp.int32)
    q = L.apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos_b[:, None], cfg.rope_theta)
    slot = jnp.mod(pos, C)
    new_k = lax.dynamic_update_slice(lc["k"], k, (0, slot, 0, 0))
    new_v = lax.dynamic_update_slice(lc["v"], v, (0, slot, 0, 0))
    new_kpos = lax.dynamic_update_slice(lc["k_pos"], pos[None].astype(jnp.int32),
                                        (slot,))
    valid = new_kpos >= 0
    out = L.full_attention_1q(q, new_k, new_v,
                              jnp.broadcast_to(new_kpos, (h.shape[0], C)),
                              pos_b, window=window,
                              kv_valid=jnp.broadcast_to(valid, (h.shape[0], C)))
    out = out.reshape(h.shape[0], 1, -1) @ p["wo"]
    return out, {"k": new_k, "v": new_v, "k_pos": new_kpos}


def decode_step(cfg: ModelConfig, params, batch: Dict[str, Any], cache, *,
                moe_impl: str = "dense", unroll: int = 1):
    """One decode step. batch["tokens"]: [B, 1]. Returns (logits [B,1,V], cache)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    pos = cache["pos"]
    window = cfg.sliding_window
    x = _embed(cfg, params, tokens)                      # [B, 1, D]

    aux_cache = dict(cache)

    def attn_block_decode(lp, x, lc):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        out, new_lc = _attn_decode(lp["attn"], h, cfg, lc, pos, window)
        x = x + out
        x = _mlp_decode(lp, x, cfg, moe_impl)
        return x, new_lc

    def mamba_block_decode(lp, x, lc):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        y, ssm_state, conv_state = L.mamba2_decode(
            lp["mamba"], h[:, 0], cfg, lc["ssm_state"], lc["conv_state"])
        x = x + y[:, None]
        x = _mlp_decode(lp, x, cfg, moe_impl)
        return x, {"ssm_state": ssm_state, "conv_state": conv_state}

    if cfg.is_encoder_decoder:
        def body(x, inp):
            lp, lc, ck, cv = inp
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            out, new_lc = _attn_decode(lp["attn"], h, cfg, lc, pos, window)
            x = x + out
            # cross-attention against fixed encoder memory K/V
            h = L.rmsnorm(x, lp["ln_cross"], cfg.norm_eps)
            q = (h @ lp["cross"]["wq"])
            if "bq" in lp["cross"]:
                q = q + lp["cross"]["bq"]
            hd = cfg.resolved_head_dim
            q = q.reshape(B, 1, cfg.num_heads, hd)
            mem_pos = jnp.broadcast_to(jnp.arange(ck.shape[1]), (B, ck.shape[1]))
            big = jnp.full((B,), 2**30, jnp.int32)
            out = L.full_attention_1q(q, ck, cv, mem_pos, big)
            x = x + out.reshape(B, 1, -1) @ lp["cross"]["wo"]
            x = _mlp_decode(lp, x, cfg, moe_impl)
            return x, new_lc

        sc = cache["self"]
        n_l = cfg.num_layers
        x, new_sc = lax.scan(
            lambda x, inp: body(x, inp), x,
            (params["decoder"], sc, cache["cross_k"], cache["cross_v"]),
            unroll=n_l if unroll in (0, -1) or unroll >= n_l else unroll)
        aux_cache["self"] = new_sc
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, lc = inp
            return mamba_block_decode(lp, x, lc)
        n_l = cfg.num_layers
        x, new_ssm = lax.scan(body, x, (params["blocks"], cache["ssm"]),
                              unroll=n_l if unroll in (0, -1) or unroll >= n_l
                              else unroll)
        aux_cache["ssm"] = new_ssm
    elif cfg.attn_period:
        period = cfg.attn_period
        plan = _layer_plan(cfg)

        def group_body(x, inp):
            gp, attn_lc, ssm_lc = inp
            new_ssm, mamba_i = {}, 0
            new_attn = attn_lc
            for j in range(period):
                mixer, _ = plan[j]
                lp = gp[f"pos{j}"]
                if mixer == "attn":
                    x, new_attn = attn_block_decode(lp, x, attn_lc)
                else:
                    lc_j = {k: v[mamba_i] for k, v in ssm_lc.items()}
                    x, upd = mamba_block_decode(lp, x, lc_j)
                    for k in upd:
                        new_ssm.setdefault(k, []).append(upd[k])
                    mamba_i += 1
            new_ssm = {k: jnp.stack(v) for k, v in new_ssm.items()}
            return x, (new_attn, new_ssm)

        n_g = cfg.num_layers // period
        x, (new_attn, new_ssm) = lax.scan(
            group_body, x, (params["groups"], cache["attn"], cache["ssm"]),
            unroll=n_g if unroll in (0, -1) or unroll >= n_g else unroll)
        aux_cache["attn"] = new_attn
        aux_cache["ssm"] = new_ssm
    else:
        def body(x, inp):
            lp, lc = inp
            return attn_block_decode(lp, x, lc)
        n_l = cfg.num_layers
        x, new_attn = lax.scan(body, x, (params["blocks"], cache["attn"]),
                               unroll=n_l if unroll in (0, -1) or unroll >= n_l
                               else unroll)
        aux_cache["attn"] = new_attn

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, x)
    aux_cache["pos"] = pos + 1
    return logits, aux_cache


def _mlp_decode(lp, x, cfg, moe_impl):
    if "mlp" in lp:
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h)
    elif "moe" in lp:
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        out, _ = L.MOE_IMPLS[moe_impl](lp["moe"], h, cfg.moe)
        x = x + out
    return x
