"""The paper's local model (Fig. 3): conv5x5 -> pool -> conv5x5 -> pool ->
fc1 -> fc2, with per-layer named params so the K-means feature-layer study
(Fig. 4 / Fig. 8 / Fig. 9) can select ``w_c1 … b_fc2`` exactly as the paper
does.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.paper_cnn import CNNConfig

PAPER_LAYER_NAMES = ("w_c1", "b_c1", "w_c2", "b_c2",
                     "w_fc1", "b_fc1", "w_fc2", "b_fc2")


def init_cnn(cfg: CNNConfig, key, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 4)
    k5 = cfg.kernel

    def conv_init(k, cin, cout):
        scale = 1.0 / math.sqrt(k5 * k5 * cin)
        return (jax.random.normal(k, (k5, k5, cin, cout), jnp.float32)
                * scale).astype(dtype)

    def fc_init(k, din, dout):
        scale = 1.0 / math.sqrt(din)
        return (jax.random.normal(k, (din, dout), jnp.float32) * scale).astype(dtype)

    return {
        "w_c1": conv_init(ks[0], cfg.input_channels, cfg.conv1_out),
        "b_c1": jnp.zeros((cfg.conv1_out,), dtype),
        "w_c2": conv_init(ks[1], cfg.conv1_out, cfg.conv2_out),
        "b_c2": jnp.zeros((cfg.conv2_out,), dtype),
        "w_fc1": fc_init(ks[2], cfg.flat_features, cfg.fc1_out),
        "b_fc1": jnp.zeros((cfg.fc1_out,), dtype),
        "w_fc2": fc_init(ks[3], cfg.fc1_out, cfg.num_classes),
        "b_fc2": jnp.zeros((cfg.num_classes,), dtype),
    }


def _conv(x, w, b):
    """5x5 VALID convolution via im2col + one GEMM.

    Spelled as patch-slices feeding a matmul instead of
    ``lax.conv_general_dilated`` because the FL round vmaps this over
    per-client kernels (and the cohort engine over seeds on top): batched
    conv with distinct kernels lowers to grouped convolution, which XLA CPU
    executes ~2-4x slower than the equivalent batched GEMM. The im2col form
    is also what the jax_pallas kernels fuse best. Same math, summation
    order differs only within the K=k·k·cin contraction.
    """
    kh, kw, cin, cout = w.shape
    H = x.shape[1] - kh + 1
    W = x.shape[2] - kw + 1
    cols = jnp.concatenate([x[:, di:di + H, dj:dj + W, :]
                            for di in range(kh) for dj in range(kw)], axis=-1)
    return cols @ w.reshape(kh * kw * cin, cout) + b


def _maxpool(x, p):
    return lax.reduce_window(x, -jnp.inf, lax.max,
                             (1, p, p, 1), (1, p, p, 1), "VALID")


def cnn_forward(params, images, cfg: CNNConfig):
    """images: [B, H, W, C] -> logits [B, num_classes]."""
    x = jax.nn.relu(_conv(images, params["w_c1"], params["b_c1"]))
    x = _maxpool(x, cfg.pool)
    x = jax.nn.relu(_conv(x, params["w_c2"], params["b_c2"]))
    x = _maxpool(x, cfg.pool)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["w_fc1"] + params["b_fc1"])
    return x @ params["w_fc2"] + params["b_fc2"]


def cnn_loss(params, batch, cfg: CNNConfig):
    """Cross-entropy loss (the paper's loss, §III-C)."""
    logits = cnn_forward(params, batch["images"], cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def cnn_accuracy(params, batch, cfg: CNNConfig):
    logits = cnn_forward(params, batch["images"], cfg)
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
