"""Model registry — the seam that makes the round engine model-agnostic.

A federated workload is a :class:`ModelDef`: how to initialize one client's
TRAINABLE state, compute its local loss, and evaluate a model on the held-out
set. The engine (``repro.core.engine``) dispatches on the TYPE of the frozen,
hashable model config riding in ``EngineConfig.model_cfg`` — the config
object itself stays the cache key for every compiled program and shared
engine, so registering new workloads cannot perturb existing keys or
numerics: ``CNNConfig`` configs resolve to the exact same ``init_cnn`` /
``cnn_loss`` function objects the engine used when it was CNN-hardwired.

Two registries live here:

* config-type -> :class:`ModelDef` (``model_def_for``): the engine-side
  dispatch. Keyed by type so it needs no strings on the hot path.
* workload name -> config builder (``workload_config``): the spec-side
  dispatch. ``ExperimentSpec(model="tinyllama")`` resolves through this to a
  frozen config object; ``"auto"``/``"cnn"`` stay on the paper-CNN path in
  ``build_experiment`` and never touch this table.

``repro.models.lm`` registers the LoRA LM workloads on import (the package
``__init__`` imports it, so any ``repro.models.registry`` import sees them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import CNNConfig
from repro.models.cnn import cnn_forward, cnn_loss, init_cnn


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """One federated workload's model hooks.

    ``init(cfg, key)`` returns the PER-CLIENT trainable pytree — for the
    LoRA LM that is the adapter tree only, so the flat plane is
    ``[N, P_adapter]`` while the frozen base rides outside the plane.
    ``loss(params, batch, cfg)`` consumes ``batch = {"images", "labels"}``
    (LM workloads ride token windows in the ``"images"`` slot).
    ``evaluate(params, test_x, test_y, cfg=cfg)`` returns
    ``(accuracy, per_class)``.
    ``price_uploads=True`` tells the driver to price the fleet's upload
    payload ``z`` from the trainable parameter count (``P·32`` bits) instead
    of the paper CNN's fixed default — the LoRA workloads upload P_adapter,
    never P_base.
    ``make_dataset(cfg, num_samples, seed=...)`` (optional) builds the
    workload's synthetic dataset; ``None`` means the workload rides the
    image datasets selected by ``ExperimentSpec.dataset`` (the CNN path).
    """
    name: str
    init: Callable
    loss: Callable
    evaluate: Callable
    price_uploads: bool = False
    make_dataset: Any = None


def _cnn_evaluate(params, test_images, test_labels, *, cfg: CNNConfig):
    logits = cnn_forward(params, test_images, cfg)
    pred = jnp.argmax(logits, axis=-1)
    acc = jnp.mean((pred == test_labels).astype(jnp.float32))
    onehot = jax.nn.one_hot(test_labels, cfg.num_classes)
    correct = (pred == test_labels).astype(jnp.float32)[:, None] * onehot
    per_class = jnp.sum(correct, 0) / jnp.maximum(jnp.sum(onehot, 0), 1.0)
    return acc, per_class


#: the paper's workload — binds the ORIGINAL function objects so every
#: jaxpr the generalized engine traces for a CNNConfig is the one it
#: traced before the registry existed (the model="cnn" bit-identity pin)
CNN_DEF = ModelDef(name="cnn", init=init_cnn, loss=cnn_loss,
                   evaluate=_cnn_evaluate)

_DEFS_BY_CONFIG_TYPE: Dict[type, ModelDef] = {CNNConfig: CNN_DEF}
_WORKLOADS: Dict[str, Callable[[], Any]] = {}


def register_model_def(cfg_type: type, mdef: ModelDef) -> None:
    """Bind a frozen-config TYPE to its engine hooks."""
    _DEFS_BY_CONFIG_TYPE[cfg_type] = mdef


def register_workload(name: str, builder: Callable[[], Any]) -> None:
    """Bind an ``ExperimentSpec.model`` name to a config builder."""
    _WORKLOADS[name] = builder


def model_def_for(model_cfg) -> ModelDef:
    """The :class:`ModelDef` for a config object (engine-side dispatch)."""
    mdef = _DEFS_BY_CONFIG_TYPE.get(type(model_cfg))
    if mdef is None:
        raise TypeError(
            f"no ModelDef registered for config type "
            f"{type(model_cfg).__name__}; register one with "
            "repro.models.registry.register_model_def")
    return mdef


def workload_config(name: str):
    """Resolve an ``ExperimentSpec.model`` name to its frozen config."""
    try:
        builder = _WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown model {name!r}; known: "
            f"{('auto', 'cnn') + workload_names()}") from None
    return builder()


def workload_names() -> Tuple[str, ...]:
    """The registered non-CNN workload names (``"auto"``/``"cnn"`` are
    aliases for the paper CNN and resolve in ``build_experiment``)."""
    return tuple(sorted(_WORKLOADS))
