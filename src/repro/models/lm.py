"""Federated LM workload: per-client LoRA adapters over a frozen transformer.

The per-client trainable state is a LoRA adapter tree (stacked per-layer
low-rank ``A``/``B`` factors on the attention q/v projections for dense
families, the Mamba2 in/out projections for SSM families). The frozen base
weights are derived ONCE per :class:`LMConfig` from ``base_seed`` and live
OUTSIDE the flat parameter plane — the analogue of the paged store's
broadcast base row — so the ``[N, P]`` client plane holds only
``P = P_adapter`` columns and divergence / K-means / aggregation /
compression / upload pricing all operate on adapter rows unchanged.

``merge_lora`` materializes ``w_eff = w_base + (alpha/rank)·A@B`` on the
stacked block leaves and hands the merged tree to the untouched
``transformer.forward`` — every existing model feature (RoPE, GQA,
scan-stacked layers, the flash-attention/SSD kernel dispatch) applies to the
federated workload for free.

Data rides the engine's existing ``(images, labels)`` slots: ``"images"``
holds ``[B, seq_len+1]`` int32 token windows (``repro.data.lm_data``),
``"labels"`` the window's dialect id — the loss derives next-token targets
from the window shift and never reads the dialect.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig
from repro.models.registry import (ModelDef, register_model_def,
                                   register_workload)
from repro.models.transformer import forward, init_model


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Frozen, hashable config of one LoRA LM workload — the engine cache
    key, exactly as ``CNNConfig`` is for the paper CNN."""
    model: ModelConfig              # the frozen-base transformer architecture
    seq_len: int = 32               # tokens per training window
    rank: int = 4                   # LoRA rank r
    alpha: float = 8.0              # LoRA scaling (applied as alpha/rank)
    base_seed: int = 0              # PRNG seed the frozen base derives from
    num_dialects: int = 10          # synthetic dialects = "classes" for
                                    # non-iid partitioning and per_class eval


def _check_supported(m: ModelConfig) -> None:
    if m.is_encoder_decoder or m.attn_period or m.moe is not None:
        raise ValueError(
            f"{m.name}: LoRA FL workloads support homogeneous dense/ssm "
            "stacks only (no enc-dec / hybrid / MoE)")
    if m.family not in ("dense", "ssm", "vlm"):
        raise ValueError(f"{m.name}: unsupported family {m.family!r}")


def adapter_targets(cfg: LMConfig):
    """``name -> (d_in, d_out)`` of the frozen-base leaves LoRA wraps."""
    m = cfg.model
    _check_supported(m)
    if m.family == "ssm":
        s = m.ssm
        d_inner = s.expand * m.d_model
        n_heads = d_inner // s.head_dim
        return {"in_proj": (m.d_model,
                            2 * d_inner + 2 * s.n_groups * s.d_state + n_heads),
                "out_proj": (d_inner, m.d_model)}
    hd = m.resolved_head_dim
    return {"wq": (m.d_model, m.num_heads * hd),
            "wv": (m.d_model, m.num_kv_heads * hd)}


def init_adapter(cfg: LMConfig, key, dtype=jnp.float32):
    """One client's trainable state: stacked ``[L, d_in, r]`` A factors
    (scaled normals) and ``[L, r, d_out]`` B factors (zeros — the standard
    LoRA init, so a fresh adapter is an exact no-op on the base model)."""
    m = cfg.model
    targets = adapter_targets(cfg)
    ks = jax.random.split(key, len(targets))
    group = "mamba" if m.family == "ssm" else "attn"
    leaves = {}
    for k, (name, (d_in, d_out)) in zip(ks, sorted(targets.items())):
        a = (jax.random.normal(k, (m.num_layers, d_in, cfg.rank), jnp.float32)
             * (1.0 / math.sqrt(d_in))).astype(dtype)
        leaves[f"{name}_a"] = a
        leaves[f"{name}_b"] = jnp.zeros((m.num_layers, cfg.rank, d_out), dtype)
    return {"blocks": {group: leaves}}


@functools.lru_cache(maxsize=8)
def base_params(cfg: LMConfig):
    """The frozen base weights for ``cfg`` — derived from ``base_seed``
    once per process and captured as jit constants by every closure that
    merges against them (the broadcast ``[P_base]`` row that never enters
    the client plane). The first call may land inside a trace (the engine's
    scanned program), where jnp ops stage instead of executing —
    ``ensure_compile_time_eval`` forces concrete arrays so the cache never
    holds tracers."""
    _check_supported(cfg.model)
    with jax.ensure_compile_time_eval():
        return init_model(cfg.model, jax.random.PRNGKey(cfg.base_seed))


def adapter_num_params(cfg: LMConfig) -> int:
    """P_adapter — the per-client upload size in parameters."""
    template = jax.eval_shape(functools.partial(init_adapter, cfg),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    return int(sum(np.prod(l.shape)
                   for l in jax.tree_util.tree_leaves(template)))


def merge_lora(cfg: LMConfig, adapter):
    """``base + (alpha/rank)·A@B`` on the wrapped block leaves; every other
    leaf is the shared base object (no copy)."""
    base = base_params(cfg)
    scale = cfg.alpha / cfg.rank

    def low_rank(a, b):
        return scale * jnp.einsum("ldr,lrk->ldk", a.astype(jnp.float32),
                                  b.astype(jnp.float32))

    group = "mamba" if cfg.model.family == "ssm" else "attn"
    ad = adapter["blocks"][group]
    wrapped = dict(base["blocks"][group])
    for name in adapter_targets(cfg):
        wrapped[name] = wrapped[name] + low_rank(ad[f"{name}_a"],
                                                 ad[f"{name}_b"])
    blocks = dict(base["blocks"])
    blocks[group] = wrapped
    merged = dict(base)
    merged["blocks"] = blocks
    return merged


def lm_loss(adapter, batch, cfg: LMConfig):
    """Next-token cross-entropy over the window shift. ``batch["images"]``
    is ``[B, seq_len+1]`` int32; the dialect labels are partition metadata
    only."""
    merged = merge_lora(cfg, adapter)
    tokens = batch["images"]
    logits, _ = forward(cfg.model, merged, {"tokens": tokens[:, :-1]})
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def lm_evaluate(adapter, test_windows, test_dialects, *, cfg: LMConfig):
    """(next-token accuracy, per-dialect accuracy) — the LM analogue of the
    CNN's (accuracy, per_class) contract, so traced history bookkeeping is
    shape-compatible across workloads."""
    merged = merge_lora(cfg, adapter)
    tokens = test_windows
    logits, _ = forward(cfg.model, merged, {"tokens": tokens[:, :-1]})
    pred = jnp.argmax(logits, axis=-1)                       # [T, S]
    hit = (pred == tokens[:, 1:]).astype(jnp.float32)
    window_acc = jnp.mean(hit, axis=-1)                      # [T]
    acc = jnp.mean(window_acc)
    onehot = jax.nn.one_hot(test_dialects, cfg.num_dialects)
    per_class = (jnp.sum(onehot * window_acc[:, None], 0)
                 / jnp.maximum(jnp.sum(onehot, 0), 1.0))
    return acc, per_class


def lm_make_dataset(cfg: LMConfig, num_samples: int, seed: int = 0):
    from repro.data.lm_data import make_lm_dataset
    return make_lm_dataset(num_samples, cfg.seq_len, cfg.model.vocab_size,
                           num_dialects=cfg.num_dialects, seed=seed)


LORA_LM_DEF = ModelDef(name="lora-lm", init=init_adapter, loss=lm_loss,
                       evaluate=lm_evaluate, price_uploads=True,
                       make_dataset=lm_make_dataset)

register_model_def(LMConfig, LORA_LM_DEF)
register_workload("tinyllama",
                  lambda: LMConfig(model=get_smoke_config("tinyllama-1.1b")))
register_workload("mamba2-130m",
                  lambda: LMConfig(model=get_smoke_config("mamba2-130m")))
