from repro.models.transformer import (
    init_model,
    forward,
    init_cache,
    decode_step,
    ENC_MEMORY_LEN,
)
from repro.models.cnn import init_cnn, cnn_forward, cnn_loss, cnn_accuracy
from repro.models.registry import (ModelDef, model_def_for, register_model_def,
                                   register_workload, workload_config,
                                   workload_names)
import repro.models.lm  # noqa: F401  (registers the LoRA LM workloads)
