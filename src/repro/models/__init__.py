from repro.models.transformer import (
    init_model,
    forward,
    init_cache,
    decode_step,
    ENC_MEMORY_LEN,
)
from repro.models.cnn import init_cnn, cnn_forward, cnn_loss, cnn_accuracy
