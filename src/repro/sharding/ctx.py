"""Opt-in activation sharding constraints (hillclimb lever, §Perf).

GSPMD propagation from params+inputs alone sometimes picks replicated or
involuntarily-rematerialized layouts for large intermediates (we observed
67 GB replicated logits when the vocab doesn't divide the model axis, and
"[SPMD] Involuntary full rematerialization" warnings on attention
reshapes). Model code calls ``constrain(x, kind)``; outside a configured
context this is the identity, so tests/examples are untouched.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax

_SPECS: contextvars.ContextVar[Optional[Dict]] = \
    contextvars.ContextVar("act_sharding_specs", default=None)


@contextlib.contextmanager
def activation_sharding(specs: Dict):
    """specs: kind -> PartitionSpec, e.g. {"act": P(("pod","data"), None),
    "logits": P(("pod","data"), None, "model")}."""
    token = _SPECS.set(specs)
    try:
        yield
    finally:
        _SPECS.reset(token)


def constrain(x, kind: str):
    specs = _SPECS.get()
    if specs is None or kind not in specs:
        return x
    spec = specs[kind]
    ndim_spec = len(spec)
    if x.ndim < ndim_spec:
        return x
    if x.ndim > ndim_spec:
        spec = jax.sharding.PartitionSpec(*spec, *([None] * (x.ndim - ndim_spec)))
    return jax.lax.with_sharding_constraint(x, spec)
