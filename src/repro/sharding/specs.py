"""Parameter / activation / cache partition rules for the production mesh.

Divisibility-aware: every rule falls back gracefully when a dim doesn't
divide the ``model`` axis (granite's 24 heads and 40 experts over a 16-way
model axis are the motivating cases — we shard the fused projection dim or
the expert FFN dim instead of heads/experts).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"
DATA_AXES = ("pod", "data")          # batch shards over whichever exist


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_axes(mesh: Mesh, batch_size: int):
    """The tuple of mesh axes the batch dim shards over (must divide)."""
    axes = [a for a in DATA_AXES if a in mesh.axis_names]
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch_size % total == 0:
        return tuple(axes)
    # try fewer axes (e.g. batch=1 -> replicate)
    for k in range(len(axes) - 1, 0, -1):
        sub = axes[:k]
        if batch_size % int(np.prod([mesh.shape[a] for a in sub])) == 0:
            return tuple(sub)
    return ()


def _div(dim: int, m: int) -> bool:
    return m > 1 and dim % m == 0


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# leaf-name -> which logical dim (negative, from the right) to shard over
# `model`, in preference order. Leading stack dims (layer/group) are skipped
# automatically because rules index from the right.
_PREFERENCES = {
    "embed": (-2,),                   # [V, D]   vocab-shard
    "lm_head": (-1,),                 # [D, V]   vocab-shard
    "wq": (-1,), "wk": (-1,), "wv": (-1,),
    "bq": (-1,), "bk": (-1,), "bv": (-1,),
    "wo": (-2,),
    "w_gate": (-3, -1), "w_up": (-3, -1),   # moe [.., E, D, F]: E then F
    "w_down": (-3, -2),                      # moe [.., E, F, D]: E then F
    "router": (),
    "in_proj": (-1,),
    "out_proj": (-2,),
    "conv_w": (-1,), "conv_b": (-1,),
    "enc_in_proj": (-1,),
}
# dense (non-moe) mlp leaves share names with moe ones but have one fewer
# dim; the negative indexing handles both: dense w_gate [.., D, F] -> -3 is
# the layer-stack dim (excluded below), so the -1 fallback fires.


def param_spec(path_names, leaf, mesh: Mesh) -> P:
    m = _axis_size(mesh, MODEL_AXIS)
    name = path_names[-1]
    ndim = leaf.ndim
    # number of leading stack dims ("blocks"/"groups posj"/"encoder"...)
    n_stack = sum(1 for p in path_names
                  if p in ("blocks", "encoder", "decoder") or p.startswith("pos"))
    if "groups" in path_names:
        n_stack = 1  # groups/posj: one group-stack axis
    prefs = _PREFERENCES.get(name, ())
    spec = [None] * ndim
    if name in _PREFERENCES and not prefs:
        return P(*spec)                 # explicitly replicated (router, ...)
    for d in prefs:
        idx = ndim + d
        if idx < n_stack or idx < 0:
            continue
        if _div(leaf.shape[idx], m):
            spec[idx] = MODEL_AXIS
            return P(*spec)
    # fallback: largest trailing dim divisible by m (2D+ only)
    if ndim - n_stack >= 2:
        cands = sorted(range(n_stack, ndim), key=lambda i: -leaf.shape[i])
        for idx in cands:
            if _div(leaf.shape[idx], m):
                spec[idx] = MODEL_AXIS
                return P(*spec)
    return P(*spec)


def params_shardings(param_tree, mesh: Mesh):
    """Tree of NamedShardings matching ``param_tree`` (arrays or structs)."""
    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return NamedSharding(mesh, param_spec(names, leaf, mesh))
    return jax.tree_util.tree_map_with_path(one, param_tree)


def opt_state_shardings(opt_state_struct, params_shardings_tree, mesh: Mesh):
    """Adam moments mirror the param shardings; scalars replicate."""
    flat_p = jax.tree_util.tree_leaves(params_shardings_tree)

    def match(struct_leaf, idx=[0]):
        if struct_leaf.ndim == 0:
            return NamedSharding(mesh, P())
        sh = flat_p[idx[0] % len(flat_p)]
        return sh

    # m and v have identical structure to params; step is scalar. Walk by
    # structure: tree_map over the OptState pytree.
    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        # drop the leading OptState field name (m/v) to match param paths
        return NamedSharding(mesh, param_spec(names, leaf, mesh))

    return jax.tree_util.tree_map_with_path(one, opt_state_struct)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def token_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    ba = batch_axes(mesh, batch)
    return P(ba if ba else None, *([None] * extra_dims))


def seq_shard_axes(mesh: Mesh, seqlen: int, used_by_batch) -> tuple:
    """Axes to shard a long sequence/cache dim over (long_500k: batch=1)."""
    free = [a for a in ("data", "model", "pod") if a in mesh.axis_names
            and a not in (used_by_batch or ())]
    out = []
    prod = 1
    for a in free:
        if seqlen % (prod * mesh.shape[a]) == 0:
            out.append(a)
            prod *= mesh.shape[a]
        if prod >= 256:
            break
    return tuple(out)


def cache_shardings(cfg, cache_struct, mesh: Mesh, batch: int):
    """NamedShardings for a decode cache pytree (see models.init_cache)."""
    m = _axis_size(mesh, MODEL_AXIS)
    ba = batch_axes(mesh, batch)

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        if leaf.ndim == 0 or name in ("pos", "cache_len"):
            return NamedSharding(mesh, P())
        if name in ("k", "v", "cross_k", "cross_v"):
            # [L(, P7), B, C, K, hd]
            spec = [None] * leaf.ndim
            bdim = leaf.ndim - 4
            spec[bdim] = ba if ba else None
            if _div(leaf.shape[-2], m):
                spec[-2] = MODEL_AXIS
            elif not ba and _div(leaf.shape[-3], m):
                spec[-3] = MODEL_AXIS          # shard cache length
            elif _div(leaf.shape[-1], m):
                spec[-1] = MODEL_AXIS
            # long-context (batch unshardable): also spread C over data
            if not ba:
                seq_ax = seq_shard_axes(mesh, leaf.shape[-3],
                                        (MODEL_AXIS,) if MODEL_AXIS in spec else ())
                if seq_ax and spec[-3] is None:
                    spec[-3] = seq_ax if len(seq_ax) > 1 else seq_ax[0]
            return NamedSharding(mesh, P(*spec))
        if name == "k_pos":
            return NamedSharding(mesh, P())
        if name == "ssm_state":
            # [L(, P7), B, H, P, N]
            spec = [None] * leaf.ndim
            spec[leaf.ndim - 4] = ba if ba else None
            for d in (-3, -2, -1):
                if _div(leaf.shape[d], m):
                    spec[d] = MODEL_AXIS
                    break
            return NamedSharding(mesh, P(*spec))
        if name == "conv_state":
            # [L(, P7), B, W-1, C]
            spec = [None] * leaf.ndim
            spec[leaf.ndim - 3] = ba if ba else None
            if _div(leaf.shape[-1], m):
                spec[-1] = MODEL_AXIS
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_struct)


# ---------------------------------------------------------------------------
# flat parameter plane (the federated [N, P] client buffer)
# ---------------------------------------------------------------------------


def plane_spec(leaf, mesh: Mesh, p: int) -> P:
    """PartitionSpec for one flat-plane carry leaf.

    Any dim equal to the plane width ``p`` shards over ``model`` when
    divisible — rightmost match wins, so ``[N, P]`` shards its COLUMN axis
    and the global ``[P]`` row shards directly; leaves with no P-sized dim
    (labels, keys, scheduler state) and non-divisible planes replicate.
    The client axis N is never sharded here: it belongs to the cohort
    ``shard_map`` axis, which this composes with orthogonally.
    """
    m = _axis_size(mesh, MODEL_AXIS)
    ndim = getattr(leaf, "ndim", 0)
    spec = [None] * ndim
    if m > 1 and p % m == 0:
        for idx in reversed(range(ndim)):
            if leaf.shape[idx] == p:
                spec[idx] = MODEL_AXIS
                break
    return P(*spec)


def plane_shardings(tree, mesh: Mesh, p: int):
    """Tree of NamedShardings for a flat-plane carry (``RoundState``)."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, plane_spec(leaf, mesh, p)), tree)


def plane_mesh(p_shards: int) -> Optional[Mesh]:
    """A 1-axis ``model`` mesh over ``min(p_shards, len(devices))`` devices
    (``None`` when sharding is off). A single-device mesh is valid — the
    shardings degenerate to replication, so the code path is exercisable
    anywhere."""
    if p_shards <= 0:
        return None
    devs = jax.devices()[:max(1, min(p_shards, len(jax.devices())))]
    return Mesh(np.asarray(devs), (MODEL_AXIS,))
