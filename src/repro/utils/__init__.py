from repro.utils.trees import (
    StackFlattenSpec,
    stack_flatten_spec,
    flatten_stacked,
    unflatten_rows,
    unflatten_vector,
    tree_flatten_vector,
    tree_unflatten_vector,
    tree_global_norm,
    tree_add,
    tree_sub,
    tree_scale,
    tree_weighted_mean,
    tree_zeros_like,
    tree_num_params,
    tree_bytes,
    tree_cast,
)
from repro.utils.prng import PRNGSequence
