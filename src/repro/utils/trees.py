"""Pytree utilities used across the framework.

All helpers are pure functions over JAX pytrees so they can be jitted,
vmapped over a client axis (federated aggregation), and differentiated
through where that makes sense.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_flatten_vector(tree, dtype=jnp.float32):
    """Flatten a pytree of arrays into a single 1-D vector.

    Used for weight-divergence (Alg. 4) and K-means features (Alg. 2),
    where a client model must be treated as one Euclidean point.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype=dtype)
    return jnp.concatenate([jnp.ravel(l).astype(dtype) for l in leaves])


def tree_unflatten_vector(tree_def_like, vector):
    """Inverse of :func:`tree_flatten_vector` given a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_def_like)
    out = []
    idx = 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        out.append(jnp.reshape(vector[idx:idx + size], leaf.shape).astype(leaf.dtype))
        idx += size
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_weighted_mean(trees, weights):
    """Weighted average of a list of pytrees — FedAvg aggregation, eq. (4).

    ``w_global = sum_n D_n w_n / sum_n D_n``
    """
    weights = jnp.asarray(weights, dtype=jnp.float32)
    norm = weights / jnp.sum(weights)

    def _avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        out = jnp.tensordot(norm, stacked, axes=1)
        return out.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(_avg, *trees)


def tree_weighted_mean_stacked(stacked_tree, weights):
    """FedAvg aggregation (eq. 4) over a *stacked* client axis.

    ``stacked_tree`` leaves have a leading client axis N; this is the
    mesh-friendly form (the client axis is shardable over ``data``).
    """
    weights = jnp.asarray(weights, dtype=jnp.float32)
    norm = weights / jnp.sum(weights)

    def _avg(leaf):
        w = norm.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0).astype(leaf.dtype)

    return jax.tree_util.tree_map(_avg, stacked_tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_num_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda l: l.astype(dtype) if jnp.issubdtype(l.dtype, jnp.floating) else l,
        tree)
