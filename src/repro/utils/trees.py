"""Pytree utilities used across the framework.

All helpers are pure functions over JAX pytrees so they can be jitted,
vmapped over a client axis (federated aggregation), and differentiated
through where that makes sense.

The flat parameter plane
------------------------
The FL round treats every client model as one Euclidean point: selection
reduces over ‖w_n − w_g‖, K-means over a feature slice, aggregation over a
weighted mean, compression over per-entry magnitudes. ``StackFlattenSpec``
makes that literal: a static (hashable, trace-time) description of how one
model pytree maps into a length-``P`` row, so N client models live in a
single ``[N, P]`` buffer and each phase is one fused row op instead of a
per-leaf ``tree_map`` (see ``repro.core.engine`` and ``docs/PERF.md``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class StackFlattenSpec:
    """Static layout of one model pytree inside a flat length-``P`` row.

    Leaves appear in ``tree_flatten`` order; leaf ``i`` occupies columns
    ``[offsets[i], offsets[i] + sizes[i])``. Hashable, so it can be closed
    over by cached traced programs (it is derived purely from shapes).
    """
    treedef: Any                       # jax PyTreeDef (hashable)
    names: Tuple[str, ...]             # best-effort leaf names (dict keys)
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    total: int                         # P = sum(sizes)

    def columns(self, name: str) -> slice:
        """Column slice of leaf ``name`` — a zero-copy feature view of the
        ``[N, P]`` plane (K-means feature extraction, compressor segments)."""
        i = self.names.index(name)
        return slice(self.offsets[i], self.offsets[i] + self.sizes[i])


def _leaf_name(path) -> str:
    """Full path as a plain string — the bare key for a flat dict (our
    models: ``"w_fc2"``), ``/``-joined components for nested trees
    (``"block1/w"``), so names stay unique per leaf."""
    parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    return "/".join(parts)


def stack_flatten_spec(template) -> StackFlattenSpec:
    """Build the static flatten spec from a template model pytree (real
    arrays or ``ShapeDtypeStruct``s — only shapes/dtypes are read)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    names, shapes, dtypes, offsets, sizes = [], [], [], [], []
    off = 0
    for path, leaf in flat:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        names.append(_leaf_name(path))
        shapes.append(tuple(leaf.shape))
        dtypes.append(jnp.dtype(leaf.dtype).name)
        offsets.append(off)
        sizes.append(size)
        off += size
    if len(set(names)) != len(names):    # columns()/apply_flat key on name
        dup = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate leaf names in flatten spec: {dup}")
    return StackFlattenSpec(treedef=treedef, names=tuple(names),
                            shapes=tuple(shapes), dtypes=tuple(dtypes),
                            offsets=tuple(offsets), sizes=tuple(sizes),
                            total=off)


def flatten_stacked(stacked_tree, dtype=jnp.float32) -> jnp.ndarray:
    """[K, ...]-leaved pytree -> one ``[K, P]`` buffer (row per client).

    Column order matches :func:`stack_flatten_spec` of the per-client
    template: leaves in ``tree_flatten`` order, each reshaped row-major —
    so ``flatten_stacked(t)[:, spec.columns(name)]`` is exactly
    ``t[name].reshape(K, -1)``, bit for bit.
    """
    leaves = jax.tree_util.tree_leaves(stacked_tree)
    if not leaves:
        return jnp.zeros((0, 0), dtype=dtype)
    return jnp.concatenate(
        [l.reshape(l.shape[0], -1).astype(dtype) for l in leaves], axis=1)


def unflatten_rows(spec: StackFlattenSpec, rows: jnp.ndarray):
    """Inverse of :func:`flatten_stacked`: ``[K, P]`` -> stacked pytree."""
    out = []
    for off, size, shape, dt in zip(spec.offsets, spec.sizes, spec.shapes,
                                    spec.dtypes):
        out.append(rows[:, off:off + size]
                   .reshape((rows.shape[0],) + shape).astype(dt))
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def unflatten_rows_np(spec: StackFlattenSpec, rows: np.ndarray):
    """Host-numpy twin of :func:`unflatten_rows` — the paged client store
    unflattens assembled chunks without a device round-trip (views where
    dtypes allow, so a ``[c, P]`` chunk costs no extra copy)."""
    rows = np.asarray(rows)
    out = []
    for off, size, shape, dt in zip(spec.offsets, spec.sizes, spec.shapes,
                                    spec.dtypes):
        out.append(np.asarray(rows[:, off:off + size], dtype=dt)
                   .reshape((rows.shape[0],) + shape))
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def unflatten_vector(spec: StackFlattenSpec, vec: jnp.ndarray):
    """One flat ``[P]`` row -> the model pytree (global params)."""
    out = []
    for off, size, shape, dt in zip(spec.offsets, spec.sizes, spec.shapes,
                                    spec.dtypes):
        out.append(vec[off:off + size].reshape(shape).astype(dt))
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def tree_flatten_vector(tree, dtype=jnp.float32):
    """Flatten a pytree of arrays into a single 1-D vector.

    Used for weight-divergence (Alg. 4) and K-means features (Alg. 2),
    where a client model must be treated as one Euclidean point.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype=dtype)
    return jnp.concatenate([jnp.ravel(l).astype(dtype) for l in leaves])


def tree_unflatten_vector(tree_def_like, vector):
    """Inverse of :func:`tree_flatten_vector` given a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_def_like)
    out = []
    idx = 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        out.append(jnp.reshape(vector[idx:idx + size], leaf.shape).astype(leaf.dtype))
        idx += size
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_weighted_mean(trees, weights):
    """Weighted average of a list of pytrees — FedAvg aggregation, eq. (4).

    ``w_global = sum_n D_n w_n / sum_n D_n``
    """
    weights = jnp.asarray(weights, dtype=jnp.float32)
    norm = weights / jnp.sum(weights)

    def _avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        out = jnp.tensordot(norm, stacked, axes=1)
        return out.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(_avg, *trees)


def tree_weighted_mean_stacked(stacked_tree, weights):
    """FedAvg aggregation (eq. 4) over a *stacked* client axis.

    ``stacked_tree`` leaves have a leading client axis N; this is the
    mesh-friendly form (the client axis is shardable over ``data``).
    """
    weights = jnp.asarray(weights, dtype=jnp.float32)
    norm = weights / jnp.sum(weights)

    def _avg(leaf):
        w = norm.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0).astype(leaf.dtype)

    return jax.tree_util.tree_map(_avg, stacked_tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_num_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda l: l.astype(dtype) if jnp.issubdtype(l.dtype, jnp.floating) else l,
        tree)
