"""Deterministic PRNG key management."""
from __future__ import annotations

import jax


class PRNGSequence:
    """Stateful convenience wrapper that hands out fresh subkeys.

    Host-side only (init code, data generation); jitted code threads keys
    explicitly.
    """

    def __init__(self, seed: int):
        self._key = jax.random.PRNGKey(seed)

    def __next__(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def take(self, n: int):
        self._key, *subs = jax.random.split(self._key, n + 1)
        return subs
