"""granite-moe-3b-a800m [moe] — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,                 # GQA kv=8
    d_ff=512,                       # per-expert hidden
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-smoke", num_layers=2, d_model=128, num_heads=8,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=64))
