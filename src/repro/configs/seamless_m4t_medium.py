"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

The mel-spectrogram + conv feature-extractor frontend is a STUB per the
assignment carve-out: ``input_specs()`` provides pre-computed frame
embeddings ``(B, T_frames, d_model)`` consumed by the text/unit
encoder-decoder backbone described here (12 layers per stack).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,                  # per stack (12 enc + 12 dec)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,                # GQA kv=16 (full MHA)
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    is_encoder_decoder=True,
    cross_attention=True,
    continuous_encoder_input=True,
    source="arXiv:2308.11596",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="seamless-smoke", num_layers=2, d_model=128, num_heads=8,
        num_kv_heads=8, head_dim=16, d_ff=256, vocab_size=512)
