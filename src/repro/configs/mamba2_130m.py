"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,                    # attention-free
    num_kv_heads=0,
    d_ff=0,                         # mamba blocks have no separate MLP
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-smoke", num_layers=2, d_model=128, vocab_size=256,
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk_size=32))
