"""The paper's own local models (Fig. 3 / Table II).

Two 5x5 conv layers (each followed by 2x2 max-pool), then two linear
layers. Channel counts per dataset reproduce Table II's exact
parameter counts:

  MNIST        : conv 15, 28 ; fc1 224 ; fc2 10  -> 113,744 params (448 KB)
  CIFAR-10     : conv 15, 28 ; fc1 300 ; fc2 10  -> 224,978 params (882 KB)
  FashionMNIST : conv 10, 12 ; fc1  80 ; fc2 10  ->  19,522 params ( 79 KB)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CNNConfig:
    name: str
    input_hw: Tuple[int, int]
    input_channels: int
    conv1_out: int
    conv2_out: int
    fc1_out: int
    num_classes: int
    kernel: int = 5
    pool: int = 2

    @property
    def flat_features(self) -> int:
        # 'valid' convs + 2x2 pools, as in the paper's Table II counts.
        h, w = self.input_hw
        h = (h - self.kernel + 1) // self.pool
        w = (w - self.kernel + 1) // self.pool
        h = (h - self.kernel + 1) // self.pool
        w = (w - self.kernel + 1) // self.pool
        return h * w * self.conv2_out


MNIST_CNN = CNNConfig("mnist_cnn", (28, 28), 1, 15, 28, 224, 10)
CIFAR10_CNN = CNNConfig("cifar10_cnn", (32, 32), 3, 15, 28, 300, 10)
FASHION_CNN = CNNConfig("fashion_cnn", (28, 28), 1, 10, 12, 80, 10)
# beyond-paper: a deliberately tiny model (P ≈ 6k) for population-scale
# runs and N-scaling benches, where the paper CNNs' P would make even the
# O(N) bookkeeping swamp the signal being measured
MICRO_CNN = CNNConfig("micro_cnn", (16, 16), 1, 8, 16, 64, 10,
                      kernel=3, pool=2)

CNN_CONFIGS = {
    "mnist": MNIST_CNN,
    "cifar10": CIFAR10_CNN,
    "fashion": FASHION_CNN,
    "micro": MICRO_CNN,
}
