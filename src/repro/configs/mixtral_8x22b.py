"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,                 # GQA kv=8
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    sliding_window=4096,            # SWA native to the mixtral family
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=16384),
    source="arXiv:2401.04088",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mixtral-smoke", num_layers=2, d_model=128, num_heads=8,
        num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=256,
        sliding_window=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=256))
