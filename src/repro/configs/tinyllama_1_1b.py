"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,                 # GQA kv=4
    d_ff=5632,
    vocab_size=32000,
    head_dim=64,
    source="arXiv:2401.02385",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="tinyllama-smoke", num_layers=2, d_model=128, num_heads=8,
        num_kv_heads=2, head_dim=16, d_ff=352, vocab_size=256)
