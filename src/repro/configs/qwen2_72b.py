"""qwen2-72b [dense] — GQA, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,                 # GQA kv=8
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-72b-smoke", num_layers=2, d_model=128, num_heads=8,
        num_kv_heads=2, head_dim=16, d_ff=448, vocab_size=256)
