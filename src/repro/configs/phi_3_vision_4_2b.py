"""phi-3-vision-4.2b [vlm] — phi3-mini + CLIP
[hf:microsoft/Phi-3-vision-128k-instruct].

The CLIP vision tower is a STUB per the assignment carve-out:
``input_specs()`` provides pre-computed patch embeddings of shape
``(B, num_image_tokens, d_model)``; this config describes the language
backbone that consumes them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,                # GQA kv=32 (full MHA)
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    num_image_tokens=576,           # 24x24 CLIP patch grid
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="phi3v-smoke", num_layers=2, d_model=128, num_heads=8,
        num_kv_heads=8, head_dim=16, d_ff=256, vocab_size=256,
        num_image_tokens=16)
