"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,                 # GQA kv=8 (attention layers only)
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    attn_period=8,                  # 1 attention layer per 8 (1:7 interleave)
    moe_period=2,                   # MoE MLP every 2nd layer (jamba e/2)
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
    source="arXiv:2403.19887",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-smoke", num_layers=8, d_model=128, num_heads=8,
        num_kv_heads=2, head_dim=16, d_ff=256, vocab_size=256,
        attn_period=4, moe_period=2,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=256),
        ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk_size=32))
