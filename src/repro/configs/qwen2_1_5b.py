"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,                 # GQA kv=2
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-1.5b-smoke", num_layers=2, d_model=96, num_heads=6,
        num_kv_heads=2, head_dim=16, d_ff=280, vocab_size=256)
