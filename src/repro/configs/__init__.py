"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture is importable lazily so that importing
``repro.configs`` stays cheap and never touches jax device state.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    InputShape,
    INPUT_SHAPES,
    LONG_CONTEXT_WINDOW,
    TrainConfig,
    FLConfig,
)
from repro.configs.paper_cnn import CNN_CONFIGS, CNNConfig

_ARCH_MODULES: Dict[str, str] = {
    "minitron-8b": "repro.configs.minitron_8b",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_ARCH_MODULES[arch_id]).smoke_config()


def get_input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
