"""Config system: model architecture + input-shape + run configs.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact assigned numbers, source cited) and ``smoke_config()``
(a reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden size
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD hyper-parameters (arXiv:2405.21060)."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # 0 for attn-free (ssm)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False          # qwen2-style
    sliding_window: Optional[int] = None   # SWA window; None = full attention
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): one attention layer per `attn_period` layers, rest SSM.
    attn_period: int = 0            # 0 = not hybrid
    moe_period: int = 0             # MoE MLP every `moe_period` layers (0 = per `moe` on all)
    # encoder-decoder (seamless): num_layers applies to each stack.
    is_encoder_decoder: bool = False
    cross_attention: bool = False
    # vlm: number of image-patch embedding tokens prepended by the (stubbed)
    # vision tower.
    num_image_tokens: int = 0
    # audio: encoder consumes pre-extracted frame embeddings (stub frontend).
    continuous_encoder_input: bool = False
    max_seq_len: int = 1 << 20
    source: str = ""                # citation for the assigned config

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter counts (for roofline MODEL_FLOPS = 6·N·D) ----
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _dense_mlp_params(self) -> int:
        return 3 * self.d_model * self.d_ff          # SwiGLU: gate, up, down

    def _moe_mlp_params(self, active_only: bool) -> int:
        m = self.moe
        n_e = m.top_k if active_only else m.num_experts
        return n_e * 3 * self.d_model * m.d_ff + self.d_model * m.num_experts

    def _ssm_params(self) -> int:
        s = self.ssm
        d_inner = s.expand * self.d_model
        n_heads = d_inner // s.head_dim
        in_proj = self.d_model * (2 * d_inner + 2 * s.n_groups * s.d_state + n_heads)
        conv = s.conv_width * (d_inner + 2 * s.n_groups * s.d_state)
        out_proj = d_inner * self.d_model
        extra = 3 * n_heads + d_inner                # A_log, D, dt_bias, norm
        return in_proj + conv + out_proj + extra

    def num_params(self, active_only: bool = False) -> int:
        """Analytic parameter count. ``active_only`` counts top-k experts only
        (for MoE MODEL_FLOPS)."""
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        per_layer_norms = 2 * self.d_model

        def block_params(layer_idx: int, decoder: bool) -> int:
            p = per_layer_norms
            is_attn = True
            if self.attn_period:
                is_attn = (layer_idx % self.attn_period) == (self.attn_period - 1)
            if self.family == "ssm" or (self.attn_period and not is_attn):
                p += self._ssm_params()
            else:
                p += self._attn_params()
            if decoder and self.cross_attention:
                p += self._attn_params() + self.d_model
            use_moe = self.moe is not None and (
                self.moe_period == 0 or (layer_idx % self.moe_period) == (self.moe_period - 1))
            if self.moe is not None and use_moe:
                p += self._moe_mlp_params(active_only)
            elif self.d_ff:
                p += self._dense_mlp_params()
            return p

        total = emb + head + self.d_model            # final norm
        if self.is_encoder_decoder:
            for i in range(self.num_layers):
                total += block_params(i, decoder=False)
                total += block_params(i, decoder=True)
            total += self.d_model                    # encoder final norm
        else:
            for i in range(self.num_layers):
                total += block_params(i, decoder=False)
        return total


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Sliding-window used by the SWA decode variant that makes `long_500k`
# sub-quadratic for dense/moe/vlm families (mixtral uses SWA natively).
LONG_CONTEXT_WINDOW = 4_096


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"        # adamw | sgd | momentum
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    moment_dtype: str = "float32"      # bf16 halves optimizer-state memory
    remat: bool = False
    label_smoothing: float = 0.0


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning run parameters (paper §III, §VI)."""
    num_devices: int = 100          # N
    devices_per_round: int = 10     # S
    local_iters: int = 5            # L
    num_clusters: int = 10          # c
    selected_per_cluster: int = 1   # s
    learning_rate: float = 0.05     # paper §VI
    sigma: float = 0.8              # non-iid bias; "H" handled by partitioner
    target_accuracy: float = 0.0    # 0 = run max_rounds
    max_rounds: int = 100
    selection: str = "divergence"   # divergence | kmeans_random | random | icas
    feature_layer: str = "auto"     # K-means feature; "auto" = last FC (w_fc2)
