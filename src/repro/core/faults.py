"""Fault injection for the FL runtime — the failure modes a wireless
fleet actually exhibits, declared once and injected into every driver
route (scanned/host × dense/paged).

The paper's premise is unreliable links and constrained devices; churn
(PR 6/9) models *absence*, this module models *failure*:

``outage``
    A dispatched client's upload is lost with probability ``outage``
    (i.i.d. Bernoulli per dispatch). The client trained — energy was
    spent, the completion was priced — but the server never receives the
    row: it is masked out of the fold and never persisted to the store.

``chan_outage``
    The CHANNEL-GROUNDED outage mode: instead of an i.i.d. coin, the
    upload fails exactly when the round's small-scale fade is deep.
    The Gauss-Markov carry (``RoundState.channel``) holds the complex
    amplitude h_t with unit-mean power gain ``|h_t|²`` ~ Exp(1), so
    dropping whenever ``|h_t|² < −ln(1 − rate)`` yields the configured
    MARGINAL outage rate while deep fades *cause* the drops — outages
    arrive in bursts with the AR(1) fade coherence, not as white noise.
    Requires a stateful channel (``gauss-markov`` / ``rayleigh-block``).

``corrupt``
    The upload arrives but the payload is garbage (radio bit-errors,
    client-side numerical blow-up): the row is replaced by NaN. The
    server's non-finite guard detects it at the receive/fold boundary,
    zeroes its weight, counts a STRIKE against the client
    (``ClientStats.strikes``), and never lets the row touch the store —
    repeat offenders are quarantined (``quarantine_after``).

``byzantine``
    A FIXED subset of clients (fraction ``byzantine``, drawn once from
    ``seed``) is adversarial: every update they send is the negated,
    amplified update ``g − byz_scale·(w − g)`` — finite, so the
    non-finite guard cannot see it; robust aggregation (``trimmed:f`` /
    ``clipnorm:c``) is the defense.

``deadline``
    Straggler-deadline drops: a priced completion time (eqs. 5+8) above
    ``deadline`` seconds means the server gave up waiting — the update
    is dropped exactly like an outage. Principled via the same delay
    model the async engine fires on (cf. Zhou et al., arXiv 2209.14900).

All rates are per-dispatch probabilities in [0, 1]; ``FaultSpec`` is a
frozen (hashable) dataclass so it keys the traced-program caches, and
the compact CLI spelling ``"outage:0.1,corrupt:0.01"`` round-trips
through ``from_string``/``to_dict``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FaultSpec", "FAULT_KINDS", "byzantine_clients",
           "draw_fault_masks", "chan_outage_threshold"]


#: the fault-kind registry the CLI parser accepts (field name → doc)
FAULT_KINDS: Dict[str, str] = {
    "outage": "P(upload lost) per dispatch, i.i.d.",
    "chan_outage": "marginal P(upload lost) derived from the fade state",
    "corrupt": "P(payload arrives non-finite) per dispatch",
    "byzantine": "fraction of clients sending adversarial updates",
    "byz_scale": "amplification of the byzantine negated update",
    "deadline": "drop updates whose priced completion exceeds this [s]",
    "seed": "PRNG decorrelator for the byzantine subset",
}

_RATE_FIELDS = ("outage", "chan_outage", "corrupt", "byzantine")


@dataclass(frozen=True)
class FaultSpec:
    """Declarative fault model — hashable, JSON-round-trippable."""

    outage: float = 0.0
    chan_outage: float = 0.0
    corrupt: float = 0.0
    byzantine: float = 0.0
    byz_scale: float = 5.0
    deadline: float = 0.0
    seed: int = 0

    def __post_init__(self):
        for name in _RATE_FIELDS:
            v = float(getattr(self, name))
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"fault rate {name!r} must lie in [0, 1]; got {v}")
            object.__setattr__(self, name, v)
        if not (np.isfinite(self.byz_scale) and self.byz_scale >= 0.0):
            raise ValueError(f"byz_scale must be finite and >= 0; got "
                             f"{self.byz_scale}")
        if self.deadline < 0.0:
            raise ValueError(f"deadline must be >= 0 seconds; got "
                             f"{self.deadline}")
        object.__setattr__(self, "byz_scale", float(self.byz_scale))
        object.__setattr__(self, "deadline", float(self.deadline))
        object.__setattr__(self, "seed", int(self.seed))

    @property
    def active(self) -> bool:
        return (self.outage > 0.0 or self.chan_outage > 0.0
                or self.corrupt > 0.0 or self.byzantine > 0.0
                or self.deadline > 0.0)

    # ---- parsing / serialization -------------------------------------
    @classmethod
    def from_string(cls, s: str) -> "FaultSpec":
        """``"outage:0.1,corrupt:0.01"`` → FaultSpec. Unknown kinds are
        rejected naming the registry, mirroring the strategy registries'
        error contract."""
        kw: Dict[str, Any] = {}
        for part in s.split(","):
            part = part.strip()
            if not part:
                continue
            kind, sep, val = part.partition(":")
            kind = kind.strip().replace("-", "_")
            if kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r}; registered kinds: "
                    f"{sorted(FAULT_KINDS)}")
            if not sep:
                raise ValueError(
                    f"fault kind {kind!r} needs a value: '{kind}:RATE'")
            try:
                kw[kind] = int(val) if kind == "seed" else float(val)
            except ValueError:
                raise ValueError(
                    f"fault kind {kind!r}: expected a number, got "
                    f"{val!r}") from None
        return cls(**kw)

    @classmethod
    def normalize(cls, ref: Any) -> Optional["FaultSpec"]:
        """None | FaultSpec | dict | compact string → FaultSpec | None."""
        if ref is None or isinstance(ref, FaultSpec):
            return ref
        if isinstance(ref, str):
            return cls.from_string(ref)
        if isinstance(ref, dict):
            unknown = set(ref) - set(FAULT_KINDS)
            if unknown:
                raise ValueError(
                    f"unknown fault kinds {sorted(unknown)}; registered "
                    f"kinds: {sorted(FAULT_KINDS)}")
            return cls(**ref)
        raise TypeError(f"cannot build a FaultSpec from {type(ref).__name__}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def byzantine_clients(spec: FaultSpec, num_clients: int) -> np.ndarray:
    """The fixed adversarial subset as a host ``[N]`` bool mask —
    a Bernoulli(byzantine) draw from ``spec.seed``, shared verbatim by
    the traced programs (as a constant) and the host loops, so every
    driver route agrees on who the adversaries are."""
    if spec.byzantine <= 0.0:
        return np.zeros(num_clients, bool)
    key = jax.random.PRNGKey(spec.seed)
    return np.asarray(jax.random.bernoulli(key, spec.byzantine,
                                           (num_clients,)))


def draw_fault_masks(key, spec: FaultSpec, shape):
    """The per-dispatch stochastic fault draws: ``(drop, corrupt)`` bool
    masks of ``shape`` (one lane per dispatched client). ONE fixed split
    structure for any active spec, so every engine consumes the PRNG
    stream identically — the dense≡paged async parity holds under faults
    by construction. Channel-coupled and deadline drops are deterministic
    (no key) and OR-ed in by the caller."""
    k_out, k_cor = jax.random.split(key)
    drop = (jax.random.bernoulli(k_out, spec.outage, shape)
            if spec.outage > 0.0 else jnp.zeros(shape, bool))
    corrupt = (jax.random.bernoulli(k_cor, spec.corrupt, shape)
               if spec.corrupt > 0.0 else jnp.zeros(shape, bool))
    return drop, corrupt


def chan_outage_threshold(rate: float) -> float:
    """The fade-power cut giving marginal outage probability ``rate``:
    the Gauss-Markov gain ``|h_t|²`` is unit-mean exponential at every
    lag, so ``P(gain < −ln(1 − rate)) = rate`` exactly."""
    return float(-np.log1p(-min(rate, 1.0 - 1e-12)))
