"""The paper's primary contribution: SAO spectrum allocation (Alg. 5/6),
K-means device clustering (Alg. 2-3), weight-divergence selection (Alg. 4),
the FedAvg loop (Alg. 1), the wireless system model (eqs. 5-11), and the
compared baselines."""
from repro.core.wireless import (Fleet, effective_arrays, sample_fleet,
                                 fleet_arrays, round_totals, rate_mbps)
from repro.core.sao import solve_sao, kkt_residuals, SAOSolution
from repro.core.baselines import (equal_bandwidth, fedl_lambda,
                                  tune_fedl_lambda, AllocResult)
from repro.core.power import optimal_transmit_power
from repro.core.clustering import (kmeans_fit, kmeans_predict, extract_features,
                                   extract_features_flat, clusters_from_labels,
                                   adjusted_rand_index)
from repro.core.divergence import (weight_divergence, weight_divergence_flat,
                                   pairwise_divergence_matrix)
from repro.core import selection
from repro.core.engine import (EngineConfig, RoundEngine, RoundResult,
                               TracedRunResult, model_flat_spec, run_rounds)
from repro.core.fedavg import FLExperiment, FLHistory, make_local_update
from repro.core.cohort import CohortHistory, CohortRunner
