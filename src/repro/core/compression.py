"""Beyond-paper: uplink update compression, coupled into the paper's
spectrum allocator.

The paper treats the uplink payload z_n as a constant (448 KB fp32 CNN).
Compressing client updates shrinks z_n, which enters SAO through
H_n = z_n·p_n and t_com = z_n/r_n — so compression directly buys latency
and energy headroom in problem (19). Schemes:

  int8      : per-leaf symmetric quantization (8 bits + fp32 scale/leaf)
  topk:<f>  : magnitude top-k sparsification, keep fraction f
              (values fp32 + index log2(n) bits each)

Both are simulated faithfully in the FL loop (quantize→dequantize on the
actual update trees) so the ACCURACY cost is measured, not assumed.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_int8(leaf):
    a = leaf.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(a)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(a / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_int8(tree):
    """Quantize→dequantize every floating leaf (simulated uplink)."""
    return jax.tree_util.tree_map(
        lambda l: _leaf_int8(l)
        if jnp.issubdtype(l.dtype, jnp.floating) else l, tree)


def compress_topk(tree, fraction: float):
    """Keep the top-|fraction| entries per leaf by magnitude; zero the rest."""
    def one(l):
        if not jnp.issubdtype(l.dtype, jnp.floating):
            return l
        flat = l.reshape(-1).astype(jnp.float32)
        k = max(int(math.ceil(fraction * flat.shape[0])), 1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
        return kept.reshape(l.shape).astype(l.dtype)
    return jax.tree_util.tree_map(one, tree)


def apply_compression(tree, scheme: str):
    if scheme in (None, "none"):
        return tree
    if scheme == "int8":
        return compress_int8(tree)
    if scheme.startswith("topk:"):
        return compress_topk(tree, float(scheme.split(":")[1]))
    raise ValueError(scheme)


def payload_mbit(num_params: int, scheme: str, num_leaves: int = 8) -> float:
    """Uplink payload for one client update under ``scheme`` (z_n in Mbit)."""
    if scheme in (None, "none"):
        bits = 32.0 * num_params
    elif scheme == "int8":
        bits = 8.0 * num_params + 32.0 * num_leaves
    elif scheme.startswith("topk:"):
        f = float(scheme.split(":")[1])
        k = max(int(math.ceil(f * num_params)), 1)
        idx_bits = max(math.ceil(math.log2(max(num_params, 2))), 1)
        bits = k * (32.0 + idx_bits)
    else:
        raise ValueError(scheme)
    return bits / 1e6
