"""Federated-learning loop — paper Algorithm 1 + the Fig. 2 framework.

Per round k:
  1. device selection        — pluggable ``Selector`` (registry: SELECTORS)
  2. spectrum allocation     — pluggable ``Allocator`` (registry: ALLOCATORS)
  3. local updates (L SGD steps each) — vmapped over the selected clients
  4. weighted aggregation    — pluggable ``Aggregator`` (eq. 4 default)
  5. bookkeeping: accuracy, T_k, E_k (eqs. 10-11), weight divergences

Clustering (Algorithm 2) happens once, after an initial all-device round,
on the K-means features of the paper's chosen layer.

``FLExperiment`` is the thin host driver: it owns experiment state (models,
clusters, rngs) and strategy objects, and delegates all jitted compute to a
``RoundEngine`` shared across experiments with equal hyper-parameters
(``repro.core.engine``). Strategies resolve through the ``repro.api``
registries — construct experiments declaratively with
``repro.api.build_experiment(ExperimentSpec(...))``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.protocols import (Allocation, RoundState, SelectionContext,
                                 TracedContext)
from repro.api.registry import (AGGREGATORS, ALLOCATORS, CHANNELS,
                                COMPRESSORS, SELECTORS)
import repro.api.scenario  # noqa: F401  (populate the channel registry)
import repro.strategies  # noqa: F401  (populate the registries)
from repro.configs.base import FLConfig
from repro.core.clustering import (kmeans_fit, kmeans_fit_minibatch,
                                   extract_features_flat,
                                   clusters_from_labels,
                                   resolve_feature_columns)
from repro.core.divergence import weight_divergence_flat
from repro.core.engine import (EngineConfig, RoundEngine, RoundResult,
                               TracedRunResult, make_local_update, run_rounds)
from repro.core.store import ClientStats, build_store
from repro.core.wireless import Fleet, fleet_arrays
from repro.data.partition import FederatedData
from repro.kernels.chunked import default_chunk_size, streaming_weighted_mean
from repro.utils.trees import (flatten_stacked, tree_flatten_vector,
                               tree_num_params, unflatten_rows,
                               unflatten_rows_np, unflatten_vector)

__all__ = ["FLExperiment", "FLHistory", "RoundResult", "make_local_update"]


@dataclass
class FLHistory:
    accuracy: List[float] = field(default_factory=list)
    T_k: List[float] = field(default_factory=list)
    E_k: List[float] = field(default_factory=list)
    selected: List[np.ndarray] = field(default_factory=list)
    rounds_to_target: Optional[int] = None
    # buffered-asynchronous per-tick traces (empty on synchronous runs):
    # updates folded per fire, their mean age at fold time, active fleet
    participation: List[float] = field(default_factory=list)
    staleness: List[float] = field(default_factory=list)
    active: List[float] = field(default_factory=list)

    @property
    def total_T(self):
        return float(np.sum(self.T_k))

    @property
    def total_E(self):
        return float(np.sum(self.E_k))

    def append(self, res: RoundResult):
        # the host boundary: allocation/eval outputs may still be device
        # scalars (the solves are jitted); coerce HERE, once per round,
        # instead of blocking inside the allocator before training even
        # dispatches — and so the stored history is plain Python floats.
        self.accuracy.append(float(res.accuracy))
        self.T_k.append(float(res.T_k))
        self.E_k.append(float(res.E_k))
        self.selected.append(np.asarray(res.selected))


class FLExperiment:
    """Host-side driver composing a shared ``RoundEngine`` with registered
    selection/allocation/aggregation/compression strategies.

    Strategy arguments accept instances, ``{"name", "params"}`` dicts, or
    compact strings (``"sao"``, ``"fedl:2.0"``, ``"topk:0.05"``) — all
    resolved through the ``repro.api`` registries.
    """

    def __init__(self, model_cfg: Any, fed: FederatedData,
                 test_images: np.ndarray, test_labels: np.ndarray,
                 fleet: Fleet, fl: FLConfig, *, bandwidth_mhz: float = 20.0,
                 allocator: Any = "sao", seed: int = 0,
                 batch_size: int = 32, box_correct: bool = False,
                 compression: Any = "none", fedprox_mu: float = 0.0,
                 server_momentum: float = 0.0, channel: Any = "static",
                 selection: Any = None, aggregator: Any = None,
                 churn: Any = None, store: str = "dense",
                 k_max: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 div_refresh_every: int = 0, cluster: str = "full",
                 p_shards: int = 0):
        self.model_cfg = model_cfg
        self.p_shards = int(p_shards)
        self.fed = fed
        self.fleet = fleet
        self.fl = fl
        self.B = bandwidth_mhz
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.test_images = jnp.asarray(test_images)
        self.test_labels = jnp.asarray(test_labels)

        # -- strategy resolution (names → registered instances) --------
        self.allocator = ALLOCATORS.resolve(allocator)
        if box_correct:
            if getattr(self.allocator, "registry_name", "") != "sao":
                raise ValueError("box_correct=True only applies to the "
                                 "'sao' allocator; set allocator params "
                                 "explicitly instead")
            import dataclasses as _dc
            self.allocator = _dc.replace(self.allocator, box_correct=True)
        self.selector = SELECTORS.resolve(selection if selection is not None
                                          else fl.selection)
        if aggregator is None:
            aggregator = ("fedavgm:%s" % server_momentum
                          if server_momentum > 0 else "fedavg")
        self.aggregator = AGGREGATORS.resolve(aggregator)
        self.aggregator.reset()
        self.compressor = COMPRESSORS.resolve(compression)
        self.channel = CHANNELS.resolve(channel)
        from repro.core.async_engine import parse_churn
        self.churn = parse_churn(churn)
        if (self.churn != (0.0, 0.0) and store != "paged"
                and not getattr(self.aggregator, "async_capable", False)):
            raise ValueError(
                "client churn needs an engine that tracks availability: "
                "either the buffered-asynchronous engine (an async-capable "
                "aggregator, e.g. aggregator='fedbuff:4') or the paged "
                "client store (store='paged'), whose round loop flips the "
                "stats table's availability mask")
        if cluster not in ("full", "minibatch"):
            raise ValueError(
                f"cluster must be 'full' or 'minibatch'; got {cluster!r}")
        self.cluster_mode = cluster

        # -- compiled compute, shared across same-config experiments ---
        self.engine = RoundEngine.shared(EngineConfig(
            model_cfg, fl.learning_rate, fl.local_iters, batch_size,
            fedprox_mu=fedprox_mu))

        self.global_params = self.engine.init_params(self._next_key())
        # the client parameter store: all N client models, either as the
        # dense device-resident [N, P] plane (row layout =
        # engine.flat_spec; updated in place for the selected rows each
        # round via the engine's donated scatter) or as the host-paged
        # active/cold split (repro.core.store) whose only O(N) hot state
        # is the per-client stats table
        gvec = tree_flatten_vector(self.global_params)
        self.chunk_size = int(chunk_size or default_chunk_size(gvec.shape[0]))
        self.k_max = int(k_max or min(fed.num_clients,
                                      max(fl.devices_per_round, 256)))
        self._store = build_store(store, gvec, fed.num_clients, self.engine,
                                  self.chunk_size, stage_rows=self.k_max)
        self._div_refresh_every = int(div_refresh_every)
        self._rounds_since_refresh = np.iinfo(np.int32).max  # force first
        self._gvec_host = (np.asarray(gvec) if store == "paged" else None)
        self.clusters: Optional[List[np.ndarray]] = None
        self.cluster_labels: Optional[np.ndarray] = None

        if getattr(fed, "lazy", False):
            # lazy federated data: per-client SAMPLE INDICES into a shared
            # pool instead of materialized [N, D, H, W, C] images — the
            # per-round gather composes on device (pool + [S, D] indices)
            if store != "paged":
                raise ValueError(
                    "lazy federated data (index-backed partition) requires "
                    "store='paged'; the dense/traced paths consume the "
                    "materialized [N, D, ...] image stack")
            self._pool_images = jnp.asarray(fed.pool_images)
            self._images = None
        else:
            self._pool_images = None
            self._images = jnp.asarray(fed.images)
        self._labels = jnp.asarray(fed.labels)
        self._sizes = jnp.asarray(fed.sizes)
        self._sizes_host = np.asarray(fed.sizes)

        # lossy uplink shrinks the payload -> z_n enters SAO via H_n, t_com
        n_par = tree_num_params(self.global_params)
        n_leaves = len(jax.tree_util.tree_leaves(self.global_params))
        z = self.compressor.payload_mbit(n_par, n_leaves)
        if z is None:
            from repro.models.registry import model_def_for
            if model_def_for(model_cfg).price_uploads:
                # adapter workloads upload the TRAINABLE parameters only:
                # price z from P (= P_adapter fp32 bits), never P_base
                z = n_par * 32 / 1e6
        if z is not None:
            import dataclasses as _dc
            self.fleet = _dc.replace(fleet, z=np.full_like(fleet.z, z))

    # ------------------------------------------------------------------
    @classmethod
    def from_spec(cls, spec) -> "FLExperiment":
        from repro.api.build import build_experiment
        return build_experiment(spec)

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    # ------------------------------------------------------------------
    @property
    def store(self):
        """The client parameter store (``DenseStore`` | ``PagedStore``) —
        the one ``ClientStore`` every driver consumes."""
        return self._store

    @property
    def stats(self) -> ClientStats:
        """The O(N) per-client statistics table — owned by the store, the
        SINGLE source of per-client truth (availability, age, in-flight
        completion, divergence/drift, virtual clock) for the host loops
        and the async scheduler alike."""
        return self._store.stats

    @property
    def client_params(self) -> jnp.ndarray:
        """The dense [N, P] plane (donation-managed by the round loop).

        A paged store keeps no materialized plane — gather the rows you
        need through the store contract instead."""
        if self._store.kind != "dense":
            raise AttributeError(
                "store='paged' keeps no [N, P] client buffer; gather "
                "active rows with exp.store.gather(idx), page the cold "
                "store with iter_client_trees()/iter_client_features(), "
                "or read the O(N) exp.stats table")
        return self._store.buffer

    @client_params.setter
    def client_params(self, value):
        if self._store.kind != "dense":
            raise AttributeError(
                "store='paged' keeps no [N, P] client buffer to assign; "
                "persist trained rows through exp.store.scatter(idx, rows)")
        self._store.buffer = value

    def _client_images(self, idx: np.ndarray) -> jnp.ndarray:
        """The selected clients' sample stacks ``[S, D, H, W, C]`` —
        a row gather for materialized data, a device-side pool gather for
        lazy (index-backed) partitions."""
        if self._pool_images is None:
            return self._images[idx]
        return self._pool_images[jnp.asarray(self.fed.indices[idx])]

    def evaluate(self):
        acc, per_class = self.engine.evaluate(
            self.global_params, self.test_images, self.test_labels)
        return float(acc), np.asarray(per_class)

    # ------------------------------------------------------------------
    def train_clients(self, idx: np.ndarray):
        """Run local updates for ``idx``; returns their new stacked params
        (after simulated lossy uplink compression, if configured)."""
        idx = np.asarray(idx)
        keys = jax.random.split(self._next_key(), len(idx))
        new_params = self.engine.train_clients(
            self.global_params, self._client_images(idx), self._labels[idx],
            keys)
        return self.compressor.apply(new_params, self.global_params)

    def aggregate(self, stacked_params, idx: np.ndarray):
        """Server aggregation over the participating local models (eq. (4)
        weighted mean by default; pluggable via the aggregator registry)."""
        weights = self._sizes[np.asarray(idx)]
        self.global_params = self.aggregator.aggregate(
            self.global_params, stacked_params, weights)

    def store_clients(self, stacked_params, idx: np.ndarray):
        """Write the clients' new models into the client store.

        Accepts flat ``[S, P]`` rows (the fused round step's output) or a
        stacked pytree (flattened here). On the dense store the scatter
        jit donates the old buffer, so the plane updates in place instead
        of double-buffering 45 MB per round — external holders of
        ``client_params`` must copy (see ``client_tree``). On the paged
        store the rows page out to the host cold store."""
        rows = (stacked_params
                if isinstance(stacked_params, jnp.ndarray)
                and stacked_params.ndim == 2
                else flatten_stacked(stacked_params))
        self._store.scatter(np.asarray(idx), rows)

    def client_tree(self, chunk_size: Optional[int] = None):
        """The client store as a stacked pytree (host-numpy leaves
        ``[N, ...]``) — always a COPY for external consumers (the dense
        buffer is donation-managed by the round loop).

        Assembled by paging the store ``chunk_size`` rows at a time, so
        peak memory beyond the (inherently O(N·P)) result is one chunk —
        use :meth:`iter_client_trees` to stream without materializing the
        full result at all."""
        spec = self.engine.flat_spec
        n = self.fed.num_clients
        leaves = [np.empty((n,) + shape, dt)
                  for shape, dt in zip(spec.shapes, spec.dtypes)]
        start = 0
        for block in self._store.iter_chunks(self._chunk(chunk_size)):
            c = block.shape[0]
            for leaf, off, size, shape in zip(leaves, spec.offsets,
                                              spec.sizes, spec.shapes):
                leaf[start:start + c] = (block[:, off:off + size]
                                         .reshape((c,) + shape))
            start += c
        return jax.tree_util.tree_unflatten(spec.treedef, leaves)

    def iter_client_trees(self, chunk_size: Optional[int] = None):
        """Stream the client store as ``(start_row, stacked pytree)``
        blocks of at most ``chunk_size`` clients — O(chunk·P) peak."""
        start = 0
        for block in self._store.iter_chunks(self._chunk(chunk_size)):
            yield start, unflatten_rows_np(self.engine.flat_spec, block)
            start += block.shape[0]

    def _chunk(self, chunk_size: Optional[int]) -> int:
        return int(chunk_size) if chunk_size else self.chunk_size

    def client_features(self, layer: Optional[str] = None,
                        chunk_size: Optional[int] = None) -> jnp.ndarray:
        """K-means feature matrix ``[N, F]`` (Alg. 2's input).

        Dense store: a zero-copy column slice of the plane
        (``layer="all"``'s view IS the buffer, so it is copied here — the
        next round's donated store would delete it out from under the
        caller otherwise). Paged store: assembled chunk-at-a-time from the
        cold store (identical columns via the shared spec resolution), so
        only the [N, F] feature block ever materializes."""
        layer = self.fl.feature_layer if layer is None else layer
        if self._store.kind == "dense":
            feats = extract_features_flat(self.client_params, layer,
                                          self.engine.flat_spec)
            return (jnp.array(feats) if feats is self._store.buffer
                    else feats)
        cols = resolve_feature_columns(self.engine.flat_spec, layer)
        blocks = [block if cols is None else block[:, cols]
                  for block in self._store.iter_chunks(
                      self._chunk(chunk_size))]
        return jnp.asarray(np.concatenate(blocks, axis=0))

    def iter_client_features(self, layer: Optional[str] = None,
                             chunk_size: Optional[int] = None):
        """Stream ``(start_row, [c, F] host feature block)`` pairs —
        the O(chunk·P) iterator variant of :meth:`client_features`."""
        layer = self.fl.feature_layer if layer is None else layer
        cols = resolve_feature_columns(self.engine.flat_spec, layer)
        start = 0
        for block in self._store.iter_chunks(self._chunk(chunk_size)):
            yield start, (np.asarray(block) if cols is None
                          else np.asarray(block[:, cols]))
            start += block.shape[0]

    # ------------------------------------------------------------------
    def initial_round(self):
        """Round 0: all devices train; then K-means clustering (Alg. 2).

        On the paged store a fleet larger than ``k_max`` trains in waves
        of ``k_max`` (the active-plane size), streaming the eq.-(4)
        weighted mean across waves — a single wave (``k_max >= N``) takes
        the dense host path verbatim and stays on the pinned numerics."""
        n = self.fed.num_clients
        idx = np.arange(n)
        if self._store.kind == "dense" or n <= self.k_max:
            new_params = self.train_clients(idx)
            self.store_clients(new_params, idx)
            self.aggregate(new_params, idx)
        else:
            self._initial_round_waves(idx)
        if self.cluster_mode == "minibatch":
            # O(chunk)-memory streaming fit: feature blocks page straight
            # from the store; a single-chunk stream IS the full fit
            chunks = lambda: (blk for _, blk in self.iter_client_features())
            _, labels, _ = kmeans_fit_minibatch(self._next_key(), chunks,
                                                self.fl.num_clusters)
        else:
            feats = self.client_features()
            _, labels, _ = kmeans_fit(self._next_key(), feats,
                                      self.fl.num_clusters)
        self.cluster_labels = np.asarray(labels)
        self.clusters = clusters_from_labels(labels, self.fl.num_clusters)
        if self._store.kind == "paged":
            self._finish_paged_round(idx)

    def _initial_round_waves(self, idx: np.ndarray):
        """All-device training in ``k_max``-sized waves: the device never
        holds more than one active [k_max, P] block; the global update is
        the streaming weighted mean over waves (not bitwise-identical to
        the one-shot eq.-(4) reduction — chunk-boundary summation — which
        is why single-wave stays on the direct path)."""
        spec = self.engine.flat_spec

        def waves():
            for s in range(0, len(idx), self.k_max):
                w_idx = idx[s:s + self.k_max]
                rows = flatten_stacked(self.train_clients(w_idx))
                self._store.scatter(w_idx, rows)
                yield np.asarray(rows), self._sizes_host[w_idx]

        mean = streaming_weighted_mean(waves(), spec.total)
        # feed the pre-aggregated mean through the aggregator as a single
        # unit-weight row, so stateful servers (momentum) see one eq.-(4)
        # mean exactly as they would from the one-shot path
        mean_tree = jax.tree_util.tree_map(
            lambda l: l[None], unflatten_vector(spec, jnp.asarray(mean)))
        self.global_params = self.aggregator.aggregate(
            self.global_params, mean_tree, np.ones(1))

    def divergences(self) -> np.ndarray:
        """Per-client ‖w_n − w_g‖ — the §IV-C selection signal.

        Dense store: one fused reduction over the [N, P] plane. Paged
        store: served from the O(N) stats table — untouched clients all
        equal the broadcast base row, so their (exact) divergence is ONE
        O(P) row op; touched clients carry the value from their last
        refresh, recomputed in streamed O(chunk·P) batches every
        ``div_refresh_every`` rounds (1 = every round = exactly the dense
        signal; 0 = never, staleness bounded by ``stats.drift``)."""
        if self._store.kind == "dense":
            return np.asarray(weight_divergence_flat(
                self.client_params, tree_flatten_vector(self.global_params)))
        return self._paged_divergences()

    def _paged_divergences(self) -> np.ndarray:
        store, stats = self._store, self.stats
        gvec = jnp.asarray(self._gvec_host)
        # every untouched row IS the base row: one [1, P] call through the
        # same fused op keeps their entries bit-identical to a dense sweep
        base_d = np.asarray(self.engine.rows_divergence(
            jnp.asarray(store.base)[None, :], gvec))[0]
        untouched = ~store.touched
        stats.divergence[untouched] = base_d
        stats.drift[untouched] = 0.0
        every = self._div_refresh_every
        # a forced refresh (sentinel) covers mass scatters that bypassed
        # the per-row update — e.g. the initial all-device round — so even
        # the lazy (every=0) policy never serves an uninitialized entry
        forced = self._rounds_since_refresh >= np.iinfo(np.int32).max
        if (store.num_touched
                and (forced or (every > 0
                                and self._rounds_since_refresh >= every))):
            tidx = np.flatnonzero(store.touched)
            for s in range(0, len(tidx), self.chunk_size):
                batch = tidx[s:s + self.chunk_size]
                stats.divergence[batch] = np.asarray(
                    self.engine.rows_divergence(store.gather(batch), gvec))
            stats.drift[store.touched] = 0.0
            self._rounds_since_refresh = 0
        return stats.divergence.copy()

    def selection_context(self) -> SelectionContext:
        return SelectionContext(
            rng=self.rng,
            num_devices=self.fed.num_clients,
            devices_per_round=self.fl.devices_per_round,
            selected_per_cluster=self.fl.selected_per_cluster,
            bandwidth_mhz=self.B,
            fleet=self.fleet,
            clusters=self.clusters,
            divergences=self.divergences)

    def select(self, method: Any = None) -> np.ndarray:
        """Device selection for one round; ``method`` may be a registered
        name, a spec dict, a Selector instance, or None for the default."""
        selector = (self.selector if method is None
                    else SELECTORS.resolve(method))
        return np.asarray(selector.select(self.selection_context()))

    def allocation(self, idx: np.ndarray) -> Allocation:
        """Spectrum allocation for the round (full per-device solution)."""
        arr = fleet_arrays(self.fleet.select(np.asarray(idx)))
        return self.allocator.allocate(arr, self.B)

    def allocate(self, idx: np.ndarray):
        """Back-compat: returns just ``(T_k, E_k)``."""
        a = self.allocation(idx)
        return a.T, a.E

    # ------------------------------------------------------------------
    def round(self, method: Any = None) -> RoundResult:
        """One full FL round: select → allocate → train → aggregate → eval.

        Uses the engine's fused jitted step when the aggregator is the
        plain eq. (4) mean and no lossy compression is configured. On the
        paged store the selection is additionally filtered by the stats
        table's availability mask (round-level churn), and the round's
        trained rows refresh the table's divergence/age entries — O(K·P)
        bookkeeping; the O(N·P) plane is never touched.
        """
        idx = self.select(method)
        paged = self._store.kind == "paged"
        if paged:
            idx = np.asarray(idx)
            idx = idx[self.stats.avail[idx]]
            if idx.size == 0:           # everyone churned out: explicit
                acc, per_class = self.evaluate()        # no-op round
                return RoundResult(
                    selected=idx, T_k=0.0, E_k=0.0, accuracy=acc,
                    per_class=per_class,
                    params=jax.tree_util.tree_map(jnp.copy,
                                                  self.global_params))
        alloc = self.allocation(idx)
        fused = (getattr(self.aggregator, "fuses_with_engine", False)
                 and getattr(self.compressor, "identity", False))
        if fused:
            keys = jax.random.split(self._next_key(), len(idx))
            # round_step donates the global params (the new global reuses
            # their buffers) and returns the clients as flat [S, P] rows
            rows, new_global, acc, per_class = self.engine.round_step(
                self.global_params, self._client_images(idx),
                self._labels[idx], keys, self._sizes[idx], self.test_images,
                self.test_labels)
            self.store_clients(rows, idx)
            self.global_params = new_global
            acc, per_class = float(acc), np.asarray(per_class)
        else:
            stacked = self.train_clients(idx)
            rows = flatten_stacked(stacked)
            self.store_clients(rows, idx)
            self.aggregate(stacked, idx)
            acc, per_class = self.evaluate()
        if paged:
            self._finish_paged_round(idx, rows)
        # params is COPIED: the next fused round donates self.global_params,
        # which would silently invalidate an earlier RoundResult's tree
        return RoundResult(selected=np.asarray(idx), T_k=alloc.T, E_k=alloc.E,
                           accuracy=acc, per_class=per_class,
                           params=jax.tree_util.tree_map(jnp.copy,
                                                         self.global_params),
                           stacked_params=rows)

    def _finish_paged_round(self, idx: np.ndarray, rows=None):
        """Post-round upkeep of the O(N) stats table (paged store only):
        drift bounds grow by ‖g_new − g_old‖ for stale entries, the
        round's trained rows get exact divergences (one O(K·P) row op on
        data already in hand), ages advance."""
        gvec_new = tree_flatten_vector(self.global_params)
        gvec_new_host = np.asarray(gvec_new)
        st = self.stats
        delta = float(np.linalg.norm(gvec_new_host - self._gvec_host))
        st.drift[self._store.touched] += delta
        if rows is not None:
            st.divergence[idx] = np.asarray(
                self.engine.rows_divergence(rows, gvec_new))
            st.drift[idx] = 0.0
        st.age[:] += 1
        st.age[idx] = 0
        self._gvec_host = gvec_new_host
        if rows is None:
            # mass scatter without per-row updates (initial round): force
            # the next divergences() call to refresh the touched rows
            self._rounds_since_refresh = np.iinfo(np.int32).max
        else:
            self._rounds_since_refresh = min(
                self._rounds_since_refresh + 1,
                np.iinfo(np.int32).max - 1)

    def _churn_step_host(self):
        """Round-level Bernoulli churn on the stats table's availability
        mask — a departed client's cold row stays paged out untouched and
        is picked up again verbatim on rejoin."""
        p_leave, p_join = self.churn
        n = self.fed.num_clients
        leave = self.rng.random(n) < p_leave
        join = self.rng.random(n) < p_join
        avail = self.stats.avail
        avail[:] = np.where(avail, ~leave, join)

    def run(self, method: Any = None, rounds: Optional[int] = None,
            target_accuracy: Optional[float] = None,
            include_initial_round: bool = True) -> FLHistory:
        """Run the experiment; identical results from two execution paths.

        When every configured strategy advertises ``traceable=True``, the
        selection policy is deterministic (bit-parity with the host loop —
        stochastic selectors draw from ``jax.random`` when traced, which
        would silently change this reproduction's numbers for the same
        seed), and no early-stop target is set, the whole experiment runs
        as ONE compiled ``lax.scan`` program on device
        (``engine.run_rounds``) and the history comes back in a single
        transfer. Otherwise the legacy round-at-a-time Python loop below
        drives the same math. Stochastic selectors run device-resident
        through the explicit ``CohortRunner`` path, which documents the
        ``jax.random`` draw.
        """
        rounds = rounds or self.fl.max_rounds
        target = (self.fl.target_accuracy
                  if target_accuracy is None else target_accuracy)
        if (getattr(self.channel, "dynamic", False)
                and self.fleet.num_cells > 1):
            raise ValueError(
                f"channel {self.channel.registry_name!r} computes per-round "
                "interference from the OTHER cells' selections; a single-"
                "cell FLExperiment cannot see them — run the multi-cell "
                "spec through CohortRunner (build_cohort / fl_sim --cells)")
        selector = (self.selector if method is None
                    else SELECTORS.resolve(method))
        if self._store.kind == "paged":
            # population-scale path: host loop over the paged store; the
            # scanned program's [N, P] carry is exactly what this mode
            # exists to avoid
            if (getattr(self.channel, "needs_rng", False)
                    or getattr(self.channel, "stateful", False)):
                raise ValueError(
                    f"channel {self.channel.registry_name!r} redraws fading "
                    "inside the scanned program; store='paged' drives the "
                    "host loop — use the static channel (or store='dense')")
            if getattr(self.aggregator, "async_capable", False):
                # buffered-asynchronous ticks over the paged store: the
                # jitted tick pieces carry only the [P] global + O(N)
                # stats columns; rows move O(k_max·P) through the store's
                # staging API between them
                if not self.traceable(selector):
                    raise ValueError(
                        "the buffered-asynchronous engine needs a fully "
                        "traceable strategy bundle (selector/allocator/"
                        "compressor/channel)")
                return self._run_async_paged(selector, rounds, target,
                                             include_initial_round)
            return self._run_paged(selector, method, rounds, target,
                                   include_initial_round)
        if getattr(self.aggregator, "async_capable", False):
            # the buffered-asynchronous engine exists ONLY as a scanned
            # program — there is no host-loop equivalent to fall back to
            if target:
                raise ValueError(
                    "the buffered-asynchronous engine runs as one scanned "
                    "program and cannot early-stop on target_accuracy")
            if not self.traceable(selector):
                raise ValueError(
                    "the buffered-asynchronous engine needs a fully "
                    "traceable strategy bundle (selector/allocator/"
                    "compressor/channel)")
            return self._run_traced(selector, rounds, include_initial_round)
        bit_parity = not getattr(selector, "needs_rng", True)
        if not target and bit_parity and self.traceable(selector):
            return self._run_traced(selector, rounds, include_initial_round)
        if getattr(self.channel, "needs_rng", False):
            raise ValueError(
                f"channel {self.channel.registry_name!r} redraws fading "
                "inside the scanned program and has no host-loop "
                "equivalent; run it with a traceable strategy bundle and "
                "no target_accuracy (or through CohortRunner)")
        hist = FLHistory()
        if include_initial_round or self.clusters is None:
            self.initial_round()
            acc, _ = self.evaluate()
            all_idx = np.arange(self.fed.num_clients)
            T0, E0 = self.allocate(all_idx)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T0))
            hist.E_k.append(float(E0))
            hist.selected.append(all_idx)
        for k in range(rounds):
            res = self.round(method)
            hist.append(res)
            if target and res.accuracy >= target and hist.rounds_to_target is None:
                hist.rounds_to_target = k + 1
                break
        return hist

    def _run_paged(self, selector, method, rounds: int,
                   target: float, include_initial_round: bool) -> FLHistory:
        """The population-scale host loop over the paged store.

        Differences from the dense host loop, both deliberate:
        the Alg.-2 initial round (which trains ALL N devices) runs only
        when requested or when the selector actually needs clusters — a
        million-client fleet with a cluster-free policy (random / icas /
        rra / stochastic-sched) skips it entirely; and round-level churn
        flips the stats table's availability mask between rounds, with
        selection filtered against it. With ``include_initial_round=True``
        and ``div_refresh_every=1`` the loop is bit-identical to the dense
        host loop (pinned in ``tests/test_paged_store.py``)."""
        hist = FLHistory()
        if include_initial_round or (self.clusters is None and
                                     getattr(selector, "needs_clusters",
                                             False)):
            self.initial_round()
            acc, _ = self.evaluate()
            all_idx = np.arange(self.fed.num_clients)
            T0, E0 = self.allocate(all_idx)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T0))
            hist.E_k.append(float(E0))
            hist.selected.append(all_idx)
        churn_on = self.churn != (0.0, 0.0)
        for k in range(rounds):
            if churn_on:
                self._churn_step_host()
            res = self.round(method)
            hist.append(res)
            if (target and res.accuracy >= target
                    and hist.rounds_to_target is None):
                hist.rounds_to_target = k + 1
                break
        return hist

    def _run_async_paged(self, selector, rounds: int, target: float,
                         include_initial_round: bool) -> FLHistory:
        """Buffered-asynchronous ticks over the paged store — the host
        composition of ``async_engine._paged_async_step_program``'s jitted
        pieces, with store paging in between.

        Per tick: (host) refresh the stats table's divergence column per
        the ``div_refresh_every`` cadence (1 = every tick = exactly the
        dense select signal; 0 = never, staleness bounded by
        ``stats.drift``) and push it into the carry → ``sched`` (churn →
        select → in-flight filter) → (host) page the cohort's data in →
        ``plan`` (allocate → completion pricing → fire plan) → ``train``
        (O(K·P)) → (host) ``store.stage`` the trained rows and gather the
        M candidate rows back → ``fire`` (O(M·P) fold + eval) → (host)
        release fired staging, fold ‖g_new − g_old‖ into the drift
        bounds. Device memory is O(k_max·P + M·P) at any N; the math, op
        order and PRNG stream are the dense tick's, pinned bit-identical
        in ``tests/test_async_paged.py``.

        Unlike the dense scanned engine this is a host loop, so
        ``target_accuracy`` early stopping IS supported here."""
        from repro.core.async_engine import _paged_async_step_program
        prog = _paged_async_step_program(
            self.engine.cfg, selector, self.allocator,
            self.aggregator.registry_name,
            tuple(sorted(self.aggregator.params().items())),
            self.compressor, self.traced_context(), self.fl.feature_layer,
            self.channel, self.churn)
        hist = FLHistory()
        if include_initial_round or (self.clusters is None and
                                     getattr(selector, "needs_clusters",
                                             False)):
            self.initial_round()
            acc, _ = self.evaluate()
            all_idx = np.arange(self.fed.num_clients)
            T0, E0 = self.allocate(all_idx)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T0))
            hist.E_k.append(float(E0))
            hist.selected.append(all_idx)
        arr = dict(fleet_arrays(self.fleet))
        arr.pop("xgain", None)           # single-cell: no cross gains
        store, stats = self._store, self.stats
        n = self.fed.num_clients
        needs_div = getattr(selector, "needs_divergence", False)
        state = self.traced_state()
        state = prog.init_channel(state, arr)
        for k in range(rounds):
            if needs_div:
                # serve selection from the refreshed stats table — the
                # paged replacement for the dense full-plane reduction
                div = self._paged_divergences()
                state = state._replace(sched=state.sched._replace(
                    divergence=jnp.asarray(div)))
            state, arr_f, idx, mask = prog.sched(state, arr)
            idx_h = np.asarray(idx)
            mask_h = np.asarray(mask)
            # the host-side mirror of the device gather's clamped OOB
            # sentinel: padding lanes read client N-1's data, train, and
            # are dropped by the mask — identical PRNG consumption
            idx_c = np.minimum(idx_h, n - 1)
            images_sel = self._client_images(idx_c)
            labels_sel = self._labels[jnp.asarray(idx_c)]
            state, T, E, cand, fired_cand, w_cand, traces = prog.plan(
                state, arr_f, idx, mask, self._sizes)
            state, rows = prog.train(state, images_sel, labels_sel)
            live = idx_h[mask_h]
            if live.size:
                store.stage(live, rows[jnp.asarray(np.flatnonzero(mask_h))])
            cand_h = np.asarray(cand)
            cand_rows = store.gather_staged(cand_h)
            state, acc, div_cand, g_delta = prog.fire(
                state, cand_rows, w_cand, fired_cand,
                self.test_images, self.test_labels)
            fired_h = np.asarray(fired_cand)
            fired_ids = cand_h[fired_h]
            store.release_staged(fired_ids)
            # stats-table upkeep, the per-tick version of the sync loop's
            # _finish_paged_round: every stale bound grows by this fold's
            # global step (exactly 0 on an empty fire); fired clients get
            # their exact refreshed divergence back from the fold
            stats.drift[store.touched] += float(g_delta)
            if fired_ids.size:
                stats.divergence[fired_ids] = np.asarray(div_cand)[fired_h]
                stats.drift[fired_ids] = 0.0
            self._gvec_host = np.asarray(state.params)
            self._rounds_since_refresh = min(
                self._rounds_since_refresh + 1, np.iinfo(np.int32).max - 1)
            part, stale, active = traces
            acc = float(acc)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T))
            hist.E_k.append(float(E))
            hist.selected.append(live)
            hist.participation.append(float(part))
            hist.staleness.append(float(stale))
            hist.active.append(float(active))
            if (target and acc >= target
                    and hist.rounds_to_target is None):
                hist.rounds_to_target = k + 1
                break
        # fold the carry back into the host source of truth: params/key/
        # opt state, plus the scheduler columns. divergence/drift stay
        # host-maintained (the table already holds the refreshed values).
        spec = self.engine.flat_spec
        self.global_params = unflatten_vector(spec, state.params)
        self.key = state.key
        self.aggregator.load_flat_state(state.opt_state, spec)
        sched = state.sched
        for col in ("age", "t_done", "avail", "t_now"):
            np.copyto(getattr(stats, col), np.asarray(getattr(sched, col)))
        return hist

    # ------------------------------------------------------------------
    # device-resident path: the whole experiment as one lax.scan program
    # ------------------------------------------------------------------
    def traceable(self, selector: Any = None) -> bool:
        """True when the configured strategy bundle supports the scanned
        device-resident pipeline. The pipeline drives the FLAT-plane
        contract, so aggregators/compressors must implement it on top of
        ``traceable=True`` — a strategy written against the pre-flat
        stacked contract falls back to the host loop instead of failing
        mid-trace."""
        selector = self.selector if selector is None else selector
        return (all(getattr(s, "traceable", False)
                    for s in (selector, self.allocator, self.aggregator,
                              self.compressor, self.channel))
                and all(hasattr(self.aggregator, m)
                        for m in ("aggregate_flat", "init_flat_state",
                                  "load_flat_state"))
                and hasattr(self.compressor, "apply_flat"))

    def traced_context(self) -> TracedContext:
        return TracedContext(num_devices=self.fed.num_clients,
                             devices_per_round=self.fl.devices_per_round,
                             selected_per_cluster=self.fl.selected_per_cluster,
                             num_clusters=self.fl.num_clusters,
                             bandwidth_mhz=self.B)

    def traced_state(self) -> RoundState:
        """Snapshot the experiment's mutable state as the scan carry —
        weights on the flat parameter plane (global as one [P] row, the
        client buffer as-is). The scanned program DONATES this state, so
        every leaf handed over here is consumed; ``load_traced_state``
        rebinds the driver's references from the result."""
        labels = (jnp.zeros((self.fed.num_clients,), jnp.int32)
                  if self.cluster_labels is None
                  else jnp.asarray(self.cluster_labels, jnp.int32))
        gvec = tree_flatten_vector(self.global_params)
        # the stats plane: async-capable programs carry the store's stats
        # table (device copy) in the sched slot — incremental run() calls
        # continue the virtual clock because load_traced_state folds it
        # back. Synchronous programs carry None. A paged store has no
        # [N, P] buffer; its programs run plane="stats" and never read
        # client_params, so a zero-row placeholder rides the slot.
        sched = (self.stats.device()
                 if getattr(self.aggregator, "async_capable", False)
                 else None)
        client_plane = (self._store.buffer
                        if self._store.kind == "dense"
                        else jnp.zeros((0,), jnp.float32))
        return RoundState(
            params=gvec, client_params=client_plane,
            opt_state=self.aggregator.init_flat_state(gvec),
            key=self.key, labels=labels, sched=sched)

    def load_traced_state(self, state: RoundState, *,
                          clusters_valid: bool = True):
        """Sync a (final) scan carry back into the host driver, so a traced
        run can be inspected or continued by the Python loop."""
        spec = self.engine.flat_spec
        self.global_params = unflatten_vector(spec, state.params)
        if self._store.kind == "dense":
            self.client_params = state.client_params
        self.key = state.key
        sched = getattr(state, "sched", None)
        if sched is not None:
            # fold the scheduler carry back into the store's stats table
            # (the single source of per-client truth)
            self.stats.load(sched)
        self.aggregator.load_flat_state(state.opt_state, spec)
        if clusters_valid:
            self.cluster_labels = np.asarray(state.labels)
            self.clusters = clusters_from_labels(self.cluster_labels,
                                                 self.fl.num_clusters)

    def _run_traced(self, selector, rounds: int,
                    include_initial_round: bool) -> FLHistory:
        with_init = include_initial_round or self.clusters is None
        fn = run_rounds(self.engine.cfg, selector=selector,
                        allocator=self.allocator, aggregator=self.aggregator,
                        compressor=self.compressor,
                        tctx=self.traced_context(),
                        feature_layer=self.fl.feature_layer,
                        rounds=rounds, with_init=with_init,
                        channel=self.channel, churn=self.churn)
        state = self.traced_state()
        if self.p_shards:
            # P-axis GSPMD: lay the carry's P-sized dims out over a `model`
            # mesh before dispatch — the scanned program's donated carry
            # keeps the layout for the whole run. Composes with the cohort
            # shard_map (which owns the lane axis, never P).
            from repro.sharding.specs import plane_mesh, plane_shardings
            mesh = plane_mesh(self.p_shards)
            if mesh is not None:
                state = jax.device_put(
                    state, plane_shardings(state, mesh,
                                           int(state.params.shape[0])))
        res = fn(state, self._images, self._labels,
                 self._sizes, fleet_arrays(self.fleet), self.test_images,
                 self.test_labels)
        self.load_traced_state(res.state,
                               clusters_valid=with_init
                               or self.cluster_labels is not None)
        return self.history_from_traced(res, with_init,
                                        self.fed.num_clients)

    @staticmethod
    def history_from_traced(res: TracedRunResult, with_init: bool,
                            num_devices: int) -> FLHistory:
        """One device→host transfer of a scanned run's stacked history."""
        hist = FLHistory()
        accs, Ts, Es, sel, msk = (np.asarray(x) for x in (
            res.rounds.accuracy, res.rounds.T, res.rounds.E,
            res.rounds.selected, res.rounds.mask))
        if with_init:
            hist.accuracy.append(float(res.init_accuracy))
            hist.T_k.append(float(res.init_T))
            hist.E_k.append(float(res.init_E))
            hist.selected.append(np.arange(num_devices))
        hist.accuracy.extend(float(a) for a in accs)
        hist.T_k.extend(float(t) for t in Ts)
        hist.E_k.extend(float(e) for e in Es)
        hist.selected.extend(sel[k][msk[k]] for k in range(sel.shape[0]))
        if res.rounds.participation is not None:
            hist.participation.extend(
                float(x) for x in np.asarray(res.rounds.participation))
            hist.staleness.extend(
                float(x) for x in np.asarray(res.rounds.staleness))
            hist.active.extend(
                float(x) for x in np.asarray(res.rounds.active))
        return hist
