"""Federated-learning loop — paper Algorithm 1 + the Fig. 2 framework.

Per round k:
  1. device selection        — pluggable ``Selector`` (registry: SELECTORS)
  2. spectrum allocation     — pluggable ``Allocator`` (registry: ALLOCATORS)
  3. local updates (L SGD steps each) — vmapped over the selected clients
  4. weighted aggregation    — pluggable ``Aggregator`` (eq. 4 default)
  5. bookkeeping: accuracy, T_k, E_k (eqs. 10-11), weight divergences

Clustering (Algorithm 2) happens once, after an initial all-device round,
on the K-means features of the paper's chosen layer.

``FLExperiment`` is the thin host driver: it owns experiment state (models,
clusters, rngs) and strategy objects, and delegates all jitted compute to a
``RoundEngine`` shared across experiments with equal hyper-parameters
(``repro.core.engine``). Strategies resolve through the ``repro.api``
registries — construct experiments declaratively with
``repro.api.build_experiment(ExperimentSpec(...))``.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.protocols import (Allocation, RoundState, SelectionContext,
                                 TracedContext)
from repro.api.registry import (AGGREGATORS, ALLOCATORS, CHANNELS,
                                COMPRESSORS, SELECTORS)
import repro.api.scenario  # noqa: F401  (populate the channel registry)
import repro.strategies  # noqa: F401  (populate the registries)
from repro.configs.base import FLConfig
from repro.core.clustering import (kmeans_fit, kmeans_fit_minibatch,
                                   extract_features_flat,
                                   clusters_from_labels,
                                   resolve_feature_columns)
from repro.core.divergence import weight_divergence_flat
from repro.core.engine import (EngineConfig, RoundEngine, RoundResult,
                               TracedRunResult, make_local_update, run_rounds)
from repro.core.faults import FaultSpec, byzantine_clients, draw_fault_masks
from repro.core.store import ClientStats, build_store
from repro.core.wireless import Fleet, completion_times, fleet_arrays
from repro.data.partition import FederatedData
from repro.kernels.chunked import default_chunk_size, streaming_weighted_mean
from repro.utils.trees import (flatten_stacked, tree_flatten_vector,
                               tree_num_params, unflatten_rows,
                               unflatten_rows_np, unflatten_vector)

__all__ = ["FLExperiment", "FLHistory", "RoundResult", "make_local_update"]


@dataclass
class FLHistory:
    accuracy: List[float] = field(default_factory=list)
    T_k: List[float] = field(default_factory=list)
    E_k: List[float] = field(default_factory=list)
    selected: List[np.ndarray] = field(default_factory=list)
    rounds_to_target: Optional[int] = None
    # buffered-asynchronous per-tick traces (empty on synchronous runs):
    # updates folded per fire, their mean age at fold time, active fleet
    participation: List[float] = field(default_factory=list)
    staleness: List[float] = field(default_factory=list)
    active: List[float] = field(default_factory=list)

    @property
    def total_T(self):
        return float(np.sum(self.T_k))

    @property
    def total_E(self):
        return float(np.sum(self.E_k))

    def append(self, res: RoundResult):
        # the host boundary: allocation/eval outputs may still be device
        # scalars (the solves are jitted); coerce HERE, once per round,
        # instead of blocking inside the allocator before training even
        # dispatches — and so the stored history is plain Python floats.
        self.accuracy.append(float(res.accuracy))
        self.T_k.append(float(res.T_k))
        self.E_k.append(float(res.E_k))
        self.selected.append(np.asarray(res.selected))

    def extend(self, other: "FLHistory") -> "FLHistory":
        """Concatenate ``other``'s rounds onto this history (checkpoint
        resume: the restored prefix continues with the new run's rounds)."""
        for name in ("accuracy", "T_k", "E_k", "selected",
                     "participation", "staleness", "active"):
            getattr(self, name).extend(getattr(other, name))
        if self.rounds_to_target is None:
            self.rounds_to_target = other.rounds_to_target
        return self

    def to_dict(self) -> dict:
        """JSON-serializable form (checkpoint manifests)."""
        return {
            "accuracy": [float(x) for x in self.accuracy],
            "T_k": [float(x) for x in self.T_k],
            "E_k": [float(x) for x in self.E_k],
            "selected": [np.asarray(s).tolist() for s in self.selected],
            "rounds_to_target": self.rounds_to_target,
            "participation": [float(x) for x in self.participation],
            "staleness": [float(x) for x in self.staleness],
            "active": [float(x) for x in self.active],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FLHistory":
        return cls(
            accuracy=list(d["accuracy"]), T_k=list(d["T_k"]),
            E_k=list(d["E_k"]),
            selected=[np.asarray(s, np.int64) for s in d["selected"]],
            rounds_to_target=d.get("rounds_to_target"),
            participation=list(d.get("participation", [])),
            staleness=list(d.get("staleness", [])),
            active=list(d.get("active", [])))


class _Checkpointer:
    """Bundles the ``run()``-level checkpoint knobs for the host loops:
    fires every ``every`` completed rounds, counting from ``offset`` so a
    resumed run continues the original round numbering."""

    def __init__(self, exp: "FLExperiment", directory: str, every: int,
                 offset: int, spec_dict: Optional[dict]):
        if every <= 0:
            raise ValueError(f"checkpoint_every must be > 0; got {every}")
        self.exp = exp
        self.directory = directory
        self.every = every
        self.offset = offset
        self.spec_dict = spec_dict

    def due(self, k: int) -> bool:
        return (self.offset + k + 1) % self.every == 0

    def save(self, k: int, hist: FLHistory) -> str:
        return self.exp.save_checkpoint(
            self.directory, self.offset + k + 1, history=hist,
            spec_dict=self.spec_dict)

    def maybe(self, k: int, hist: FLHistory) -> None:
        if self.due(k):
            self.save(k, hist)


class FLExperiment:
    """Host-side driver composing a shared ``RoundEngine`` with registered
    selection/allocation/aggregation/compression strategies.

    Strategy arguments accept instances, ``{"name", "params"}`` dicts, or
    compact strings (``"sao"``, ``"fedl:2.0"``, ``"topk:0.05"``) — all
    resolved through the ``repro.api`` registries.
    """

    def __init__(self, model_cfg: Any, fed: FederatedData,
                 test_images: np.ndarray, test_labels: np.ndarray,
                 fleet: Fleet, fl: FLConfig, *, bandwidth_mhz: float = 20.0,
                 allocator: Any = "sao", seed: int = 0,
                 batch_size: int = 32, box_correct: bool = False,
                 compression: Any = "none", fedprox_mu: float = 0.0,
                 server_momentum: float = 0.0, channel: Any = "static",
                 selection: Any = None, aggregator: Any = None,
                 churn: Any = None, store: str = "dense",
                 k_max: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 div_refresh_every: int = 0, cluster: str = "full",
                 p_shards: int = 0, faults: Any = None,
                 quarantine_after: int = 0):
        self.model_cfg = model_cfg
        self.p_shards = int(p_shards)
        self.fed = fed
        self.fleet = fleet
        self.fl = fl
        self.B = bandwidth_mhz
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.test_images = jnp.asarray(test_images)
        self.test_labels = jnp.asarray(test_labels)

        # -- strategy resolution (names → registered instances) --------
        self.allocator = ALLOCATORS.resolve(allocator)
        if box_correct:
            if getattr(self.allocator, "registry_name", "") != "sao":
                raise ValueError("box_correct=True only applies to the "
                                 "'sao' allocator; set allocator params "
                                 "explicitly instead")
            import dataclasses as _dc
            self.allocator = _dc.replace(self.allocator, box_correct=True)
        self.selector = SELECTORS.resolve(selection if selection is not None
                                          else fl.selection)
        if aggregator is None:
            aggregator = ("fedavgm:%s" % server_momentum
                          if server_momentum > 0 else "fedavg")
        self.aggregator = AGGREGATORS.resolve(aggregator)
        self.aggregator.reset()
        self.compressor = COMPRESSORS.resolve(compression)
        self.channel = CHANNELS.resolve(channel)
        from repro.core.async_engine import parse_churn
        self.churn = parse_churn(churn)
        if (self.churn != (0.0, 0.0) and store != "paged"
                and not getattr(self.aggregator, "async_capable", False)):
            raise ValueError(
                "client churn needs an engine that tracks availability: "
                "either the buffered-asynchronous engine (an async-capable "
                "aggregator, e.g. aggregator='fedbuff:4') or the paged "
                "client store (store='paged'), whose round loop flips the "
                "stats table's availability mask")
        if cluster not in ("full", "minibatch"):
            raise ValueError(
                f"cluster must be 'full' or 'minibatch'; got {cluster!r}")
        self.cluster_mode = cluster

        # -- fault injection / quarantine (repro.core.faults) -----------
        self.faults = FaultSpec.normalize(faults)
        self.quarantine_after = int(quarantine_after)
        if self.quarantine_after < 0:
            raise ValueError("quarantine_after must be >= 0; got "
                             f"{quarantine_after}")
        if (self.faults is not None and self.faults.chan_outage > 0.0
                and not getattr(self.channel, "stateful", False)):
            raise ValueError(
                "faults: chan_outage derives upload failures from the "
                "Gauss-Markov fade state and needs a stateful channel "
                "(e.g. channel='gauss-markov'); got "
                f"{self.channel.registry_name!r}")
        self._byz_mask = (byzantine_clients(self.faults, fed.num_clients)
                          if self.faults is not None
                          and self.faults.byzantine > 0.0 else None)

        # -- compiled compute, shared across same-config experiments ---
        self.engine = RoundEngine.shared(EngineConfig(
            model_cfg, fl.learning_rate, fl.local_iters, batch_size,
            fedprox_mu=fedprox_mu))

        self.global_params = self.engine.init_params(self._next_key())
        # the client parameter store: all N client models, either as the
        # dense device-resident [N, P] plane (row layout =
        # engine.flat_spec; updated in place for the selected rows each
        # round via the engine's donated scatter) or as the host-paged
        # active/cold split (repro.core.store) whose only O(N) hot state
        # is the per-client stats table
        gvec = tree_flatten_vector(self.global_params)
        self.chunk_size = int(chunk_size or default_chunk_size(gvec.shape[0]))
        self.k_max = int(k_max or min(fed.num_clients,
                                      max(fl.devices_per_round, 256)))
        self._store = build_store(store, gvec, fed.num_clients, self.engine,
                                  self.chunk_size, stage_rows=self.k_max)
        self._div_refresh_every = int(div_refresh_every)
        self._rounds_since_refresh = np.iinfo(np.int32).max  # force first
        self._gvec_host = (np.asarray(gvec) if store == "paged" else None)
        self.clusters: Optional[List[np.ndarray]] = None
        self.cluster_labels: Optional[np.ndarray] = None

        if getattr(fed, "lazy", False):
            # lazy federated data: per-client SAMPLE INDICES into a shared
            # pool instead of materialized [N, D, H, W, C] images — the
            # per-round gather composes on device (pool + [S, D] indices)
            if store != "paged":
                raise ValueError(
                    "lazy federated data (index-backed partition) requires "
                    "store='paged'; the dense/traced paths consume the "
                    "materialized [N, D, ...] image stack")
            self._pool_images = jnp.asarray(fed.pool_images)
            self._images = None
        else:
            self._pool_images = None
            self._images = jnp.asarray(fed.images)
        self._labels = jnp.asarray(fed.labels)
        self._sizes = jnp.asarray(fed.sizes)
        self._sizes_host = np.asarray(fed.sizes)

        # lossy uplink shrinks the payload -> z_n enters SAO via H_n, t_com
        n_par = tree_num_params(self.global_params)
        n_leaves = len(jax.tree_util.tree_leaves(self.global_params))
        z = self.compressor.payload_mbit(n_par, n_leaves)
        if z is None:
            from repro.models.registry import model_def_for
            if model_def_for(model_cfg).price_uploads:
                # adapter workloads upload the TRAINABLE parameters only:
                # price z from P (= P_adapter fp32 bits), never P_base
                z = n_par * 32 / 1e6
        if z is not None:
            import dataclasses as _dc
            self.fleet = _dc.replace(fleet, z=np.full_like(fleet.z, z))

    # ------------------------------------------------------------------
    @classmethod
    def from_spec(cls, spec) -> "FLExperiment":
        from repro.api.build import build_experiment
        return build_experiment(spec)

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    # ------------------------------------------------------------------
    @property
    def store(self):
        """The client parameter store (``DenseStore`` | ``PagedStore``) —
        the one ``ClientStore`` every driver consumes."""
        return self._store

    @property
    def stats(self) -> ClientStats:
        """The O(N) per-client statistics table — owned by the store, the
        SINGLE source of per-client truth (availability, age, in-flight
        completion, divergence/drift, virtual clock) for the host loops
        and the async scheduler alike."""
        return self._store.stats

    @property
    def _faults_on(self) -> bool:
        return self.faults is not None and self.faults.active

    @property
    def _track_faults(self) -> bool:
        return self._faults_on or self.quarantine_after > 0

    @property
    def client_params(self) -> jnp.ndarray:
        """The dense [N, P] plane (donation-managed by the round loop).

        A paged store keeps no materialized plane — gather the rows you
        need through the store contract instead."""
        if self._store.kind != "dense":
            raise AttributeError(
                "store='paged' keeps no [N, P] client buffer; gather "
                "active rows with exp.store.gather(idx), page the cold "
                "store with iter_client_trees()/iter_client_features(), "
                "or read the O(N) exp.stats table")
        return self._store.buffer

    @client_params.setter
    def client_params(self, value):
        if self._store.kind != "dense":
            raise AttributeError(
                "store='paged' keeps no [N, P] client buffer to assign; "
                "persist trained rows through exp.store.scatter(idx, rows)")
        self._store.buffer = value

    def _client_images(self, idx: np.ndarray) -> jnp.ndarray:
        """The selected clients' sample stacks ``[S, D, H, W, C]`` —
        a row gather for materialized data, a device-side pool gather for
        lazy (index-backed) partitions."""
        if self._pool_images is None:
            return self._images[idx]
        return self._pool_images[jnp.asarray(self.fed.indices[idx])]

    def evaluate(self):
        acc, per_class = self.engine.evaluate(
            self.global_params, self.test_images, self.test_labels)
        return float(acc), np.asarray(per_class)

    # ------------------------------------------------------------------
    def train_clients(self, idx: np.ndarray):
        """Run local updates for ``idx``; returns their new stacked params
        (after simulated lossy uplink compression, if configured)."""
        idx = np.asarray(idx)
        keys = jax.random.split(self._next_key(), len(idx))
        new_params = self.engine.train_clients(
            self.global_params, self._client_images(idx), self._labels[idx],
            keys)
        return self.compressor.apply(new_params, self.global_params)

    def aggregate(self, stacked_params, idx: np.ndarray):
        """Server aggregation over the participating local models (eq. (4)
        weighted mean by default; pluggable via the aggregator registry)."""
        weights = self._sizes[np.asarray(idx)]
        self.global_params = self.aggregator.aggregate(
            self.global_params, stacked_params, weights)

    def store_clients(self, stacked_params, idx: np.ndarray):
        """Write the clients' new models into the client store.

        Accepts flat ``[S, P]`` rows (the fused round step's output) or a
        stacked pytree (flattened here). On the dense store the scatter
        jit donates the old buffer, so the plane updates in place instead
        of double-buffering 45 MB per round — external holders of
        ``client_params`` must copy (see ``client_tree``). On the paged
        store the rows page out to the host cold store."""
        rows = (stacked_params
                if isinstance(stacked_params, jnp.ndarray)
                and stacked_params.ndim == 2
                else flatten_stacked(stacked_params))
        self._store.scatter(np.asarray(idx), rows)

    def client_tree(self, chunk_size: Optional[int] = None):
        """The client store as a stacked pytree (host-numpy leaves
        ``[N, ...]``) — always a COPY for external consumers (the dense
        buffer is donation-managed by the round loop).

        Assembled by paging the store ``chunk_size`` rows at a time, so
        peak memory beyond the (inherently O(N·P)) result is one chunk —
        use :meth:`iter_client_trees` to stream without materializing the
        full result at all."""
        spec = self.engine.flat_spec
        n = self.fed.num_clients
        leaves = [np.empty((n,) + shape, dt)
                  for shape, dt in zip(spec.shapes, spec.dtypes)]
        start = 0
        for block in self._store.iter_chunks(self._chunk(chunk_size)):
            c = block.shape[0]
            for leaf, off, size, shape in zip(leaves, spec.offsets,
                                              spec.sizes, spec.shapes):
                leaf[start:start + c] = (block[:, off:off + size]
                                         .reshape((c,) + shape))
            start += c
        return jax.tree_util.tree_unflatten(spec.treedef, leaves)

    def iter_client_trees(self, chunk_size: Optional[int] = None):
        """Stream the client store as ``(start_row, stacked pytree)``
        blocks of at most ``chunk_size`` clients — O(chunk·P) peak."""
        start = 0
        for block in self._store.iter_chunks(self._chunk(chunk_size)):
            yield start, unflatten_rows_np(self.engine.flat_spec, block)
            start += block.shape[0]

    def _chunk(self, chunk_size: Optional[int]) -> int:
        return int(chunk_size) if chunk_size else self.chunk_size

    def client_features(self, layer: Optional[str] = None,
                        chunk_size: Optional[int] = None) -> jnp.ndarray:
        """K-means feature matrix ``[N, F]`` (Alg. 2's input).

        Dense store: a zero-copy column slice of the plane
        (``layer="all"``'s view IS the buffer, so it is copied here — the
        next round's donated store would delete it out from under the
        caller otherwise). Paged store: assembled chunk-at-a-time from the
        cold store (identical columns via the shared spec resolution), so
        only the [N, F] feature block ever materializes."""
        layer = self.fl.feature_layer if layer is None else layer
        if self._store.kind == "dense":
            feats = extract_features_flat(self.client_params, layer,
                                          self.engine.flat_spec)
            return (jnp.array(feats) if feats is self._store.buffer
                    else feats)
        cols = resolve_feature_columns(self.engine.flat_spec, layer)
        blocks = [block if cols is None else block[:, cols]
                  for block in self._store.iter_chunks(
                      self._chunk(chunk_size))]
        return jnp.asarray(np.concatenate(blocks, axis=0))

    def iter_client_features(self, layer: Optional[str] = None,
                             chunk_size: Optional[int] = None):
        """Stream ``(start_row, [c, F] host feature block)`` pairs —
        the O(chunk·P) iterator variant of :meth:`client_features`."""
        layer = self.fl.feature_layer if layer is None else layer
        cols = resolve_feature_columns(self.engine.flat_spec, layer)
        start = 0
        for block in self._store.iter_chunks(self._chunk(chunk_size)):
            yield start, (np.asarray(block) if cols is None
                          else np.asarray(block[:, cols]))
            start += block.shape[0]

    # ------------------------------------------------------------------
    def initial_round(self):
        """Round 0: all devices train; then K-means clustering (Alg. 2).

        On the paged store a fleet larger than ``k_max`` trains in waves
        of ``k_max`` (the active-plane size), streaming the eq.-(4)
        weighted mean across waves — a single wave (``k_max >= N``) takes
        the dense host path verbatim and stays on the pinned numerics."""
        n = self.fed.num_clients
        idx = np.arange(n)
        if self._store.kind == "dense" or n <= self.k_max:
            new_params = self.train_clients(idx)
            self.store_clients(new_params, idx)
            self.aggregate(new_params, idx)
        else:
            self._initial_round_waves(idx)
        if self.cluster_mode == "minibatch":
            # O(chunk)-memory streaming fit: feature blocks page straight
            # from the store; a single-chunk stream IS the full fit
            chunks = lambda: (blk for _, blk in self.iter_client_features())
            _, labels, _ = kmeans_fit_minibatch(self._next_key(), chunks,
                                                self.fl.num_clusters)
        else:
            feats = self.client_features()
            _, labels, _ = kmeans_fit(self._next_key(), feats,
                                      self.fl.num_clusters)
        self.cluster_labels = np.asarray(labels)
        self.clusters = clusters_from_labels(labels, self.fl.num_clusters)
        if self._store.kind == "paged":
            self._finish_paged_round(idx)

    def _initial_round_waves(self, idx: np.ndarray):
        """All-device training in ``k_max``-sized waves: the device never
        holds more than one active [k_max, P] block; the global update is
        the streaming weighted mean over waves (not bitwise-identical to
        the one-shot eq.-(4) reduction — chunk-boundary summation — which
        is why single-wave stays on the direct path)."""
        spec = self.engine.flat_spec

        def waves():
            for s in range(0, len(idx), self.k_max):
                w_idx = idx[s:s + self.k_max]
                rows = flatten_stacked(self.train_clients(w_idx))
                self._store.scatter(w_idx, rows)
                yield np.asarray(rows), self._sizes_host[w_idx]

        mean = streaming_weighted_mean(waves(), spec.total)
        # feed the pre-aggregated mean through the aggregator as a single
        # unit-weight row, so stateful servers (momentum) see one eq.-(4)
        # mean exactly as they would from the one-shot path
        mean_tree = jax.tree_util.tree_map(
            lambda l: l[None], unflatten_vector(spec, jnp.asarray(mean)))
        self.global_params = self.aggregator.aggregate(
            self.global_params, mean_tree, np.ones(1))

    def divergences(self) -> np.ndarray:
        """Per-client ‖w_n − w_g‖ — the §IV-C selection signal.

        Dense store: one fused reduction over the [N, P] plane. Paged
        store: served from the O(N) stats table — untouched clients all
        equal the broadcast base row, so their (exact) divergence is ONE
        O(P) row op; touched clients carry the value from their last
        refresh, recomputed in streamed O(chunk·P) batches every
        ``div_refresh_every`` rounds (1 = every round = exactly the dense
        signal; 0 = never, staleness bounded by ``stats.drift``)."""
        if self._store.kind == "dense":
            return np.asarray(weight_divergence_flat(
                self.client_params, tree_flatten_vector(self.global_params)))
        return self._paged_divergences()

    def _paged_divergences(self) -> np.ndarray:
        store, stats = self._store, self.stats
        gvec = jnp.asarray(self._gvec_host)
        # every untouched row IS the base row: one [1, P] call through the
        # same fused op keeps their entries bit-identical to a dense sweep
        base_d = np.asarray(self.engine.rows_divergence(
            jnp.asarray(store.base)[None, :], gvec))[0]
        untouched = ~store.touched
        stats.divergence[untouched] = base_d
        stats.drift[untouched] = 0.0
        every = self._div_refresh_every
        # a forced refresh (sentinel) covers mass scatters that bypassed
        # the per-row update — e.g. the initial all-device round — so even
        # the lazy (every=0) policy never serves an uninitialized entry
        forced = self._rounds_since_refresh >= np.iinfo(np.int32).max
        if (store.num_touched
                and (forced or (every > 0
                                and self._rounds_since_refresh >= every))):
            tidx = np.flatnonzero(store.touched)
            for s in range(0, len(tidx), self.chunk_size):
                batch = tidx[s:s + self.chunk_size]
                stats.divergence[batch] = np.asarray(
                    self.engine.rows_divergence(store.gather(batch), gvec))
            stats.drift[store.touched] = 0.0
            self._rounds_since_refresh = 0
        return stats.divergence.copy()

    def selection_context(self) -> SelectionContext:
        return SelectionContext(
            rng=self.rng,
            num_devices=self.fed.num_clients,
            devices_per_round=self.fl.devices_per_round,
            selected_per_cluster=self.fl.selected_per_cluster,
            bandwidth_mhz=self.B,
            fleet=self.fleet,
            clusters=self.clusters,
            divergences=self.divergences)

    def select(self, method: Any = None) -> np.ndarray:
        """Device selection for one round; ``method`` may be a registered
        name, a spec dict, a Selector instance, or None for the default."""
        selector = (self.selector if method is None
                    else SELECTORS.resolve(method))
        return np.asarray(selector.select(self.selection_context()))

    def allocation(self, idx: np.ndarray) -> Allocation:
        """Spectrum allocation for the round (full per-device solution)."""
        arr = fleet_arrays(self.fleet.select(np.asarray(idx)))
        return self.allocator.allocate(arr, self.B)

    def allocate(self, idx: np.ndarray):
        """Back-compat: returns just ``(T_k, E_k)``."""
        a = self.allocation(idx)
        return a.T, a.E

    # ------------------------------------------------------------------
    def round(self, method: Any = None) -> RoundResult:
        """One full FL round: select → allocate → train → aggregate → eval.

        Uses the engine's fused jitted step when the aggregator is the
        plain eq. (4) mean and no lossy compression is configured. On the
        paged store the selection is additionally filtered by the stats
        table's availability mask (round-level churn), and the round's
        trained rows refresh the table's divergence/age entries — O(K·P)
        bookkeeping; the O(N·P) plane is never touched.
        """
        idx = np.asarray(self.select(method))
        paged = self._store.kind == "paged"
        faults_on = self._faults_on
        if paged:
            idx = idx[self.stats.avail[idx]]
        if self.quarantine_after > 0:
            idx = idx[self.stats.strikes[idx] < float(self.quarantine_after)]
        if idx.size == 0:               # everyone churned/quarantined out:
            acc, per_class = self.evaluate()    # explicit no-op round
            return RoundResult(
                selected=idx, T_k=0.0, E_k=0.0, accuracy=acc,
                per_class=per_class,
                params=jax.tree_util.tree_map(jnp.copy,
                                              self.global_params))
        alloc = self.allocation(idx)
        fused = (getattr(self.aggregator, "fuses_with_engine", False)
                 and getattr(self.compressor, "identity", False)
                 and not faults_on)
        keep = None                     # faults: lanes persisted to store
        if fused:
            keys = jax.random.split(self._next_key(), len(idx))
            # round_step donates the global params (the new global reuses
            # their buffers) and returns the clients as flat [S, P] rows
            rows, new_global, acc, per_class = self.engine.round_step(
                self.global_params, self._client_images(idx),
                self._labels[idx], keys, self._sizes[idx], self.test_images,
                self.test_labels)
            self.store_clients(rows, idx)
            self.global_params = new_global
            acc, per_class = float(acc), np.asarray(per_class)
        else:
            stacked = self.train_clients(idx)
            rows = flatten_stacked(stacked)
            if faults_on:
                rows, survive, keep = self._inject_faults_host(
                    idx, rows, alloc)
                ksel = np.flatnonzero(keep)
                if ksel.size:
                    self.store_clients(rows[jnp.asarray(ksel)], idx[ksel])
                self._aggregate_flat_host(rows, survive, idx)
            else:
                self.store_clients(rows, idx)
                self.aggregate(stacked, idx)
            acc, per_class = self.evaluate()
        if paged:
            if keep is None:
                self._finish_paged_round(idx, rows)
            elif keep.any():
                ksel = np.flatnonzero(keep)
                self._finish_paged_round(idx[ksel], rows[jnp.asarray(ksel)])
            # all-failed round: nothing landed and the global row did not
            # move, so there is no drift/divergence upkeep to do
        # params is COPIED: the next fused round donates self.global_params,
        # which would silently invalidate an earlier RoundResult's tree
        return RoundResult(selected=np.asarray(idx), T_k=alloc.T, E_k=alloc.E,
                           accuracy=acc, per_class=per_class,
                           params=jax.tree_util.tree_map(jnp.copy,
                                                         self.global_params),
                           stacked_params=rows)

    def _inject_faults_host(self, idx: np.ndarray, rows, alloc: Allocation):
        """Host twin of the traced post-train fault phase (``engine``'s
        ``inject_faults`` + ``finite_guard``): ONE key split at the same
        stream position as the traced program, the same Bernoulli draws,
        the same semantics — host ≡ scanned under faults is pinned in
        ``tests/test_faults.py``.

        Returns ``(rows, survive, keep)``: the (byzantine-transformed,
        corrupt-NaN'd) rows, the lanes whose weight survives the fold
        (``~drop & finite``), and the lanes that persist to the store
        (``~drop & ~corrupt`` — matching the traced sentinel scatter)."""
        fs = self.faults
        if fs.chan_outage > 0.0:
            raise ValueError(
                "faults: chan_outage needs the fade state the scanned "
                "program carries; the host round loop has none — run a "
                "traceable bundle with no target_accuracy (store='dense')")
        drop_j, corrupt_j = draw_fault_masks(self._next_key(), fs,
                                             (len(idx),))
        drop = np.asarray(drop_j)
        corrupt = np.asarray(corrupt_j)
        if fs.deadline > 0.0:
            d = np.asarray(completion_times(
                fleet_arrays(self.fleet.select(idx)), alloc.b, alloc.f))
            drop = drop | (d > fs.deadline)
        if self._byz_mask is not None:
            gvec = tree_flatten_vector(self.global_params)
            byz = jnp.asarray(self._byz_mask[idx])
            rows = jnp.where(byz[:, None],
                             gvec[None, :]
                             - fs.byz_scale * (rows - gvec[None, :]),
                             rows)
        if fs.corrupt > 0.0:
            rows = jnp.where(jnp.asarray(corrupt)[:, None],
                             jnp.full((), jnp.nan, rows.dtype), rows)
        finite = np.asarray(jnp.all(jnp.isfinite(rows), axis=1))
        st = self.stats
        np.add.at(st.faults, idx[drop | corrupt], 1.0)
        # strike = a non-finite payload that actually arrived (not lost)
        np.add.at(st.strikes, idx[~finite & ~drop], 1.0)
        return rows, ~drop & finite, ~drop & ~corrupt

    def _aggregate_flat_host(self, rows, survive: np.ndarray,
                             idx: np.ndarray):
        """Eq.-(4) fold of a faulty round: aggregate ALL dispatched lanes
        with the failed lanes' weights zeroed — ``ops.flat_aggregate``
        zeroes a 0-weight lane's payload, so this matches the traced
        program bitwise (and an all-failed round is an explicit no-op on
        the global row, never a 0/0)."""
        if not bool(np.any(survive)):
            return
        spec = self.engine.flat_spec
        if not hasattr(self.aggregator, "aggregate_flat"):
            # pre-flat custom aggregator: feed it the surviving subset
            # (zero-weight lanes would change stacked-contract semantics)
            sel = np.flatnonzero(survive)
            self.global_params = self.aggregator.aggregate(
                self.global_params, unflatten_rows(spec,
                                                   rows[jnp.asarray(sel)]),
                self._sizes[idx[sel]])
            return
        gvec = tree_flatten_vector(self.global_params)
        w = jnp.where(jnp.asarray(survive),
                      self._sizes[idx].astype(jnp.float32), 0.0)
        new_gvec, new_opt = self.aggregator.aggregate_flat(
            gvec, rows, w, self.aggregator.init_flat_state(gvec))
        self.global_params = unflatten_vector(spec, new_gvec)
        self.aggregator.load_flat_state(new_opt, spec)

    def _finish_paged_round(self, idx: np.ndarray, rows=None):
        """Post-round upkeep of the O(N) stats table (paged store only):
        drift bounds grow by ‖g_new − g_old‖ for stale entries, the
        round's trained rows get exact divergences (one O(K·P) row op on
        data already in hand), ages advance."""
        gvec_new = tree_flatten_vector(self.global_params)
        gvec_new_host = np.asarray(gvec_new)
        st = self.stats
        delta = float(np.linalg.norm(gvec_new_host - self._gvec_host))
        st.drift[self._store.touched] += delta
        if rows is not None:
            st.divergence[idx] = np.asarray(
                self.engine.rows_divergence(rows, gvec_new))
            st.drift[idx] = 0.0
        st.age[:] += 1
        st.age[idx] = 0
        self._gvec_host = gvec_new_host
        if rows is None:
            # mass scatter without per-row updates (initial round): force
            # the next divergences() call to refresh the touched rows
            self._rounds_since_refresh = np.iinfo(np.int32).max
        else:
            self._rounds_since_refresh = min(
                self._rounds_since_refresh + 1,
                np.iinfo(np.int32).max - 1)

    def _churn_step_host(self):
        """Round-level Bernoulli churn on the stats table's availability
        mask — a departed client's cold row stays paged out untouched and
        is picked up again verbatim on rejoin."""
        p_leave, p_join = self.churn
        n = self.fed.num_clients
        leave = self.rng.random(n) < p_leave
        join = self.rng.random(n) < p_join
        avail = self.stats.avail
        avail[:] = np.where(avail, ~leave, join)

    def run(self, method: Any = None, rounds: Optional[int] = None,
            target_accuracy: Optional[float] = None,
            include_initial_round: bool = True, *,
            checkpoint_every: int = 0,
            checkpoint_dir: Optional[str] = None,
            checkpoint_offset: int = 0,
            checkpoint_spec: Optional[dict] = None,
            history: Optional[FLHistory] = None) -> FLHistory:
        """Run the experiment; identical results from two execution paths.

        When every configured strategy advertises ``traceable=True``, the
        selection policy is deterministic (bit-parity with the host loop —
        stochastic selectors draw from ``jax.random`` when traced, which
        would silently change this reproduction's numbers for the same
        seed), and no early-stop target is set, the whole experiment runs
        as ONE compiled ``lax.scan`` program on device
        (``engine.run_rounds``) and the history comes back in a single
        transfer. Otherwise the legacy round-at-a-time Python loop below
        drives the same math. Stochastic selectors run device-resident
        through the explicit ``CohortRunner`` path, which documents the
        ``jax.random`` draw.
        """
        rounds = rounds or self.fl.max_rounds
        target = (self.fl.target_accuracy
                  if target_accuracy is None else target_accuracy)
        ck = None
        if checkpoint_every:
            if not checkpoint_dir:
                raise ValueError(
                    "checkpoint_every > 0 needs a checkpoint_dir")
            ck = _Checkpointer(self, checkpoint_dir, int(checkpoint_every),
                               int(checkpoint_offset), checkpoint_spec)
        if (getattr(self.channel, "dynamic", False)
                and self.fleet.num_cells > 1):
            raise ValueError(
                f"channel {self.channel.registry_name!r} computes per-round "
                "interference from the OTHER cells' selections; a single-"
                "cell FLExperiment cannot see them — run the multi-cell "
                "spec through CohortRunner (build_cohort / fl_sim --cells)")
        selector = (self.selector if method is None
                    else SELECTORS.resolve(method))
        if self._store.kind == "paged":
            # population-scale path: host loop over the paged store; the
            # scanned program's [N, P] carry is exactly what this mode
            # exists to avoid
            if (getattr(self.channel, "needs_rng", False)
                    or getattr(self.channel, "stateful", False)):
                raise ValueError(
                    f"channel {self.channel.registry_name!r} redraws fading "
                    "inside the scanned program; store='paged' drives the "
                    "host loop — use the static channel (or store='dense')")
            if getattr(self.aggregator, "async_capable", False):
                # buffered-asynchronous ticks over the paged store: the
                # jitted tick pieces carry only the [P] global + O(N)
                # stats columns; rows move O(k_max·P) through the store's
                # staging API between them
                if not self.traceable(selector):
                    raise ValueError(
                        "the buffered-asynchronous engine needs a fully "
                        "traceable strategy bundle (selector/allocator/"
                        "compressor/channel)")
                return self._run_async_paged(selector, rounds, target,
                                             include_initial_round,
                                             history, ck)
            return self._run_paged(selector, method, rounds, target,
                                   include_initial_round, history, ck)
        if getattr(self.aggregator, "async_capable", False):
            # the buffered-asynchronous engine exists ONLY as a scanned
            # program — there is no host-loop equivalent to fall back to
            if target:
                raise ValueError(
                    "the buffered-asynchronous engine runs as one scanned "
                    "program and cannot early-stop on target_accuracy")
            if ck is not None:
                raise ValueError(
                    "the dense buffered-asynchronous engine runs as ONE "
                    "scanned program with no host boundary to snapshot "
                    "at; checkpoint with store='paged' (the host-composed "
                    "async loop) or checkpoint_every=0")
            if not self.traceable(selector):
                raise ValueError(
                    "the buffered-asynchronous engine needs a fully "
                    "traceable strategy bundle (selector/allocator/"
                    "compressor/channel)")
            out = self._run_traced(selector, rounds, include_initial_round)
            return history.extend(out) if history is not None else out
        bit_parity = not getattr(selector, "needs_rng", True)
        if (not target and bit_parity and self.traceable(selector)
                and ck is None):
            out = self._run_traced(selector, rounds, include_initial_round)
            return history.extend(out) if history is not None else out
        if getattr(self.channel, "needs_rng", False):
            raise ValueError(
                f"channel {self.channel.registry_name!r} redraws fading "
                "inside the scanned program and has no host-loop "
                "equivalent; run it with a traceable strategy bundle and "
                "no target_accuracy (or through CohortRunner)")
        if ck is not None and getattr(self.channel, "stateful", False):
            raise ValueError(
                f"channel {self.channel.registry_name!r} carries fade "
                "state only the scanned program steps; checkpointing "
                "drives the host round loop — use the static channel or "
                "checkpoint_every=0")
        hist = history if history is not None else FLHistory()
        if include_initial_round or self.clusters is None:
            self.initial_round()
            acc, _ = self.evaluate()
            all_idx = np.arange(self.fed.num_clients)
            T0, E0 = self.allocate(all_idx)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T0))
            hist.E_k.append(float(E0))
            hist.selected.append(all_idx)
        for k in range(rounds):
            res = self.round(method)
            hist.append(res)
            if ck is not None:
                ck.maybe(k, hist)
            if target and res.accuracy >= target and hist.rounds_to_target is None:
                hist.rounds_to_target = k + 1
                break
        return hist

    def _run_paged(self, selector, method, rounds: int,
                   target: float, include_initial_round: bool,
                   history: Optional[FLHistory] = None,
                   ck: Optional["_Checkpointer"] = None) -> FLHistory:
        """The population-scale host loop over the paged store.

        Differences from the dense host loop, both deliberate:
        the Alg.-2 initial round (which trains ALL N devices) runs only
        when requested or when the selector actually needs clusters — a
        million-client fleet with a cluster-free policy (random / icas /
        rra / stochastic-sched) skips it entirely; and round-level churn
        flips the stats table's availability mask between rounds, with
        selection filtered against it. With ``include_initial_round=True``
        and ``div_refresh_every=1`` the loop is bit-identical to the dense
        host loop (pinned in ``tests/test_paged_store.py``)."""
        hist = history if history is not None else FLHistory()
        if include_initial_round or (self.clusters is None and
                                     getattr(selector, "needs_clusters",
                                             False)):
            self.initial_round()
            acc, _ = self.evaluate()
            all_idx = np.arange(self.fed.num_clients)
            T0, E0 = self.allocate(all_idx)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T0))
            hist.E_k.append(float(E0))
            hist.selected.append(all_idx)
        churn_on = self.churn != (0.0, 0.0)
        for k in range(rounds):
            if churn_on:
                self._churn_step_host()
            res = self.round(method)
            hist.append(res)
            if ck is not None:
                ck.maybe(k, hist)
            if (target and res.accuracy >= target
                    and hist.rounds_to_target is None):
                hist.rounds_to_target = k + 1
                break
        return hist

    def _run_async_paged(self, selector, rounds: int, target: float,
                         include_initial_round: bool,
                         history: Optional[FLHistory] = None,
                         ck: Optional["_Checkpointer"] = None) -> FLHistory:
        """Buffered-asynchronous ticks over the paged store — the host
        composition of ``async_engine._paged_async_step_program``'s jitted
        pieces, with store paging in between.

        Per tick: (host) refresh the stats table's divergence column per
        the ``div_refresh_every`` cadence (1 = every tick = exactly the
        dense select signal; 0 = never, staleness bounded by
        ``stats.drift``) and push it into the carry → ``sched`` (churn →
        select → in-flight filter) → (host) page the cohort's data in →
        ``plan`` (allocate → completion pricing → fire plan) → ``train``
        (O(K·P)) → (host) ``store.stage`` the trained rows and gather the
        M candidate rows back → ``fire`` (O(M·P) fold + eval) → (host)
        release fired staging, fold ‖g_new − g_old‖ into the drift
        bounds. Device memory is O(k_max·P + M·P) at any N; the math, op
        order and PRNG stream are the dense tick's, pinned bit-identical
        in ``tests/test_async_paged.py``.

        Unlike the dense scanned engine this is a host loop, so
        ``target_accuracy`` early stopping IS supported here."""
        from repro.core.async_engine import _paged_async_step_program
        prog = _paged_async_step_program(
            self.engine.cfg, selector, self.allocator,
            self.aggregator.registry_name,
            tuple(sorted(self.aggregator.params().items())),
            self.compressor, self.traced_context(), self.fl.feature_layer,
            self.channel, self.churn, self.faults, self.quarantine_after)
        hist = history if history is not None else FLHistory()
        if include_initial_round or (self.clusters is None and
                                     getattr(selector, "needs_clusters",
                                             False)):
            self.initial_round()
            acc, _ = self.evaluate()
            all_idx = np.arange(self.fed.num_clients)
            T0, E0 = self.allocate(all_idx)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T0))
            hist.E_k.append(float(E0))
            hist.selected.append(all_idx)
        arr = dict(fleet_arrays(self.fleet))
        arr.pop("xgain", None)           # single-cell: no cross gains
        store, stats = self._store, self.stats
        n = self.fed.num_clients
        needs_div = getattr(selector, "needs_divergence", False)
        state = self.traced_state()
        state = prog.init_channel(state, arr)
        for k in range(rounds):
            if needs_div:
                # serve selection from the refreshed stats table — the
                # paged replacement for the dense full-plane reduction
                div = self._paged_divergences()
                state = state._replace(sched=state.sched._replace(
                    divergence=jnp.asarray(div)))
            state, arr_f, idx, mask = prog.sched(state, arr)
            idx_h = np.asarray(idx)
            mask_h = np.asarray(mask)
            # the host-side mirror of the device gather's clamped OOB
            # sentinel: padding lanes read client N-1's data, train, and
            # are dropped by the mask — identical PRNG consumption
            idx_c = np.minimum(idx_h, n - 1)
            images_sel = self._client_images(idx_c)
            labels_sel = self._labels[jnp.asarray(idx_c)]
            state, T, E, cand, fired_cand, w_cand, good, traces = prog.plan(
                state, arr_f, idx, mask, self._sizes)
            state, rows = prog.train(state, idx, images_sel, labels_sel)
            live = idx_h[mask_h]
            # persist the GOOD lanes only (== mask when fault-free): a
            # dropped/corrupted dispatch never reaches the store, exactly
            # like the dense tick's sentinel scatter
            good_h = np.asarray(good)
            stored = idx_h[good_h]
            if stored.size:
                store.stage(stored,
                            rows[jnp.asarray(np.flatnonzero(good_h))])
            cand_h = np.asarray(cand)
            cand_rows = store.gather_staged(cand_h)
            state, acc, div_cand, g_delta, ok_cand = prog.fire(
                state, cand, cand_rows, w_cand, fired_cand,
                self.test_images, self.test_labels)
            fired_h = np.asarray(fired_cand)
            fired_ids = cand_h[fired_h]
            store.release_staged(fired_ids)
            # stats-table upkeep, the per-tick version of the sync loop's
            # _finish_paged_round: every stale bound grows by this fold's
            # global step (exactly 0 on an empty fire); fired clients get
            # their exact refreshed divergence back from the fold —
            # except lanes the non-finite guard rejected (ok_cand=False),
            # whose divergence entry must not turn NaN
            stats.drift[store.touched] += float(g_delta)
            ok_h = np.asarray(ok_cand)
            ok_ids = cand_h[ok_h]
            if ok_ids.size:
                stats.divergence[ok_ids] = np.asarray(div_cand)[ok_h]
                stats.drift[ok_ids] = 0.0
            self._gvec_host = np.asarray(state.params)
            self._rounds_since_refresh = min(
                self._rounds_since_refresh + 1, np.iinfo(np.int32).max - 1)
            part, stale, active = traces
            acc = float(acc)
            hist.accuracy.append(acc)
            hist.T_k.append(float(T))
            hist.E_k.append(float(E))
            hist.selected.append(live)
            hist.participation.append(float(part))
            hist.staleness.append(float(stale))
            hist.active.append(float(active))
            if ck is not None and ck.due(k):
                # fold the carry into the host tables (read-only on the
                # device state), snapshot, keep driving the same carry
                self._fold_async_carry(state)
                ck.save(k, hist)
            if (target and acc >= target
                    and hist.rounds_to_target is None):
                hist.rounds_to_target = k + 1
                break
        self._fold_async_carry(state)
        return hist

    def _fold_async_carry(self, state: RoundState):
        """Fold an async carry back into the host source of truth:
        params/key/opt state, plus the scheduler columns. divergence/
        drift stay host-maintained (the table already holds the refreshed
        values). Read-only on ``state`` — callable mid-loop (checkpoint
        snapshots) as well as at the end of the run."""
        spec = self.engine.flat_spec
        self.global_params = unflatten_vector(spec, state.params)
        self.key = state.key
        self.aggregator.load_flat_state(state.opt_state, spec)
        sched = state.sched
        stats = self.stats
        for col in ("age", "t_done", "avail", "t_now", "faults", "strikes"):
            np.copyto(getattr(stats, col), np.asarray(getattr(sched, col)))

    # ------------------------------------------------------------------
    # checkpoint / resume (repro.train.checkpoint under the hood)
    # ------------------------------------------------------------------
    def save_checkpoint(self, directory: str, round_idx: int,
                        history: Optional[FLHistory] = None,
                        spec_dict: Optional[dict] = None,
                        keep_last: int = 3) -> str:
        """Atomic full-state snapshot → ``directory/round_%06d/``.

        Contents: the flat global row, the JAX PRNG key, the aggregator's
        flat optimizer state, cluster labels, the O(N) stats table
        (``leaves.npz`` + ``manifest.json`` via ``repro.train.checkpoint``)
        and the client store's rows as chunk-streamed ``store_*.npz``
        blocks — O(chunk·P) peak host memory; a paged store writes only
        its touched rows (the base row is rebuilt from the spec). The
        numpy RNG state, the run history and the (optional) spec ride in
        the manifest extras. The snapshot directory is written under a
        temporary name and ``os.replace``d into place, then the
        ``LATEST`` pointer flips — a killed writer can never leave a
        half-readable snapshot behind. Returns the snapshot path.
        """
        from repro.train import checkpoint as ckpt
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, "round_%06d" % int(round_idx))
        tmp = final + ".tmp"
        import shutil
        for stale in (tmp, final):
            if os.path.isdir(stale):
                shutil.rmtree(stale)
        gvec = tree_flatten_vector(self.global_params)
        opt = self.aggregator.init_flat_state(gvec)
        tree = {
            "gvec": np.asarray(gvec),
            "key": np.asarray(self.key),
            "labels": (np.zeros(self.fed.num_clients, np.int32)
                       if self.cluster_labels is None
                       else np.asarray(self.cluster_labels, np.int32)),
            "opt": (np.zeros((0,), np.float32) if opt is None
                    else np.asarray(opt)),
            "stats": {k: np.asarray(v)
                      for k, v in self.stats._asdict().items()},
        }
        extra = {
            "round": int(round_idx),
            "store_kind": self._store.kind,
            "opt_none": opt is None,
            "has_clusters": self.cluster_labels is not None,
            "rounds_since_refresh": int(self._rounds_since_refresh),
            "rng_state": self.rng.bit_generator.state,
            "spec": spec_dict,
            "history": None if history is None else history.to_dict(),
        }
        ckpt.save_checkpoint(tmp, tree, step=int(round_idx), extra=extra)
        self._save_store_rows(tmp)
        os.replace(tmp, final)
        ckpt.write_latest(directory, os.path.basename(final))
        if keep_last:
            snaps = sorted(d for d in os.listdir(directory)
                           if d.startswith("round_")
                           and not d.endswith(".tmp"))
            for name in snaps[:-keep_last]:
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)
        return final

    def _save_store_rows(self, path: str) -> None:
        """Stream the client store into ``store_*.npz`` blocks of
        ``{idx, rows}`` pairs — O(chunk·P) peak beyond the store itself."""
        store = self._store
        if store.kind == "paged":
            tidx = np.flatnonzero(store.touched)
            for ci, s in enumerate(range(0, tidx.size, self.chunk_size)):
                b = tidx[s:s + self.chunk_size]
                np.savez(os.path.join(path, "store_%05d.npz" % ci),
                         idx=b, rows=np.asarray(store.gather(b)))
            return
        start, ci = 0, 0
        for block in store.iter_chunks(self.chunk_size):
            c = block.shape[0]
            np.savez(os.path.join(path, "store_%05d.npz" % ci),
                     idx=np.arange(start, start + c), rows=np.asarray(block))
            start += c
            ci += 1

    def load_checkpoint(self, directory: str,
                        expected_spec: Optional[dict] = None):
        """Restore a :meth:`save_checkpoint` snapshot into this FRESHLY
        BUILT experiment (same spec — pass ``expected_spec`` to have the
        manifest's recorded spec verified). ``directory`` may be the
        snapshot itself or a parent holding ``round_*`` dirs + ``LATEST``.
        Returns ``(round_idx, history)`` — feed them back into
        :meth:`run` as ``checkpoint_offset``/``history`` with
        ``include_initial_round=False`` for a bit-identical continuation.
        """
        from repro.train import checkpoint as ckpt
        path = ckpt.latest_checkpoint(directory)
        extra = ckpt.checkpoint_extra(path)
        if extra.get("store_kind") != self._store.kind:
            raise ValueError(
                f"checkpoint was taken on store={extra.get('store_kind')!r}"
                f" but this experiment runs store={self._store.kind!r}")
        if (expected_spec is not None and extra.get("spec") is not None
                and extra["spec"] != expected_spec):
            diff = sorted(k for k in set(extra["spec"]) | set(expected_spec)
                          if extra["spec"].get(k) != expected_spec.get(k))
            raise ValueError(
                "checkpoint spec does not match this experiment's spec "
                f"(differing fields: {diff}); resume rebuilds from the "
                "checkpoint's own spec")
        gvec = tree_flatten_vector(self.global_params)
        template = {
            "gvec": np.asarray(gvec),
            "key": np.asarray(self.key),
            "labels": np.zeros(self.fed.num_clients, np.int32),
            "opt": (np.zeros((0,), np.float32) if extra["opt_none"]
                    else np.zeros(gvec.shape, np.float32)),
            "stats": {k: np.asarray(v)
                      for k, v in self.stats._asdict().items()},
        }
        tree = ckpt.load_checkpoint(path, template)
        spec = self.engine.flat_spec
        self.global_params = unflatten_vector(spec, jnp.asarray(tree["gvec"]))
        self.key = jnp.asarray(tree["key"])
        if extra["has_clusters"]:
            self.cluster_labels = np.asarray(tree["labels"])
            self.clusters = clusters_from_labels(self.cluster_labels,
                                                 self.fl.num_clusters)
        else:
            self.cluster_labels = None
            self.clusters = None
        self.aggregator.reset()
        if not extra["opt_none"]:
            self.aggregator.load_flat_state(jnp.asarray(tree["opt"]), spec)
        st = self.stats
        for name, arr in tree["stats"].items():
            np.copyto(getattr(st, name), arr)
        self.rng.bit_generator.state = extra["rng_state"]
        self._rounds_since_refresh = int(extra["rounds_since_refresh"])
        self._load_store_rows(path)
        if self._store.kind == "paged":
            self._gvec_host = np.asarray(tree["gvec"], np.float32)
        hist = (None if extra.get("history") is None
                else FLHistory.from_dict(extra["history"]))
        return int(extra["round"]), hist

    def _load_store_rows(self, path: str) -> None:
        import glob
        for fn in sorted(glob.glob(os.path.join(path, "store_*.npz"))):
            with np.load(fn) as data:
                idx, rows = data["idx"], data["rows"]
            if idx.size:
                self._store.scatter(idx, jnp.asarray(rows))

    # ------------------------------------------------------------------
    # device-resident path: the whole experiment as one lax.scan program
    # ------------------------------------------------------------------
    def traceable(self, selector: Any = None) -> bool:
        """True when the configured strategy bundle supports the scanned
        device-resident pipeline. The pipeline drives the FLAT-plane
        contract, so aggregators/compressors must implement it on top of
        ``traceable=True`` — a strategy written against the pre-flat
        stacked contract falls back to the host loop instead of failing
        mid-trace."""
        selector = self.selector if selector is None else selector
        return (all(getattr(s, "traceable", False)
                    for s in (selector, self.allocator, self.aggregator,
                              self.compressor, self.channel))
                and all(hasattr(self.aggregator, m)
                        for m in ("aggregate_flat", "init_flat_state",
                                  "load_flat_state"))
                and hasattr(self.compressor, "apply_flat"))

    def traced_context(self) -> TracedContext:
        return TracedContext(num_devices=self.fed.num_clients,
                             devices_per_round=self.fl.devices_per_round,
                             selected_per_cluster=self.fl.selected_per_cluster,
                             num_clusters=self.fl.num_clusters,
                             bandwidth_mhz=self.B)

    def traced_state(self) -> RoundState:
        """Snapshot the experiment's mutable state as the scan carry —
        weights on the flat parameter plane (global as one [P] row, the
        client buffer as-is). The scanned program DONATES this state, so
        every leaf handed over here is consumed; ``load_traced_state``
        rebinds the driver's references from the result."""
        labels = (jnp.zeros((self.fed.num_clients,), jnp.int32)
                  if self.cluster_labels is None
                  else jnp.asarray(self.cluster_labels, jnp.int32))
        gvec = tree_flatten_vector(self.global_params)
        # the stats plane: async-capable programs carry the store's stats
        # table (device copy) in the sched slot — incremental run() calls
        # continue the virtual clock because load_traced_state folds it
        # back. Synchronous programs carry None, UNLESS fault tracking /
        # quarantine needs the fault-counter columns in the carry. A
        # paged store has no [N, P] buffer; its programs run
        # plane="stats" and never read client_params, so a zero-row
        # placeholder rides the slot.
        sched = (self.stats.device()
                 if (getattr(self.aggregator, "async_capable", False)
                     or self._track_faults)
                 else None)
        client_plane = (self._store.buffer
                        if self._store.kind == "dense"
                        else jnp.zeros((0,), jnp.float32))
        return RoundState(
            params=gvec, client_params=client_plane,
            opt_state=self.aggregator.init_flat_state(gvec),
            key=self.key, labels=labels, sched=sched)

    def load_traced_state(self, state: RoundState, *,
                          clusters_valid: bool = True):
        """Sync a (final) scan carry back into the host driver, so a traced
        run can be inspected or continued by the Python loop."""
        spec = self.engine.flat_spec
        self.global_params = unflatten_vector(spec, state.params)
        if self._store.kind == "dense":
            self.client_params = state.client_params
        self.key = state.key
        sched = getattr(state, "sched", None)
        if sched is not None:
            # fold the scheduler carry back into the store's stats table
            # (the single source of per-client truth)
            self.stats.load(sched)
        self.aggregator.load_flat_state(state.opt_state, spec)
        if clusters_valid:
            self.cluster_labels = np.asarray(state.labels)
            self.clusters = clusters_from_labels(self.cluster_labels,
                                                 self.fl.num_clusters)

    def _run_traced(self, selector, rounds: int,
                    include_initial_round: bool) -> FLHistory:
        with_init = include_initial_round or self.clusters is None
        fn = run_rounds(self.engine.cfg, selector=selector,
                        allocator=self.allocator, aggregator=self.aggregator,
                        compressor=self.compressor,
                        tctx=self.traced_context(),
                        feature_layer=self.fl.feature_layer,
                        rounds=rounds, with_init=with_init,
                        channel=self.channel, churn=self.churn,
                        faults=self.faults,
                        quarantine_after=self.quarantine_after)
        state = self.traced_state()
        if self.p_shards:
            # P-axis GSPMD: lay the carry's P-sized dims out over a `model`
            # mesh before dispatch — the scanned program's donated carry
            # keeps the layout for the whole run. Composes with the cohort
            # shard_map (which owns the lane axis, never P).
            from repro.sharding.specs import plane_mesh, plane_shardings
            mesh = plane_mesh(self.p_shards)
            if mesh is not None:
                state = jax.device_put(
                    state, plane_shardings(state, mesh,
                                           int(state.params.shape[0])))
        res = fn(state, self._images, self._labels,
                 self._sizes, fleet_arrays(self.fleet), self.test_images,
                 self.test_labels)
        self.load_traced_state(res.state,
                               clusters_valid=with_init
                               or self.cluster_labels is not None)
        return self.history_from_traced(res, with_init,
                                        self.fed.num_clients)

    @staticmethod
    def history_from_traced(res: TracedRunResult, with_init: bool,
                            num_devices: int) -> FLHistory:
        """One device→host transfer of a scanned run's stacked history."""
        hist = FLHistory()
        accs, Ts, Es, sel, msk = (np.asarray(x) for x in (
            res.rounds.accuracy, res.rounds.T, res.rounds.E,
            res.rounds.selected, res.rounds.mask))
        if with_init:
            hist.accuracy.append(float(res.init_accuracy))
            hist.T_k.append(float(res.init_T))
            hist.E_k.append(float(res.init_E))
            hist.selected.append(np.arange(num_devices))
        hist.accuracy.extend(float(a) for a in accs)
        hist.T_k.extend(float(t) for t in Ts)
        hist.E_k.extend(float(e) for e in Es)
        hist.selected.extend(sel[k][msk[k]] for k in range(sel.shape[0]))
        if res.rounds.participation is not None:
            hist.participation.extend(
                float(x) for x in np.asarray(res.rounds.participation))
            hist.staleness.extend(
                float(x) for x in np.asarray(res.rounds.staleness))
            hist.active.extend(
                float(x) for x in np.asarray(res.rounds.active))
        return hist
