"""Federated-learning loop — paper Algorithm 1 + the Fig. 2 framework.

Per round k:
  1. device selection (divergence / kmeans_random / random / icas / rra)
  2. spectrum allocation for the selected set (SAO Alg. 5 or a baseline)
  3. local updates (L SGD steps each) — vmapped over the selected clients
  4. weighted aggregation, eq. (4)
  5. bookkeeping: accuracy, T_k, E_k (eqs. 10-11), weight divergences

Clustering (Algorithm 2) happens once, after an initial all-device round,
on the K-means features of the paper's chosen layer.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.paper_cnn import CNNConfig
from repro.core import selection as sel
from repro.core.clustering import (kmeans_fit, extract_features,
                                   clusters_from_labels)
from repro.core.divergence import weight_divergence
from repro.core.sao import solve_sao
from repro.core.baselines import equal_bandwidth, fedl_lambda
from repro.core.wireless import DeviceFleet, fleet_arrays, rate_mbps
from repro.data.partition import FederatedData
from repro.models.cnn import init_cnn, cnn_loss, cnn_forward
from repro.utils.trees import (tree_weighted_mean_stacked, tree_sub,
                               tree_add, tree_num_params)
from repro.core.compression import apply_compression, payload_mbit
from repro.core.algorithms import make_fedprox_local_update, ServerMomentum


def make_local_update(cnn_cfg: CNNConfig, lr: float, local_iters: int,
                      batch_size: int):
    """One client's local training: L SGD steps on its own shard (Alg. 1
    lines 6-10, with the paper-endorsed SGD variant of §III-A)."""

    def local_update(params, images, labels, key):
        def step(p, k):
            idx = jax.random.randint(k, (batch_size,), 0, images.shape[0])
            batch = {"images": images[idx], "labels": labels[idx]}
            g = jax.grad(cnn_loss)(p, batch, cnn_cfg)
            p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
            return p, None

        keys = jax.random.split(key, local_iters)
        params, _ = jax.lax.scan(step, params, keys)
        return params

    return local_update


@dataclass
class FLHistory:
    accuracy: List[float] = field(default_factory=list)
    T_k: List[float] = field(default_factory=list)
    E_k: List[float] = field(default_factory=list)
    selected: List[np.ndarray] = field(default_factory=list)
    rounds_to_target: Optional[int] = None

    @property
    def total_T(self):
        return float(np.sum(self.T_k))

    @property
    def total_E(self):
        return float(np.sum(self.E_k))


class FLExperiment:
    """Host-side driver around jitted client/aggregation steps."""

    def __init__(self, cnn_cfg: CNNConfig, fed: FederatedData,
                 test_images: np.ndarray, test_labels: np.ndarray,
                 fleet: DeviceFleet, fl: FLConfig, *, bandwidth_mhz: float = 20.0,
                 allocator: str = "sao", seed: int = 0,
                 batch_size: int = 32, box_correct: bool = False,
                 compression: str = "none", fedprox_mu: float = 0.0,
                 server_momentum: float = 0.0):
        self.cnn_cfg = cnn_cfg
        self.fed = fed
        self.fleet = fleet
        self.compression = compression
        self.fedprox_mu = fedprox_mu
        self.server_opt = (ServerMomentum(server_momentum)
                           if server_momentum > 0 else None)
        self.fl = fl
        self.B = bandwidth_mhz
        self.allocator = allocator
        self.box_correct = box_correct
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.test_images = jnp.asarray(test_images)
        self.test_labels = jnp.asarray(test_labels)

        self.global_params = init_cnn(cnn_cfg, self._next_key())
        # all-client stacked copies (updated lazily for selected clients)
        self.client_params = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (fed.num_clients,) + l.shape).copy(),
            self.global_params)
        self.clusters: Optional[List[np.ndarray]] = None
        self.cluster_labels: Optional[np.ndarray] = None

        if fedprox_mu > 0:
            local_update = make_fedprox_local_update(
                cnn_cfg, fl.learning_rate, fl.local_iters, batch_size,
                mu=fedprox_mu)
        else:
            local_update = make_local_update(cnn_cfg, fl.learning_rate,
                                             fl.local_iters, batch_size)
        self._vmapped_update = jax.jit(jax.vmap(local_update,
                                                in_axes=(None, 0, 0, 0)))
        self._eval = jax.jit(self._eval_fn)
        self._images = jnp.asarray(fed.images)
        self._labels = jnp.asarray(fed.labels)
        self._sizes = jnp.asarray(fed.sizes)
        if compression != "none":
            # uplink payload shrinks -> z_n enters SAO via H_n and t_com
            n_par = tree_num_params(self.global_params)
            n_leaves = len(jax.tree_util.tree_leaves(self.global_params))
            z = payload_mbit(n_par, compression, n_leaves)
            import dataclasses as _dc
            self.fleet = _dc.replace(fleet, z=np.full_like(fleet.z, z))

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def _eval_fn(self, params):
        logits = cnn_forward(params, self.test_images, self.cnn_cfg)
        pred = jnp.argmax(logits, axis=-1)
        acc = jnp.mean((pred == self.test_labels).astype(jnp.float32))
        onehot = jax.nn.one_hot(self.test_labels, self.cnn_cfg.num_classes)
        correct = (pred == self.test_labels).astype(jnp.float32)[:, None] * onehot
        per_class = jnp.sum(correct, 0) / jnp.maximum(jnp.sum(onehot, 0), 1.0)
        return acc, per_class

    def evaluate(self):
        acc, per_class = self._eval(self.global_params)
        return float(acc), np.asarray(per_class)

    # ------------------------------------------------------------------
    def train_clients(self, idx: np.ndarray):
        """Run local updates for ``idx``; returns their new stacked params
        (after simulated lossy uplink compression, if configured)."""
        idx = np.asarray(idx)
        keys = jax.random.split(self._next_key(), len(idx))
        new_params = self._vmapped_update(
            self.global_params, self._images[idx], self._labels[idx], keys)
        if self.compression != "none":
            deltas = jax.tree_util.tree_map(
                lambda n, g: n - g[None], new_params, self.global_params)
            deltas = apply_compression(deltas, self.compression)
            new_params = jax.tree_util.tree_map(
                lambda d, g: g[None] + d, deltas, self.global_params)
        return new_params

    def aggregate(self, stacked_params, idx: np.ndarray):
        """Eq. (4): D_n-weighted average of the participating local models
        (+ optional FedAvgM server momentum)."""
        weights = self._sizes[np.asarray(idx)]
        agg = tree_weighted_mean_stacked(stacked_params, weights)
        if self.server_opt is not None:
            agg = self.server_opt.step(self.global_params, agg)
        self.global_params = agg

    def store_clients(self, stacked_params, idx: np.ndarray):
        idx = jnp.asarray(np.asarray(idx))
        self.client_params = jax.tree_util.tree_map(
            lambda all_, new: all_.at[idx].set(new),
            self.client_params, stacked_params)

    # ------------------------------------------------------------------
    def initial_round(self):
        """Round 0: all devices train; then K-means clustering (Alg. 2)."""
        idx = np.arange(self.fed.num_clients)
        new_params = self.train_clients(idx)
        self.store_clients(new_params, idx)
        self.aggregate(new_params, idx)
        feats = extract_features(self.client_params, self.fl.feature_layer)
        _, labels, _ = kmeans_fit(self._next_key(), feats, self.fl.num_clusters)
        self.cluster_labels = np.asarray(labels)
        self.clusters = clusters_from_labels(labels, self.fl.num_clusters)

    def divergences(self) -> np.ndarray:
        return np.asarray(weight_divergence(self.client_params,
                                            self.global_params))

    def select(self, method: str) -> np.ndarray:
        S = self.fl.devices_per_round
        if method == "random":
            return sel.select_random(self.rng, self.fed.num_clients, S)
        if method == "kmeans_random":
            return sel.select_kmeans_random(self.rng, self.clusters,
                                            self.fl.selected_per_cluster)
        if method == "divergence":
            return sel.select_divergence(self.divergences(), self.clusters,
                                         self.fl.selected_per_cluster)
        if method == "icas":
            arr = fleet_arrays(self.fleet)
            rates = np.asarray(rate_mbps(self.B / self.fed.num_clients,
                                         arr["J"]))
            return sel.select_icas(self.divergences(), rates, S)
        if method == "rra":
            arr = fleet_arrays(self.fleet)
            e_eq = np.asarray(arr["H"] / rate_mbps(self.B / 45.0, arr["J"]))
            return sel.select_rra(self.rng, e_eq, np.asarray(arr["e_cons"]),
                                  target_mean=45)
        raise ValueError(method)

    def allocate(self, idx: np.ndarray):
        """Spectrum allocation for the round; returns (T_k, E_k)."""
        arr = fleet_arrays(self.fleet.select(idx))
        if self.allocator == "sao":
            s = solve_sao(arr, self.B, box_correct=self.box_correct)
            Q = s.b * jnp.log2(1.0 + arr["J"] / s.b)
            e = arr["G"] * jnp.square(s.f) + arr["H"] / Q
            return float(s.T), float(jnp.sum(e))
        if self.allocator == "equal":
            r = equal_bandwidth(arr, self.B)
            return float(r.T), float(jnp.sum(r.e))
        if self.allocator.startswith("fedl"):
            lam = float(self.allocator.split(":")[1]) if ":" in self.allocator else 1.0
            r = fedl_lambda(arr, self.B, lam)
            return float(r.T), float(jnp.sum(r.e))
        raise ValueError(self.allocator)

    # ------------------------------------------------------------------
    def run(self, method: Optional[str] = None, rounds: Optional[int] = None,
            target_accuracy: Optional[float] = None,
            include_initial_round: bool = True) -> FLHistory:
        method = method or self.fl.selection
        rounds = rounds or self.fl.max_rounds
        target = (self.fl.target_accuracy
                  if target_accuracy is None else target_accuracy)
        hist = FLHistory()
        if include_initial_round or self.clusters is None:
            self.initial_round()
            acc, _ = self.evaluate()
            hist.accuracy.append(acc)
            T0, E0 = self.allocate(np.arange(self.fed.num_clients))
            hist.T_k.append(T0)
            hist.E_k.append(E0)
            hist.selected.append(np.arange(self.fed.num_clients))
        for k in range(rounds):
            idx = self.select(method)
            T_k, E_k = self.allocate(idx)
            new_params = self.train_clients(idx)
            self.store_clients(new_params, idx)
            self.aggregate(new_params, idx)
            acc, _ = self.evaluate()
            hist.accuracy.append(acc)
            hist.T_k.append(T_k)
            hist.E_k.append(E_k)
            hist.selected.append(np.asarray(idx))
            if target and acc >= target and hist.rounds_to_target is None:
                hist.rounds_to_target = k + 1
                break
        return hist
