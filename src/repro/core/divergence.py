"""Weight divergence — paper §IV-C, the selection signal of Algorithm 4.

d_n = ‖w_n − w_global‖₂ over ALL layers (the paper: "we consider the model
weights of all the layers during calculating the weight divergence").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weight_divergence(stacked_client_params, global_params) -> jnp.ndarray:
    """[N_clients] Euclidean distances between each client model and the
    global model. Client params are stacked on a leading axis (mesh-friendly:
    that axis shards over ``data``)."""
    def leaf_sq(cl, gl):
        diff = cl.astype(jnp.float32) - gl.astype(jnp.float32)[None]
        return jnp.sum(jnp.square(diff).reshape(diff.shape[0], -1), axis=1)

    sq = jax.tree_util.tree_map(leaf_sq, stacked_client_params, global_params)
    total = sum(jax.tree_util.tree_leaves(sq))
    return jnp.sqrt(total)


def pairwise_divergence_matrix(features: jnp.ndarray) -> jnp.ndarray:
    """[N, N] Euclidean distance matrix (Fig. 4's visualization)."""
    sq = jnp.sum(jnp.square(features), axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * features @ features.T
    return jnp.sqrt(jnp.maximum(d2, 0.0))
