"""Weight divergence — paper §IV-C, the selection signal of Algorithm 4.

d_n = ‖w_n − w_global‖₂ over ALL layers (the paper: "we consider the model
weights of all the layers during calculating the weight divergence").

Two equivalent entry points: :func:`weight_divergence_flat` is the round
hot path — one fused row-norm reduction over the ``[N, P]`` flat client
plane, routed through ``repro.kernels.ops`` (Pallas ``pairwise_l2`` on
TPU, fused jnp elsewhere). :func:`weight_divergence` keeps the stacked-
pytree form for callers that hold per-leaf trees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def weight_divergence(stacked_client_params, global_params) -> jnp.ndarray:
    """[N_clients] Euclidean distances between each client model and the
    global model. Client params are stacked on a leading axis (mesh-friendly:
    that axis shards over ``data``)."""
    def leaf_sq(cl, gl):
        diff = cl.astype(jnp.float32) - gl.astype(jnp.float32)[None]
        return jnp.sum(jnp.square(diff).reshape(diff.shape[0], -1), axis=1)

    sq = jax.tree_util.tree_map(leaf_sq, stacked_client_params, global_params)
    total = sum(jax.tree_util.tree_leaves(sq))
    return jnp.sqrt(total)


def weight_divergence_flat(client_flat: jnp.ndarray,
                           global_vec: jnp.ndarray) -> jnp.ndarray:
    """[N] divergences over the flat plane: client_flat [N, P], global [P].

    The traced round pipeline and the host driver both call THIS form, so
    the two execution paths consume identical selection signals bit for
    bit (per-leaf partial sums would differ from the single fused
    reduction in the last ulp — enough to flip a top-k tie)."""
    return ops.client_divergence(client_flat, global_vec)


def pairwise_divergence_matrix(features: jnp.ndarray) -> jnp.ndarray:
    """[N, N] Euclidean distance matrix (Fig. 4's visualization)."""
    return jnp.sqrt(ops.pairwise_sq_dists(features, features))
