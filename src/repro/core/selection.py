"""Device-selection policies — paper §IV (Algorithms 3-4) + compared baselines.

  divergence      : Algorithm 4 — top-s weight divergence per cluster (ours)
  kmeans_random   : Algorithm 3 — s random devices per cluster [23-benchmark]
  random          : FedAvg [31] — S uniform devices
  icas            : ICAS [42] — importance (update norm) × channel-aware rank
  rra             : RRA [39] — energy-efficient participation thresholding

All return a 1-D int array of selected device indices.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def select_random(rng: np.random.Generator, num_devices: int, S: int) -> np.ndarray:
    return rng.choice(num_devices, size=S, replace=False)


def select_kmeans_random(rng: np.random.Generator, clusters: Sequence[np.ndarray],
                         s: int = 1) -> np.ndarray:
    """Algorithm 3: s random devices from each cluster."""
    out = []
    for members in clusters:
        if len(members) == 0:
            continue
        take = min(s, len(members))
        out.append(rng.choice(members, size=take, replace=False))
    return np.concatenate(out)


def select_divergence(divergences: np.ndarray, clusters: Sequence[np.ndarray],
                      s: int = 1) -> np.ndarray:
    """Algorithm 4: from each cluster the devices with the TOP-s weight
    divergence ‖w_n − w_global‖ (most informative local datasets)."""
    out = []
    for members in clusters:
        if len(members) == 0:
            continue
        take = min(s, len(members))
        order = np.argsort(-np.asarray(divergences)[members])
        out.append(members[order[:take]])
    return np.concatenate(out)


def select_icas(update_norms: np.ndarray, rates: np.ndarray, S: int,
                beta: float = 0.5) -> np.ndarray:
    """ICAS [42]: importance- and channel-aware scheduling. Score is a
    geometric blend of gradient/update importance and channel rate (their
    multiplicative probabilistic rule, deterministic top-S variant)."""
    u = np.asarray(update_norms, np.float64)
    r = np.asarray(rates, np.float64)
    u = u / max(u.max(), 1e-12)
    r = r / max(r.max(), 1e-12)
    score = (u ** beta) * (r ** (1.0 - beta))
    return np.argsort(-score)[:S]


def select_rra(rng: np.random.Generator, e_com_at_equal_share: np.ndarray,
               e_budget: np.ndarray, target_mean: int = 45) -> np.ndarray:
    """RRA [39]: energy-efficient radio resource allocation — devices whose
    uplink energy at an equal bandwidth share stays well inside budget
    participate; the set size therefore varies per round (~45 avg in §VI-C)."""
    eff = e_budget / np.maximum(e_com_at_equal_share, 1e-12)
    # participation probability grows with energy efficiency
    p = np.clip(eff / np.percentile(eff, 100 * min(
        1.0, target_mean / len(eff))), 0.0, 1.0)
    # Rescale toward the target mean, but never ABOVE probability-one: when
    # target_mean >= N the percentile lands at 100 (p ≈ eff/max(eff)) and an
    # unclamped target_mean/p.sum() factor pushed every device past 1 —
    # deterministic all-device participation with zero round-to-round
    # variance, silently degenerating the thresholding policy.
    scale = min(1.0, target_mean / max(p.sum(), 1e-9))
    mask = rng.uniform(size=len(eff)) < p * scale
    if not mask.any():
        mask[np.argmax(eff)] = True
    return np.flatnonzero(mask)
