"""SAO — energy-efficient Spectrum Allocation Optimization (paper §V, Alg. 5).

Solves, per global iteration k (problem (19)):

    min_{b, f} T_k
    s.t.  G_n f_n² + H_n / Q_n(b_n) ≤ e_cons_n          (19a) energy
          z_n / Q_n(b_n) + U_n / f_n ≤ T_k              (19b) deadline
          Σ_n b_n ≤ B                                   (19c) total bandwidth
          f_min ≤ f_n ≤ f_max                           (19d)
    where Q_n(b) = b·log2(1 + J_n/b)   (monotone ↑, sup = J_n/ln2, Lemma 2).

Solution structure (Theorem 1): at the optimum every device finishes exactly
at T_k*, every energy budget is tight, and the full band is used. Combining
(20) and (21) eliminates Q and yields the per-device cubic (23)

    f³ + (H·T/(z·G) − e_cons/G)·f − H·U/(z·G) = 0,

which has a unique positive root (Lemma 3). Algorithm 5 then runs a
three-level bisection: outer on T_k (feasibility of the bandwidth budget),
inner per-device on f (cubic) and on b (monotone Q).

Everything is vectorized over devices with `vmap`-free jnp ops and
fixed-trip-count `lax.fori_loop` bisections, so the whole solver jits and
is differentiable-free but fast (microseconds for S=10..100).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.wireless import (LN2, effective_arrays, masked_max,
                                 masked_sum, rate_mbps)


class SAOSolution(NamedTuple):
    T: jnp.ndarray            # optimal round latency T_k*  [s]
    b: jnp.ndarray            # per-device bandwidth [MHz]
    f: jnp.ndarray            # per-device CPU frequency [GHz]
    converged: jnp.ndarray    # outer bisection reached the ratio band
    ratio: jnp.ndarray        # Σb/B at the returned T


def _Q(b, J):
    """Q_n(b) = b log2(1 + J/b) — Lemma 2 (monotone ↑, bounded by J/ln2)."""
    return rate_mbps(b, J)


def _solve_cubic_f(T, arr, n_iters: int) -> jnp.ndarray:
    """Unique positive root of (23): f³ + X·f − Y = 0 (Lemma 3), bisected.

    X = H·T/(z·G) − e_cons/G  (any sign),  Y = H·U/(z·G) > 0.
    Root upper bound: f ≤ cbrt(Y) + sqrt(max(−X,0)/3) + 1 (comfortably above
    the Lemma-3 root interval).
    """
    X = arr["H"] * T / (arr["z"] * arr["G"]) - arr["e_cons"] / arr["G"]
    Y = arr["H"] * arr["U"] / (arr["z"] * arr["G"])

    def M(f):
        return f * f * f + X * f - Y

    lo = jnp.zeros_like(Y)
    hi = jnp.cbrt(Y) + jnp.sqrt(jnp.maximum(-X, 0.0) / 3.0) + 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        pos = M(mid) > 0.0
        return jnp.where(pos, lo, mid), jnp.where(pos, mid, hi)

    lo, hi = lax.fori_loop(0, n_iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def _solve_b_from_energy(f, arr, b_max, n_iters: int) -> jnp.ndarray:
    """Solve (21): Q(b) = H / (e_cons − G·f²) for b by bisection (Lemma 2).

    Devices whose residual comm-energy budget is non-positive, or whose
    required Q exceeds the supremum J/ln2, are clipped to b_max (Alg. 5
    line 9's clipping threshold).
    """
    resid = arr["e_cons"] - arr["G"] * jnp.square(f)      # energy left for comm
    target = arr["H"] / jnp.maximum(resid, 1e-12)
    achievable = (resid > 0.0) & (target < arr["J"] / LN2) & \
                 (_Q(b_max, arr["J"]) >= target)

    lo = jnp.full_like(f, 1e-9)
    hi = jnp.broadcast_to(b_max, f.shape).astype(f.dtype)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ge = _Q(mid, arr["J"]) >= target
        return jnp.where(ge, lo, mid), jnp.where(ge, mid, hi)

    lo, hi = lax.fori_loop(0, n_iters, body, (lo, hi))
    b = 0.5 * (lo + hi)
    return jnp.where(achievable, b, b_max)


def _solve_b_from_deadline(T, f, arr, b_max, n_iters: int) -> jnp.ndarray:
    """Solve (20): Q(b) = z / (T − U/f) for b — used for box-clipped devices
    in the box-corrected variant (their energy multiplier μ* is zero, so the
    deadline, not the energy budget, pins b)."""
    slack = T - arr["U"] / f
    target = arr["z"] / jnp.maximum(slack, 1e-9)
    achievable = (slack > 0.0) & (target < arr["J"] / LN2) & \
                 (_Q(b_max, arr["J"]) >= target)

    lo = jnp.full_like(f, 1e-9)
    hi = jnp.broadcast_to(b_max, f.shape).astype(f.dtype)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ge = _Q(mid, arr["J"]) >= target
        return jnp.where(ge, lo, mid), jnp.where(ge, mid, hi)

    lo, hi = lax.fori_loop(0, n_iters, body, (lo, hi))
    return jnp.where(achievable, 0.5 * (lo + hi), b_max)


def _inner_allocate(T, arr, b_max, n_iters: int, box_correct: bool):
    """Lines 5-11 of Algorithm 5: per-device f from the cubic, clip to the
    box, then b from the tight energy constraint (21).

    ``box_correct`` (beyond-paper, EXPERIMENTS.md §Perf-sched): devices whose
    f clipped at a box face get b from the deadline equality (20) instead —
    the correct KKT completion, which stops clipped devices from burning
    bandwidth to exhaust an energy budget the optimum leaves slack.
    """
    f_raw = _solve_cubic_f(T, arr, n_iters)
    f = jnp.clip(f_raw, arr["f_min"], arr["f_max"])
    b_energy = _solve_b_from_energy(f, arr, b_max, n_iters)
    if not box_correct:
        return b_energy, f
    b_deadline = _solve_b_from_deadline(T, f, arr, b_max, n_iters)
    clipped = (f_raw < arr["f_min"]) | (f_raw > arr["f_max"])
    # Q(b) is monotone ↑, so each of (20)/(21) gives a MINIMAL feasible b;
    # a clipped device must satisfy both → take the max. (For interior
    # devices the cubic already makes the two coincide.)
    b = jnp.where(clipped, jnp.maximum(b_deadline, b_energy), b_energy)
    return jnp.minimum(b, b_max), f


@functools.partial(jax.jit,
                   static_argnames=("n_outer", "n_inner", "box_correct"))
def solve_sao(arr: Dict[str, jnp.ndarray], B: float, *, mask=None,
              eps0: float = 1e-3, b_max: float = None, n_outer: int = 48,
              n_inner: int = 48, box_correct: bool = False) -> SAOSolution:
    """Algorithm 5. ``arr`` = fleet_arrays(fleet.select(S_k)); B in MHz.

    Outer bisection on T_k: Σ_n b_n(T) is monotone ↓ in T (looser deadline →
    smaller f → more energy headroom for comm → less bandwidth needed), so
    plain bisection converges to the T* where the band is exactly used.

    ``mask`` (optional, [S] bool) marks which lanes are real devices — the
    traced round pipeline passes fixed-size padded selections; padded lanes
    are excluded from every cross-device reduction (band sum, delay max)
    and get ``b = f = 0`` in the returned solution.

    When ``arr`` carries an ``"inr"`` interference term (multi-cell
    scenarios) it is folded into J at entry — the solve itself is
    interference-aware with no other change (eq. (7) keeps its shape with
    J_eff = J/(1+inr)).
    """
    arr = effective_arrays(arr)
    if b_max is None:
        b_max = B
    b_max = jnp.asarray(b_max, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    if mask is None:
        mask = jnp.ones(arr["J"].shape, bool)

    # Line 1: T_min = max_n( ln2·z/J + U/f_max ) — the b→∞, f=f_max limit.
    T_min0 = masked_max(LN2 * arr["z"] / arr["J"] + arr["U"] / arr["f_max"],
                        mask)
    # T_max: generous upper bound — slowest CPU + a 1000th of the band each.
    n = arr["J"].shape[0]
    b_floor = jnp.maximum(B / n * 1e-3, 1e-6)
    T_max0 = masked_max(arr["z"] / _Q(b_floor, arr["J"])
                        + arr["U"] / arr["f_min"], mask) * 2.0

    def cond(carry):
        i, T_lo, T_hi, done = carry
        return (i < n_outer) & (~done)

    def body(carry):
        i, T_lo, T_hi, _ = carry
        T = 0.5 * (T_lo + T_hi)
        b, f = _inner_allocate(T, arr, b_max, n_inner, box_correct)
        ratio = masked_sum(b, mask) / B
        done = (ratio <= 1.0) & (ratio >= 1.0 - eps0)
        # pin both ends to T on convergence so the returned midpoint IS the
        # T that satisfied the band; otherwise shrink the bracket.
        T_lo = jnp.where(done, T, jnp.where(ratio > 1.0, T, T_lo))
        T_hi = jnp.where(done, T, jnp.where(ratio < 1.0 - eps0, T, T_hi))
        return i + 1, T_lo, T_hi, done

    i, T_lo, T_hi, done = lax.while_loop(
        cond, body, (0, T_min0, T_max0, jnp.asarray(False)))
    T = 0.5 * (T_lo + T_hi)

    # final allocation at the converged T (lines 21-22)
    b, f = _inner_allocate(T, arr, b_max, n_inner, box_correct)
    # Recalculate f* from the *clipped* b* via the tight energy budget (21):
    # f = sqrt((e_cons − H/Q(b*)) / G), boxed — then the true delay (20).
    resid = arr["e_cons"] - arr["H"] / _Q(b, arr["J"])
    f_star = jnp.sqrt(jnp.maximum(resid, 0.0) / arr["G"])
    f_star = jnp.clip(f_star, arr["f_min"], arr["f_max"])
    # keep the better (feasible) of the two candidates per device
    e_of = lambda ff: arr["G"] * jnp.square(ff) + arr["H"] / _Q(b, arr["J"])
    f_final = jnp.where(e_of(f_star) <= arr["e_cons"] + 1e-6, f_star, f)
    t = arr["z"] / _Q(b, arr["J"]) + arr["U"] / f_final
    T_star = masked_max(t, mask)
    ratio = masked_sum(b, mask) / B
    # ratio ≤ 1 at the bracket floor means the band constraint is slack at
    # the optimum (γ* = 0 corner: energy budgets loose, T* = T_min) — that is
    # a converged optimum too, (22) just isn't tight.
    return SAOSolution(T=T_star, b=jnp.where(mask, b, 0.0),
                       f=jnp.where(mask, f_final, 0.0),
                       converged=done | (ratio <= 1.0), ratio=ratio)


def kkt_residuals(sol: SAOSolution, arr, B):
    """Theorem-1 optimality residuals (used by property tests & benchmarks).

    Returns dict with:
      delay_spread : max_n t_n − min_n t_n  (eq. 20 — all-equal delays)
      energy_slack : e_cons − e_n           (eq. 21 — ≈0 when not box-clipped)
      band_slack   : B − Σ b_n              (eq. 22 — ≈0)
    """
    arr = effective_arrays(arr)
    Q = _Q(sol.b, arr["J"])
    t = arr["z"] / Q + arr["U"] / sol.f
    e = arr["G"] * jnp.square(sol.f) + arr["H"] / Q
    return {
        "delay_spread": jnp.max(t) - jnp.min(t),
        "energy_slack": arr["e_cons"] - e,
        "band_slack": B - jnp.sum(sol.b),
        "t": t,
        "e": e,
    }
