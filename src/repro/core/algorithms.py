"""Beyond-paper FL aggregation/objective variants on the same substrate.

  FedProx  (Li et al. 2020): proximal term μ/2‖w − w_global‖² in the client
           objective — stabilizes non-iid local updates.
  FedAvgM  (Hsu et al. 2019): server momentum over the pseudo-gradient
           Δ_k = w_k − aggregate(w_locals).

These compose with the paper's selection + SAO layers unchanged (selection
sees the same weight-divergence signal; SAO the same payloads).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.registry import model_def_for
from repro.utils.trees import tree_sub, tree_scale, tree_add


def make_fedprox_local_update(model_cfg, lr: float,
                              local_iters: int, batch_size: int,
                              mu: float = 0.01):
    """FedProx client update: SGD on  f_n(w) + μ/2‖w − w_g‖²."""
    loss_fn = model_def_for(model_cfg).loss

    def local_update(global_params, images, labels, key):
        def prox_loss(p, batch):
            base = loss_fn(p, batch, model_cfg)
            sq = sum(jnp.sum(jnp.square(a.astype(jnp.float32)
                                        - b.astype(jnp.float32)))
                     for a, b in zip(jax.tree_util.tree_leaves(p),
                                     jax.tree_util.tree_leaves(global_params)))
            return base + 0.5 * mu * sq

        def step(p, k):
            idx = jax.random.randint(k, (batch_size,), 0, images.shape[0])
            g = jax.grad(prox_loss)(p, {"images": images[idx],
                                        "labels": labels[idx]})
            return jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g), None

        keys = jax.random.split(key, local_iters)
        params, _ = jax.lax.scan(step, global_params, keys)
        return params

    return local_update


class ServerMomentum:
    """FedAvgM: w ← w − η·v,  v ← β·v + (w − w_agg)."""

    def __init__(self, beta: float = 0.9, lr: float = 1.0):
        self.beta = beta
        self.lr = lr
        self.v = None

    def step(self, global_params, aggregated):
        delta = tree_sub(global_params, aggregated)       # pseudo-gradient
        if self.v is None:
            self.v = delta
        else:
            self.v = tree_add(tree_scale(self.v, self.beta), delta)
        return tree_sub(global_params, tree_scale(self.v, self.lr))
