"""The buffered-asynchronous tick loop — FL rounds as EVENTS, not
barriers, compiled into the same single ``lax.scan`` program as the
synchronous pipeline.

Production FL has no round barrier: clients are dispatched, train at their
own pace, and the server folds updates as they land. This engine replaces
``repro.core.engine._traced_round_program``'s barrier with a FedBuff-style
(Nguyen et al. 2022) virtual-time loop:

  * every dispatched client's finish time is priced by the PAPER's delay
    model — ``completion_times`` (eqs. 5+8) under the round's SAO/allocator
    bandwidth+frequency assignment and the PR-4 channel-fading carry;
  * the aggregation buffer fires when the ``M`` earliest in-flight
    completions land (``fedbuff:M[:alpha]``), folding them into the global
    row with staleness-discounted weights ``w ∝ (1 + age)^(-alpha)``
    through the same ``ops.flat_aggregate`` row-reduction — over the M
    gathered candidate rows only (O(M·P) per tick, not O(N·P));
    stragglers stay in flight and age;
  * Bernoulli churn streams flip a per-client availability mask riding the
    carry — departures cancel in-flight work, arrivals rejoin the pool —
    and selection/allocation never touch an unavailable client.

One scan iteration = one buffer fire = one history row, so the
``FLHistory`` plumbing (cohort vmap, shard_map, donation) is untouched;
``RoundOutputs`` simply gains participation / staleness / active-fleet
traces.

The engine builds its tick from the SAME phase closures as the
synchronous program (``engine.build_round_phases``), and the degenerate
config — buffer at least the padded selection size, no churn — takes a
static branch that IS the synchronous round body op for op: the
sync-degeneracy parity pin (``fedbuff:M>=K, alpha=0`` ≡ scanned fedavg)
holds bit-identically by construction, not by numerical luck.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.api.protocols import TracedContext
from repro.core.engine import (EngineConfig, RoundOutputs, TracedRunResult,
                               build_round_phases, model_eval)
from repro.core.store import ClientStats
from repro.core.wireless import completion_times, masked_max
from repro.kernels import ops
from repro.utils.trees import unflatten_vector


def parse_churn(churn):
    """Normalize a churn spec to the ``(p_leave, p_join)`` float pair.

    Accepts ``None`` (no churn), a single number / ``"0.3"`` (leave-only),
    a ``"p_leave:p_join"`` string (the CLI spelling), or a 2-sequence.
    Both entries are per-tick Bernoulli probabilities in [0, 1].
    """
    if churn is None:
        return (0.0, 0.0)
    if isinstance(churn, str):
        leave_s, _, join_s = churn.partition(":")
        parts = (leave_s, join_s or "0")
    elif isinstance(churn, (int, float)):
        parts = (churn, 0.0)
    else:
        parts = tuple(churn)
        if len(parts) != 2:
            raise ValueError(
                f"churn must be (p_leave, p_join); got {churn!r}")
    try:
        p = tuple(float(x) for x in parts)
    except (TypeError, ValueError):
        raise ValueError(
            f"churn must be numeric 'P_LEAVE[:P_JOIN]'; got {churn!r}"
        ) from None
    if not all(0.0 <= x <= 1.0 for x in p):
        raise ValueError(
            f"churn probabilities must lie in [0, 1]; got {p}")
    return p


def _async_fault_plan(faults, state, sched, idx, mask, d):
    """Dispatch-side fault plan, shared VERBATIM by the dense tick and the
    paged ``plan_fn`` (same key split position, same draws — the
    dense ≡ paged parity holds under faults by construction): one split
    off the carry, the per-dispatch drop/corrupt Bernoullis, then the
    deterministic channel-coupled and straggler-deadline drops. A failed
    upload is priced ``+inf`` — it never completes, so it can never fire
    and its row is never persisted; the event lands in the stats table's
    ``faults`` column (and ``strikes`` for corrupt payloads, detected at
    receipt). Returns ``(state, sched, d, good)`` with ``good`` the lanes
    whose trained rows may be staged/persisted."""
    from repro.core.faults import chan_outage_threshold, draw_fault_masks

    key, kf = jax.random.split(state.key)
    state = state._replace(key=key)
    drop, corrupt = draw_fault_masks(kf, faults, idx.shape)
    if faults.chan_outage > 0.0:
        # unit-mean exponential fade power from the Gauss-Markov carry
        gain = jnp.sum(jnp.square(state.channel), axis=-1)
        drop = drop | (gain[idx] < chan_outage_threshold(faults.chan_outage))
    if faults.deadline > 0.0:
        drop = drop | (d > faults.deadline)
    bad = (drop | corrupt) & mask
    d = jnp.where(bad, jnp.inf, d)
    sched = sched._replace(
        faults=sched.faults.at[idx].add(bad.astype(jnp.float32),
                                        mode="drop"),
        strikes=sched.strikes.at[idx].add(
            (corrupt & mask).astype(jnp.float32), mode="drop"))
    return state, sched, d, mask & ~bad


def _byz_transform(faults, byz_pad, idx, gvec, rows):
    """The byzantine row transform ``g − byz_scale·(w − g)`` on the fixed
    adversarial lanes — finite but extreme, so only robust aggregation
    (not the non-finite guard) defends against it."""
    return jnp.where(byz_pad[idx][:, None],
                     gvec[None, :] - faults.byz_scale
                     * (rows - gvec[None, :]),
                     rows)


@functools.lru_cache(maxsize=32)
def _traced_async_program(cfg: EngineConfig, selector, allocator,
                          agg_name: str, agg_params: tuple, compressor,
                          tctx: TracedContext, feature_layer: str,
                          channel=None, churn=(0.0, 0.0), faults=None,
                          quarantine_after: int = 0):
    """The pure (unjitted) buffered-asynchronous experiment fn.

    Same signature contract as ``engine._traced_round_program`` (all
    arguments hashable trace-time constants, aggregator travelling as its
    registry spec) and the same
    ``(state, images, labels, sizes, arr, test_images, test_labels,
    rounds, with_init) -> TracedRunResult`` call shape, so ``run_rounds``
    swaps it in transparently — cohort vmap, shard_map and carry donation
    all apply unchanged.

    One scan iteration ("tick"):

      1. churn — Bernoulli departure/arrival flips ``sched.avail``;
         a departure cancels the client's in-flight update;
      2. select — the registered selector runs on the faded fleet arrays
         (availability exposed as ``arr["avail"]``), then the engine
         post-filters the padded index set: unavailable or already
         in-flight clients drop to the OOB sentinel;
      3. dispatch — the allocator prices the cohort's bandwidth/frequency,
         ``completion_times`` (eqs. 5+8) stamps each dispatched client's
         absolute finish time ``t_now + d`` into ``sched.t_done``, and
         local training writes their rows onto the [N, P] plane;
      4. fire — the buffer collects the ``M`` earliest in-flight
         completions (fewer if the fleet can't fill the buffer: no
         deadlock), advances the virtual clock to the latest of them, and
         folds the fired rows with ``sizes × (1+age)^(-alpha)`` weights;
         an EMPTY fire (everyone churned out) is an explicit no-op — the
         global row and optimizer state pass through untouched;
      5. age — surviving in-flight clients' ``age`` grows by one server
         fold; fired/idle clients reset.
    """
    from repro.api.registry import AGGREGATORS

    aggregator = AGGREGATORS.resolve({"name": agg_name,
                                      "params": dict(agg_params)})
    M = int(aggregator.buffer_size)
    alpha = float(aggregator.staleness_alpha)
    p_leave, p_join = float(churn[0]), float(churn[1])
    churn_on = p_leave > 0.0 or p_join > 0.0
    faults_on = faults is not None and faults.active
    track_faults = faults_on or quarantine_after > 0

    ph = build_round_phases(cfg, aggregator, selector, allocator, compressor,
                            tctx, feature_layer, channel, faults=faults,
                            quarantine_after=quarantine_after)
    N, spec = ph.N, ph.spec
    byz_pad = None
    if faults_on and faults.byzantine > 0.0:
        from repro.core.faults import byzantine_clients
        byz_pad = jnp.asarray(np.concatenate(
            [byzantine_clients(faults, N), np.zeros(1, bool)]))
    S_pad = selector.pad_size(tctx)
    # With the buffer at least the padded selection size and no churn, the
    # backlog is provably empty by induction (every dispatch fires whole),
    # so the tick IS the synchronous round body — take the static branch
    # built from the very same phase closures. Bit-parity by construction.
    degenerate = (M >= S_pad) and not churn_on

    def init_sched(state):
        if state.sched is not None:      # continuing a previous run
            return state
        # same values as ClientStats.create(N).device() — the cohort path
        # builds the table inside the program, the host driver ships its
        # store's table in through RoundState.sched instead
        return state._replace(sched=ClientStats.create_traced(N))

    def churn_step(state):
        """Flip the availability mask; departures cancel in-flight work."""
        sched = state.sched
        key, kc = jax.random.split(state.key)
        k_leave, k_join = jax.random.split(kc)
        leave = jax.random.uniform(k_leave, (N,)) < p_leave
        join = jax.random.uniform(k_join, (N,)) < p_join
        avail = jnp.where(sched.avail, ~leave, join)
        sched = sched._replace(
            avail=avail,
            t_done=jnp.where(avail, sched.t_done, jnp.inf),
            age=jnp.where(avail, sched.age, 0.0))
        return state._replace(key=key, sched=sched)

    def tick(state, images, labels, sizes, arr, test_images, test_labels):
        if churn_on:
            state = churn_step(state)
        sched = state.sched

        # -- select on the faded fleet, availability exposed to churn-
        # aware policies, then hard-filter the padded index set ----------
        arr_in = arr
        if churn_on:
            arr_in = dict(arr)
            arr_in["avail"] = sched.avail.astype(jnp.float32)
        state, arr_f, idx, mask = ph.select_phase(state, arr_in)
        arr_f = dict(arr_f)
        arr_f.pop("avail", None)
        # a client already in flight, or churned out, must not be
        # re-dispatched: drop its lane to the OOB sentinel (okpad's
        # appended False also kills lanes that were already padding)
        ok_client = sched.avail & ~jnp.isfinite(sched.t_done)
        okpad = jnp.concatenate([ok_client, jnp.zeros((1,), bool)])
        mask = mask & okpad[idx]
        idx = jnp.where(mask, idx, N).astype(jnp.int32)

        # -- dispatch: allocate, price completions, train ----------------
        arr_sel = {k: v[idx] for k, v in arr_f.items()}
        T, E, b, f = allocator.allocate_traced(arr_sel, ph.B, mask)
        d = completion_times(arr_sel, b, f, mask)        # +inf on padding
        good = mask
        if faults_on:
            state, sched, d, good = _async_fault_plan(faults, state, sched,
                                                      idx, mask, d)
        t_done = sched.t_done.at[idx].set(sched.t_now + d, mode="drop")
        state, rows = ph.train_rows(state, idx, images, labels)
        if byz_pad is not None:
            rows = _byz_transform(faults, byz_pad, idx, state.params, rows)
        # sentinel rows are out of bounds -> dropped (failed uploads are
        # re-pointed at the sentinel so a lost row never lands)
        store_idx = idx if not faults_on else jnp.where(good, idx, N)
        state = state._replace(
            client_params=state.client_params.at[store_idx].set(rows))

        # -- fire: the M earliest in-flight completions ------------------
        inflight = jnp.isfinite(t_done)
        # completion RANKS, not a k-th-value threshold: the SAO allocator
        # EQUALIZES its cohort's completion times (min-max optimum), so a
        # value cut would fire every tied client at once and overrun the
        # buffer. Stable argsort breaks ties by client index — exactly
        # min(M, #in-flight) fire (fewer than M in flight all fire: no
        # deadlock), the simultaneous rest stay in flight and age.
        order = jnp.argsort(t_done)
        rank = jnp.zeros((N,), jnp.int32).at[order].set(jnp.arange(
            N, dtype=jnp.int32))
        fired = inflight & (rank < M)
        t_fire = jnp.maximum(sched.t_now,
                             masked_max(t_done, fired, empty=sched.t_now))

        # the server fold touches only the M buffer-candidate rows
        # (``fired ⊆ order[:M]`` by construction) — an O(M·P) gather +
        # reduction instead of the full-plane O(N·P) masked sweep, which
        # at population scale dwarfed the actual training. Candidates are
        # sorted into CLIENT-INDEX order first, so the nonzero summation
        # order (and hence the fp32 result) matches the full-plane
        # reduction this replaces.
        cand = jnp.sort(order[:M])
        fired_cand = jnp.isfinite(t_done[cand])
        w_cand = jnp.where(fired_cand, sizes[cand], 0.0)
        if alpha != 0.0:
            w_cand = w_cand * aggregator.staleness_weights(sched.age[cand])
        cand_rows = state.client_params[cand]
        ok_cand = fired_cand
        if track_faults:
            # receive-side non-finite guard: a NaN/Inf candidate row is
            # zero-weighted out of the fold and strikes its sender
            finite_c = jnp.all(jnp.isfinite(cand_rows), axis=1)
            bad_c = fired_cand & ~finite_c
            sched = sched._replace(
                strikes=sched.strikes.at[cand].add(
                    bad_c.astype(jnp.float32), mode="drop"))
            w_cand = jnp.where(finite_c, w_cand, 0.0)
            ok_cand = fired_cand & finite_c
        agg_vec, agg_opt = aggregator.aggregate_flat(
            state.params, cand_rows, w_cand, state.opt_state)
        # EMPTY-FIRE GUARD: flat_aggregate normalizes by max(Σw, eps), so
        # an all-zero weight row yields a ZERO vector — an empty (or
        # all-failed) tick must instead pass the old global (and optimizer
        # state) through
        any_fired = jnp.any(w_cand > 0.0) if track_faults else jnp.any(fired)
        new_gvec = jnp.where(any_fired, agg_vec, state.params)
        new_opt = jax.tree_util.tree_map(
            lambda a, o: jnp.where(any_fired, a, o), agg_opt,
            state.opt_state)

        # traces read the PRE-fold ages (the staleness actually applied)
        part = jnp.sum(fired.astype(jnp.float32))
        stale = (jnp.sum(jnp.where(fired, sched.age, 0.0))
                 / jnp.maximum(part, 1.0))
        active = jnp.sum(sched.avail.astype(jnp.float32))

        # -- stats-table maintenance: a fired update refreshes the
        # client's divergence against the NEW global and resets its drift
        # bound; everyone else's bound grows by this fold's global step
        # ‖g_new − g_old‖ (exactly 0 on an empty fire) — the same
        # invariant the paged sync loop keeps, so selectors reading
        # ``sched.divergence`` see refresh-on-contribution semantics on
        # either backend. Pure add-on columns: nothing here feeds the
        # history numerics or the PRNG stream.
        div_cand = ops.client_divergence(cand_rows, new_gvec)
        new_div = sched.divergence.at[cand].set(
            jnp.where(ok_cand, div_cand, sched.divergence[cand]))
        g_delta = jnp.linalg.norm(new_gvec - state.params)
        refreshed = fired
        if track_faults:
            # a fired-but-guarded (non-finite) row refreshed nothing: its
            # client leaves flight but keeps accruing drift
            bad_full = jnp.zeros((N,), bool).at[cand].set(bad_c, mode="drop")
            refreshed = fired & ~bad_full
        new_drift = jnp.where(refreshed, 0.0, sched.drift + g_delta)

        # -- age the survivors, clear the fired, advance the clock -------
        sched = sched._replace(
            divergence=new_div,
            drift=new_drift,
            age=jnp.where(inflight & ~fired, sched.age + 1.0, 0.0),
            t_done=jnp.where(fired, jnp.inf, t_done),
            t_now=t_fire)
        state = state._replace(params=new_gvec, opt_state=new_opt,
                               sched=sched)

        acc, _ = model_eval(cfg.model_cfg)(unflatten_vector(spec, state.params),
                                           test_images, test_labels)
        return state, RoundOutputs(
            accuracy=acc, T=T, E=E, selected=idx, mask=mask,
            participation=part, staleness=stale, active=active)

    def sync_tick(state, images, labels, sizes, arr, test_images,
                  test_labels):
        """The degenerate branch: the synchronous round body verbatim,
        with the async traces welded on (staleness identically zero, the
        whole fleet active)."""
        state, arr_f, idx, mask = ph.select_phase(state, arr)
        state, outs = ph.finish_phase(state, arr_f, idx, mask, None, images,
                                      labels, sizes, test_images,
                                      test_labels)
        return state, outs._replace(
            participation=jnp.sum(mask.astype(jnp.float32)),
            staleness=jnp.zeros((), jnp.float32),
            active=jnp.full((), N, jnp.float32))

    body = sync_tick if degenerate else tick

    def run(state, images, labels, sizes, arr, test_images, test_labels,
            rounds: int, with_init: bool):
        arr = dict(arr)
        arr.pop("xgain", None)           # single-cell: no cross gains
        state = ph.init_channel(state, arr)
        if not degenerate or track_faults:
            state = init_sched(state)

        init_out = None
        if with_init:
            state, init_out = ph.init_round(state, images, labels, sizes,
                                            arr, None, test_images,
                                            test_labels)

        def step(s, _):
            return body(s, images, labels, sizes, arr, test_images,
                        test_labels)

        state, outs = lax.scan(step, state, None, length=rounds)
        if init_out is None:
            return TracedRunResult(state=state, rounds=outs)
        acc0, T0, E0 = init_out
        return TracedRunResult(state=state, rounds=outs, init_accuracy=acc0,
                               init_T=T0, init_E=E0)

    return run


@functools.lru_cache(maxsize=32)
def _paged_async_step_program(cfg: EngineConfig, selector, allocator,
                              agg_name: str, agg_params: tuple, compressor,
                              tctx: TracedContext, feature_layer: str,
                              channel=None, churn=(0.0, 0.0), faults=None,
                              quarantine_after: int = 0):
    """The jitted pieces of ONE buffered-asynchronous tick over a paged
    ``ClientStore`` — the host driver composes them with store paging in
    between (``FLExperiment._run_async_paged``).

    Same math, same PRNG discipline, same op order as the dense
    :func:`_traced_async_program` tick, but the traced carry holds only
    the O(N) stats columns (``RoundState.sched``, a ``ClientStats``
    pytree) + the [P] global row — never an [N, P] plane
    (``build_round_phases(plane="stats")``). The dispatched cohort's rows
    and data move O(K·P) per tick through the store's staging API, and
    the fire folds the M candidate rows gathered back from staging:
    device memory is O(k_max·P + M·P) at any fleet size. Pinned
    bit-identical to the dense tick at small N (``tests/
    test_async_paged.py``).

    The split into four functions is deliberate: ``sched`` (churn →
    select → in-flight post-filter) and ``plan`` (allocate → completion
    pricing → fire plan) hold every O(N)/O(N log N) scheduler op, while
    ``train`` (O(K·P) local SGD) and ``fire`` (O(M·P) fold + eval) scale
    only with the cohort — so the N-scaling benchmark can gate the
    rest-of-tick cost flat in N, exactly like the PR-7 paged sync gate.
    """
    from types import SimpleNamespace

    from repro.api.registry import AGGREGATORS

    aggregator = AGGREGATORS.resolve({"name": agg_name,
                                      "params": dict(agg_params)})
    M = int(aggregator.buffer_size)
    alpha = float(aggregator.staleness_alpha)
    p_leave, p_join = float(churn[0]), float(churn[1])
    churn_on = p_leave > 0.0 or p_join > 0.0
    faults_on = faults is not None and faults.active
    track_faults = faults_on or quarantine_after > 0

    ph = build_round_phases(cfg, aggregator, selector, allocator, compressor,
                            tctx, feature_layer, channel, plane="stats",
                            faults=faults,
                            quarantine_after=quarantine_after)
    N, spec = ph.N, ph.spec
    byz_pad = None
    if faults_on and faults.byzantine > 0.0:
        from repro.core.faults import byzantine_clients
        byz_pad = jnp.asarray(np.concatenate(
            [byzantine_clients(faults, N), np.zeros(1, bool)]))
    eval_fn = model_eval(cfg.model_cfg)

    def churn_step(state):
        """Identical to the dense tick's churn: same splits, same masks —
        the PRNG streams of the two backends stay in lockstep."""
        sched = state.sched
        key, kc = jax.random.split(state.key)
        k_leave, k_join = jax.random.split(kc)
        leave = jax.random.uniform(k_leave, (N,)) < p_leave
        join = jax.random.uniform(k_join, (N,)) < p_join
        avail = jnp.where(sched.avail, ~leave, join)
        sched = sched._replace(
            avail=avail,
            t_done=jnp.where(avail, sched.t_done, jnp.inf),
            age=jnp.where(avail, sched.age, 0.0))
        return state._replace(key=key, sched=sched)

    def sched_fn(state, arr):
        """churn → select (divergence read from the stats carry) →
        in-flight/availability post-filter. All the O(N) selection work."""
        if churn_on:
            state = churn_step(state)
        sched = state.sched
        arr_in = arr
        if churn_on:
            arr_in = dict(arr)
            arr_in["avail"] = sched.avail.astype(jnp.float32)
        state, arr_f, idx, mask = ph.select_phase(state, arr_in)
        arr_f = dict(arr_f)
        arr_f.pop("avail", None)
        ok_client = sched.avail & ~jnp.isfinite(sched.t_done)
        okpad = jnp.concatenate([ok_client, jnp.zeros((1,), bool)])
        mask = mask & okpad[idx]
        idx = jnp.where(mask, idx, N).astype(jnp.int32)
        return state, arr_f, idx, mask

    def plan_fn(state, arr_f, idx, mask, sizes):
        """allocate → price completions → stamp ``t_done`` → fire plan.
        Returns the tick's (T, E), the M buffer candidates (client-index
        sorted, exactly the dense tick's summation order), their fired
        mask and staleness-discounted weights, and the per-tick traces —
        and advances age/t_done/t_now on the stats carry."""
        sched = state.sched
        arr_sel = {k: v[idx] for k, v in arr_f.items()}
        T, E, b, f = allocator.allocate_traced(arr_sel, ph.B, mask)
        d = completion_times(arr_sel, b, f, mask)        # +inf on padding
        good = mask
        if faults_on:
            state, sched, d, good = _async_fault_plan(faults, state, sched,
                                                      idx, mask, d)
        t_done = sched.t_done.at[idx].set(sched.t_now + d, mode="drop")
        inflight = jnp.isfinite(t_done)
        order = jnp.argsort(t_done)
        rank = jnp.zeros((N,), jnp.int32).at[order].set(
            jnp.arange(N, dtype=jnp.int32))
        fired = inflight & (rank < M)
        t_fire = jnp.maximum(sched.t_now,
                             masked_max(t_done, fired, empty=sched.t_now))
        cand = jnp.sort(order[:M])
        # fired == cand[fired_cand]: fired ⊆ order[:M] by construction,
        # and a candidate's pre-clear t_done is finite iff it fired — so
        # the host learns which staged rows to release from the [M]
        # transfer alone, never a [N] one
        fired_cand = jnp.isfinite(t_done[cand])
        w_cand = jnp.where(fired_cand, sizes[cand], 0.0)
        if alpha != 0.0:
            w_cand = w_cand * aggregator.staleness_weights(sched.age[cand])
        # traces read the PRE-fold ages (the staleness actually applied)
        part = jnp.sum(fired.astype(jnp.float32))
        stale = (jnp.sum(jnp.where(fired, sched.age, 0.0))
                 / jnp.maximum(part, 1.0))
        active = jnp.sum(sched.avail.astype(jnp.float32))
        sched = sched._replace(
            age=jnp.where(inflight & ~fired, sched.age + 1.0, 0.0),
            t_done=jnp.where(fired, jnp.inf, t_done),
            t_now=t_fire)
        state = state._replace(sched=sched)
        return (state, T, E, cand, fired_cand, w_cand, good,
                (part, stale, active))

    def train_fn(state, idx, images_sel, labels_sel):
        """O(K·P) local SGD of the host-gathered cohort data — the same
        ``train_gathered`` closure (and key split) as every other driver.
        ``idx`` only feeds the byzantine row transform (same placement as
        the dense tick: post-train, pre-staging)."""
        state, rows = ph.train_gathered(state, images_sel, labels_sel)
        if byz_pad is not None:
            rows = _byz_transform(faults, byz_pad, idx, state.params, rows)
        return state, rows

    def fire_fn(state, cand, cand_rows, w_cand, fired_cand, test_images,
                test_labels):
        """Fold the M candidate rows (staged back from the store), guard
        the empty fire, evaluate; returns the fired candidates' refreshed
        divergence, the global step norm ‖g_new − g_old‖ (exactly 0 on an
        empty fire) for the host's stats-table bookkeeping, and the
        ``ok_cand`` mask of candidates that actually refreshed (fired AND
        finite — the non-finite guard strikes the rest)."""
        ok_cand = fired_cand
        if track_faults:
            finite_c = jnp.all(jnp.isfinite(cand_rows), axis=1)
            bad_c = fired_cand & ~finite_c
            state = state._replace(sched=state.sched._replace(
                strikes=state.sched.strikes.at[cand].add(
                    bad_c.astype(jnp.float32), mode="drop")))
            w_cand = jnp.where(finite_c, w_cand, 0.0)
            ok_cand = fired_cand & finite_c
        agg_vec, agg_opt = aggregator.aggregate_flat(
            state.params, cand_rows, w_cand, state.opt_state)
        # EMPTY-FIRE GUARD — any(fired_cand) ≡ any(fired), see plan_fn
        any_fired = (jnp.any(w_cand > 0.0) if track_faults
                     else jnp.any(fired_cand))
        new_gvec = jnp.where(any_fired, agg_vec, state.params)
        new_opt = jax.tree_util.tree_map(
            lambda a, o: jnp.where(any_fired, a, o), agg_opt,
            state.opt_state)
        div_cand = ops.client_divergence(cand_rows, new_gvec)
        g_delta = jnp.linalg.norm(new_gvec - state.params)
        state = state._replace(params=new_gvec, opt_state=new_opt)
        acc, _ = eval_fn(unflatten_vector(spec, new_gvec),
                         test_images, test_labels)
        return state, acc, div_cand, g_delta, ok_cand

    return SimpleNamespace(
        N=N, M=M, spec=spec, churn_on=churn_on,
        init_channel=ph.init_channel,
        sched=jax.jit(sched_fn), plan=jax.jit(plan_fn),
        train=jax.jit(train_fn), fire=jax.jit(fire_fn))
