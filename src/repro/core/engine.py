"""RoundEngine — the jit-compiled compute core of one FL round, split out
of the host driver so experiments that share hyper-parameters (seed sweeps,
σ sweeps, selector comparisons) also share XLA executables.

The engine is pure: it owns no model/cluster/rng state, only compiled
functions keyed by an ``EngineConfig``. The host driver
(``repro.core.fedavg.FLExperiment``) owns state and strategy objects and
calls into the engine.

``round_step`` is the fused fast path — local training of the selected
clients, eq. (4) weighted aggregation, and test-set evaluation in a single
XLA program — usable whenever the aggregator is the plain weighted mean and
no lossy uplink compression is configured; the driver otherwise composes
the unfused pieces with the strategy objects in between.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CNNConfig
from repro.core.algorithms import make_fedprox_local_update
from repro.models.cnn import cnn_forward, cnn_loss, init_cnn
from repro.utils.trees import tree_weighted_mean_stacked


def make_local_update(cnn_cfg: CNNConfig, lr: float, local_iters: int,
                      batch_size: int):
    """One client's local training: L SGD steps on its own shard (Alg. 1
    lines 6-10, with the paper-endorsed SGD variant of §III-A)."""

    def local_update(params, images, labels, key):
        def step(p, k):
            idx = jax.random.randint(k, (batch_size,), 0, images.shape[0])
            batch = {"images": images[idx], "labels": labels[idx]}
            g = jax.grad(cnn_loss)(p, batch, cnn_cfg)
            p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
            return p, None

        keys = jax.random.split(key, local_iters)
        params, _ = jax.lax.scan(step, params, keys)
        return params

    return local_update


@dataclass(frozen=True)
class EngineConfig:
    """The static (compile-time) hyper-parameters of the round compute."""
    cnn_cfg: CNNConfig
    learning_rate: float
    local_iters: int
    batch_size: int
    fedprox_mu: float = 0.0


@dataclass
class RoundResult:
    """Everything one round produces (paper bookkeeping: eqs. 4, 10-11)."""
    selected: np.ndarray              # device indices that participated
    T_k: float                        # round delay [s]
    E_k: float                        # round energy [J]
    accuracy: float                   # test accuracy after aggregation
    per_class: np.ndarray             # per-class test accuracy
    params: Any = None                # new global model
    stacked_params: Any = None        # the clients' post-training models


class RoundEngine:
    """Compiled round compute, shared across experiments via ``shared``."""

    # LRU-bounded: sweeps over many distinct configs must not pin every
    # XLA executable for the process lifetime (live experiments keep their
    # own engine reference, so eviction only limits future sharing).
    _CACHE: "OrderedDict[EngineConfig, RoundEngine]" = OrderedDict()
    _CACHE_MAX = 16

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        if cfg.fedprox_mu > 0:
            local_update = make_fedprox_local_update(
                cfg.cnn_cfg, cfg.learning_rate, cfg.local_iters,
                cfg.batch_size, mu=cfg.fedprox_mu)
        else:
            local_update = make_local_update(
                cfg.cnn_cfg, cfg.learning_rate, cfg.local_iters,
                cfg.batch_size)
        self._vmapped_update = jax.vmap(local_update, in_axes=(None, 0, 0, 0))
        self.train_clients = jax.jit(self._vmapped_update)
        self.evaluate = jax.jit(functools.partial(_eval_fn,
                                                  cnn_cfg=cfg.cnn_cfg))
        self.round_step = jax.jit(self._round_step)

    @classmethod
    def shared(cls, cfg: EngineConfig) -> "RoundEngine":
        """The process-wide engine for ``cfg`` — experiments with equal
        static hyper-parameters reuse one set of XLA executables."""
        eng = cls._CACHE.get(cfg)
        if eng is None:
            eng = cls._CACHE[cfg] = cls(cfg)
            while len(cls._CACHE) > cls._CACHE_MAX:
                cls._CACHE.popitem(last=False)
        else:
            cls._CACHE.move_to_end(cfg)
        return eng

    def init_params(self, key):
        return init_cnn(self.cfg.cnn_cfg, key)

    # -- fused fast path -----------------------------------------------
    def _round_step(self, global_params, images, labels, keys, weights,
                    test_images, test_labels):
        """Train the selected clients, aggregate (eq. 4), evaluate."""
        stacked = self._vmapped_update(global_params, images, labels, keys)
        new_global = tree_weighted_mean_stacked(stacked, weights)
        acc, per_class = _eval_fn(new_global, test_images, test_labels,
                                  cnn_cfg=self.cfg.cnn_cfg)
        return stacked, new_global, acc, per_class


def _eval_fn(params, test_images, test_labels, *, cnn_cfg: CNNConfig):
    logits = cnn_forward(params, test_images, cnn_cfg)
    pred = jnp.argmax(logits, axis=-1)
    acc = jnp.mean((pred == test_labels).astype(jnp.float32))
    onehot = jax.nn.one_hot(test_labels, cnn_cfg.num_classes)
    correct = (pred == test_labels).astype(jnp.float32)[:, None] * onehot
    per_class = jnp.sum(correct, 0) / jnp.maximum(jnp.sum(onehot, 0), 1.0)
    return acc, per_class
