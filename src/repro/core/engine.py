"""RoundEngine — the jit-compiled compute core of one FL round, split out
of the host driver so experiments that share hyper-parameters (seed sweeps,
σ sweeps, selector comparisons) also share XLA executables.

The engine is pure: it owns no model/cluster/rng state, only compiled
functions keyed by an ``EngineConfig``. The host driver
(``repro.core.fedavg.FLExperiment``) owns state and strategy objects and
calls into the engine.

``round_step`` is the fused fast path — local training of the selected
clients, eq. (4) weighted aggregation, and test-set evaluation in a single
XLA program — usable whenever the aggregator is the plain weighted mean and
no lossy uplink compression is configured; the driver otherwise composes
the unfused pieces with the strategy objects in between.

``run_rounds`` goes further: when every configured strategy is traceable,
the ENTIRE experiment — initial all-device round + K-means clustering
(Alg. 2), then K rounds of select → SAO allocate → vmapped local training →
aggregate → eval — compiles to a single ``lax.scan`` program. The whole
``FLHistory`` comes back as stacked arrays in one device→host transfer, and
the same program vmaps over a cohort axis (``repro.core.cohort``).

Model weights travel on the FLAT PARAMETER PLANE (one [P] global row, one
[N, P] client buffer; ``model_flat_spec``), every per-round reduction is a
single fused row op routed through ``repro.kernels.ops``, and the scanned
carry is donated — see ``docs/PERF.md``.

At population scale (``store="paged"``) the [N, P] plane never
materializes: the driver pages a host cold store (``repro.core.store``)
and the engine only ever sees the round's ACTIVE [K, P] rows
(``gather_rows`` / ``scatter_rows`` / ``rows_divergence``) — selection
reads the O(N) per-client statistics table instead of reducing the plane.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.api.protocols import RoundState, TracedContext
from repro.core.algorithms import make_fedprox_local_update
from repro.kernels import ops
from repro.models.registry import model_def_for
from repro.utils.trees import (StackFlattenSpec, flatten_stacked,
                               stack_flatten_spec, unflatten_vector)


@functools.lru_cache(maxsize=64)
def model_flat_spec(model_cfg) -> StackFlattenSpec:
    """The flat-parameter-plane layout of ``model_cfg``'s PER-CLIENT
    trainable state — derived from shapes only (``eval_shape``), cached per
    config so every engine, driver, and traced program shares one spec
    object. ``model_cfg`` is any registered frozen model config
    (``CNNConfig`` → the full CNN pytree; ``LMConfig`` → the LoRA adapter
    tree only, so ``P = P_adapter`` across the whole plane)."""
    mdef = model_def_for(model_cfg)
    template = jax.eval_shape(functools.partial(mdef.init, model_cfg),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    return stack_flatten_spec(template)


def make_local_update(model_cfg, lr: float, local_iters: int,
                      batch_size: int):
    """One client's local training: L SGD steps on its own shard (Alg. 1
    lines 6-10, with the paper-endorsed SGD variant of §III-A). The loss
    comes from ``model_cfg``'s registered :class:`ModelDef` — for
    ``CNNConfig`` it IS the original ``cnn_loss`` function object, so the
    traced jaxpr is bit-identical to the pre-registry engine."""
    loss_fn = model_def_for(model_cfg).loss

    def local_update(params, images, labels, key):
        def step(p, k):
            idx = jax.random.randint(k, (batch_size,), 0, images.shape[0])
            batch = {"images": images[idx], "labels": labels[idx]}
            g = jax.grad(loss_fn)(p, batch, model_cfg)
            p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
            return p, None

        keys = jax.random.split(key, local_iters)
        params, _ = jax.lax.scan(step, params, keys)
        return params

    return local_update


@functools.lru_cache(maxsize=64)
def model_eval(model_cfg):
    """``(params, test_x, test_y) -> (accuracy, per_class)`` for
    ``model_cfg``'s workload (cached so every program traces one closure)."""
    mdef = model_def_for(model_cfg)
    return functools.partial(mdef.evaluate, cfg=model_cfg)


@dataclass(frozen=True)
class EngineConfig:
    """The static (compile-time) hyper-parameters of the round compute.

    ``model_cfg`` is the hashable frozen config of ANY registered workload
    (``CNNConfig``, ``LMConfig``, ...) — its value keys every compiled
    program and shared engine."""
    model_cfg: Any
    learning_rate: float
    local_iters: int
    batch_size: int
    fedprox_mu: float = 0.0


@dataclass
class RoundResult:
    """Everything one round produces (paper bookkeeping: eqs. 4, 10-11)."""
    selected: np.ndarray              # device indices that participated
    T_k: float                        # round delay [s]
    E_k: float                        # round energy [J]
    accuracy: float                   # test accuracy after aggregation
    per_class: np.ndarray             # per-class test accuracy
    params: Any = None                # new global model pytree (a copy —
                                      # safe to hold across rounds)
    stacked_params: Any = None        # the clients' post-training models as
                                      # flat [S, P] rows of the parameter
                                      # plane (unflatten_rows for pytrees)


class RoundEngine:
    """Compiled round compute, shared across experiments via ``shared``."""

    # LRU-bounded: sweeps over many distinct configs must not pin every
    # XLA executable for the process lifetime (live experiments keep their
    # own engine reference, so eviction only limits future sharing).
    _CACHE: "OrderedDict[EngineConfig, RoundEngine]" = OrderedDict()
    _CACHE_MAX = 16

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        if cfg.fedprox_mu > 0:
            local_update = make_fedprox_local_update(
                cfg.model_cfg, cfg.learning_rate, cfg.local_iters,
                cfg.batch_size, mu=cfg.fedprox_mu)
        else:
            local_update = make_local_update(
                cfg.model_cfg, cfg.learning_rate, cfg.local_iters,
                cfg.batch_size)
        self._vmapped_update = jax.vmap(local_update, in_axes=(None, 0, 0, 0))
        self.flat_spec = model_flat_spec(cfg.model_cfg)
        # train_clients has no input/output buffer alias to donate (its
        # output rows are param-shaped, its inputs are data-shaped); the
        # donation that stops the legacy path double-buffering the client
        # stack lives on scatter_rows, the store half of the round trip.
        self.train_clients = jax.jit(self._vmapped_update)
        self.evaluate = jax.jit(model_eval(cfg.model_cfg))
        # donate the global params: the new global aliases them in place
        self.round_step = jax.jit(self._round_step, donate_argnums=(0,))
        # donated in-place row scatter into the [N, P] client-weight plane
        self.scatter_rows = jax.jit(
            lambda buf, idx, rows: buf.at[idx].set(rows),
            donate_argnums=(0,))
        # active-plane row gather (the paged store ships only the round's
        # K rows to device; the dense store slices its resident plane)
        self.gather_rows = jax.jit(lambda buf, idx: buf[idx])
        # per-row divergence of an ACTIVE [K, P] block against the global
        # row — the paged driver's stats-table refresh: O(K·P) per round
        # instead of the dense select phase's O(N·P) full-plane reduction
        self.rows_divergence = jax.jit(
            lambda rows, gvec: ops.client_divergence(rows, gvec))

    @classmethod
    def shared(cls, cfg: EngineConfig) -> "RoundEngine":
        """The process-wide engine for ``cfg`` — experiments with equal
        static hyper-parameters reuse one set of XLA executables."""
        eng = cls._CACHE.get(cfg)
        if eng is None:
            eng = cls._CACHE[cfg] = cls(cfg)
            while len(cls._CACHE) > cls._CACHE_MAX:
                cls._CACHE.popitem(last=False)
        else:
            cls._CACHE.move_to_end(cfg)
        return eng

    def init_params(self, key):
        return model_def_for(self.cfg.model_cfg).init(self.cfg.model_cfg, key)

    # -- fused fast path -----------------------------------------------
    def _round_step(self, global_params, images, labels, keys, weights,
                    test_images, test_labels):
        """Train the selected clients, aggregate (eq. 4), evaluate.

        Returns the clients' post-training models as flat ``[S, P]`` rows
        of the parameter plane; aggregation is the single fused
        ``ops.flat_aggregate`` row-reduction (same numerics as the traced
        pipeline, so fused host rounds and scanned rounds agree bit for
        bit)."""
        stacked = self._vmapped_update(global_params, images, labels, keys)
        rows = flatten_stacked(stacked)
        new_global = unflatten_vector(self.flat_spec,
                                      ops.flat_aggregate(rows, weights))
        acc, per_class = model_eval(self.cfg.model_cfg)(
            new_global, test_images, test_labels)
        return rows, new_global, acc, per_class


# ---------------------------------------------------------------------------
# the device-resident round pipeline: one lax.scan over K full rounds
# ---------------------------------------------------------------------------


class RoundOutputs(NamedTuple):
    """Per-round stacked history a traced run produces ([R] / [R, S_pad];
    a cells>1 program inserts a cells axis after R). ``inr`` is the round's
    selection-driven I/N0 per cell (dynamic-interference channels only,
    None otherwise). The last three slots are the buffered-asynchronous
    engine's per-tick traces (``repro.core.async_engine``): how many
    updates the buffer folded, their mean age at fold time, and the
    churn-driven active-fleet size — None on the synchronous barrier."""
    accuracy: Any
    T: Any
    E: Any
    selected: Any
    mask: Any
    inr: Any = None
    participation: Any = None
    staleness: Any = None
    active: Any = None


class TracedRunResult(NamedTuple):
    """Everything one ``run_rounds`` call returns, still on device."""
    state: RoundState
    rounds: RoundOutputs
    # initial (all-device) round bookkeeping, or None when with_init=False
    init_accuracy: Any = None
    init_T: Any = None
    init_E: Any = None


def build_round_phases(cfg: EngineConfig, aggregator, selector, allocator,
                       compressor, tctx: TracedContext, feature_layer: str,
                       channel=None, plane: str = "full", faults=None,
                       quarantine_after: int = 0):
    """The per-round phase closures every scanned program is composed of.

    Both device-resident execution modes — the synchronous round barrier
    (:func:`_traced_round_program`) and the buffered-asynchronous tick
    loop (``repro.core.async_engine``) — build from these same closures,
    so the async engine's degenerate config (full buffer, no churn) IS
    the synchronous round body op for op, and the sync-degeneracy parity
    pin holds bit-identically by construction.

    ``plane`` selects what client state the traced carry holds:

    ``"full"``
        ``RoundState.client_params`` is the dense ``[N, P]`` buffer (the
        PR-5 layout — the dense backend degenerates to today's program,
        bit-identical): divergence is the full-plane row reduction and
        trained rows scatter into the carry.

    ``"stats"``
        The carry holds only the O(N) stats columns
        (``RoundState.sched``, a ``ClientStats`` pytree) plus whatever
        active ``[K, P]`` rows the caller gathers from its
        ``ClientStore``: ``select_phase`` reads divergence straight from
        ``sched.divergence`` (the store's refreshed table) and
        ``train_aggregate`` skips the plane scatter — persisting rows is
        the store's job at the host boundary. This is how the paged
        backend runs the scanned closures without an ``[N, P]`` buffer.

    ``aggregator`` is the resolved (possibly stateful) instance; all other
    strategies are the frozen dataclasses the program caches key on.
    Returns a namespace of pure jnp closures over the ``RoundState``
    carry: ``init_channel``/``step_channel`` (channel-state lifecycle),
    ``train_gathered`` (local SGD of already-gathered ``[S_pad, ...]``
    data → compressed flat rows — the store-agnostic core),
    ``train_rows`` (index-set wrapper over ``train_gathered``, sync-loop
    key discipline), ``train_aggregate`` (train + store + eq.-(4) masked
    aggregation), ``select_phase`` (fade → divergence → select) and
    ``init_round``/``finish_phase`` (the Alg.-2 initial round and one
    cell's allocate → train → eval round tail).

    ``faults`` (a ``repro.core.faults.FaultSpec``) arms the traced
    post-train fault phase: dispatched uploads are dropped (i.i.d.,
    channel-coupled, or past the straggler deadline), corrupted to NaN,
    or adversarially negated, with failed rows zero-weighted out of the
    fold, kept out of the client plane, and counted in the stats table's
    ``faults``/``strikes`` columns. ``quarantine_after > 0`` additionally
    filters clients with that many strikes out of every selection, like
    ``avail=False``. Either option requires the carry to hold a
    ``ClientStats`` sched table.
    """
    from repro.core.clustering import extract_features_flat, kmeans_fit
    from repro.core.divergence import weight_divergence_flat
    from repro.core.faults import (byzantine_clients, chan_outage_threshold,
                                   draw_fault_masks)
    from repro.core.wireless import completion_times

    if plane not in ("full", "stats"):
        raise ValueError(f"unknown carry plane {plane!r}; "
                         "expected 'full' or 'stats'")
    if cfg.fedprox_mu > 0:
        local_update = make_fedprox_local_update(
            cfg.model_cfg, cfg.learning_rate, cfg.local_iters, cfg.batch_size,
            mu=cfg.fedprox_mu)
    else:
        local_update = make_local_update(
            cfg.model_cfg, cfg.learning_rate, cfg.local_iters, cfg.batch_size)
    vmapped_update = jax.vmap(local_update, in_axes=(None, 0, 0, 0))
    spec = model_flat_spec(cfg.model_cfg)
    eval_fn = model_eval(cfg.model_cfg)
    N, B = tctx.num_devices, tctx.bandwidth_mhz
    channel_rng = channel is not None and getattr(channel, "needs_rng", False)
    channel_stateful = (channel is not None
                        and getattr(channel, "stateful", False))
    faults_on = faults is not None and faults.active
    track_faults = faults_on or quarantine_after > 0
    if faults_on and faults.chan_outage > 0.0 and not channel_stateful:
        raise ValueError(
            "chan_outage faults derive the drop probability from the fade "
            "state riding the carry; configure a stateful channel "
            "(e.g. 'gauss-markov')")
    byz_pad = None
    if faults_on and faults.byzantine > 0.0:
        # the fixed adversarial subset, padded with one False sentinel lane
        # so clamped out-of-bounds gathers stay honest
        byz_pad = jnp.asarray(np.concatenate(
            [byzantine_clients(faults, N), np.zeros(1, bool)]))

    def init_channel(state, arr):
        """Populate the carry's channel-state slot (one key split, only
        for stateful models — keyless/memoryless channels leave the PRNG
        stream untouched)."""
        if not channel_stateful:
            return state
        key, k0 = jax.random.split(state.key)
        return state._replace(key=key, channel=channel.init_state(k0, arr))

    def step_channel(state, arr):
        """Per-round fading: evolve the carried state (stateful models) or
        draw memorylessly (rng models); a no-op for everything else."""
        if not (channel_rng or channel_stateful):
            return state, arr
        if channel_rng:
            key, k_ch = jax.random.split(state.key)
            state = state._replace(key=key)
        else:
            k_ch = None
        if channel_stateful:
            ch_state, arr = channel.step_traced(k_ch, state.channel, arr)
            return state._replace(channel=ch_state), arr
        return state, channel.apply_traced(k_ch, arr)

    def train_gathered(state, images_sel, labels_sel):
        """Local training of already-gathered ``[S_pad, ...]`` client data
        from the current global → compressed flat [S_pad, P] rows. The
        store-agnostic core: the dense path gathers by index on device
        (``train_rows``), the paged path hands in host-paged slices —
        identical PRNG consumption either way.

        Key discipline mirrors the host loop exactly: one split off the
        stream, then per-client subkeys — a traced run and the Python loop
        consume identical PRNG sequences.
        """
        key, sub = jax.random.split(state.key)
        tkeys = jax.random.split(sub, images_sel.shape[0])
        # the one pytree excursion of the round: the CNN forward/backward
        # wants named leaves, so unflatten the global row for the vmapped
        # SGD steps and flatten the results straight back onto the plane
        params = unflatten_vector(spec, state.params)
        stacked = vmapped_update(params, images_sel, labels_sel, tkeys)
        rows = flatten_stacked(stacked)                       # [S_pad, P]
        rows = compressor.apply_flat(rows, state.params, spec)
        return state._replace(key=key), rows

    def train_rows(state, idx, images, labels):
        """Local training of the padded index set ``idx`` — device-side
        gathers clamp the out-of-bounds padding sentinel; masked later."""
        return train_gathered(state, images[idx], labels[idx])

    def inject_faults(state, idx, mask, rows, w, d=None):
        """The traced post-train fault phase: one key split, then the
        per-dispatch drop/corrupt draws, the deterministic channel-coupled
        and deadline drops, and the byzantine row transform. Returns the
        (possibly corrupted) rows, the fold weights with lost uploads
        zeroed, and ``keep`` — the lanes whose rows may persist to the
        client plane (byzantine rows persist: the adversary's state is
        real; lost and corrupted uploads never do)."""
        key, kf = jax.random.split(state.key)
        drop, corrupt = draw_fault_masks(kf, faults, idx.shape)
        if faults.chan_outage > 0.0:
            # unit-mean exponential fade power from the Gauss-Markov carry:
            # the upload fails exactly when this round's fade is deep
            gain = jnp.sum(jnp.square(state.channel), axis=-1)
            drop = drop | (gain[idx]
                           < chan_outage_threshold(faults.chan_outage))
        if faults.deadline > 0.0 and d is not None:
            drop = drop | (d > faults.deadline)
        if byz_pad is not None:
            g = state.params
            rows = jnp.where(byz_pad[idx][:, None],
                             g[None, :] - faults.byz_scale
                             * (rows - g[None, :]),
                             rows)
        if faults.corrupt > 0.0:
            rows = jnp.where(corrupt[:, None], jnp.nan, rows)
        ev = (drop | corrupt) & mask
        sched = state.sched._replace(
            faults=state.sched.faults.at[idx].add(
                ev.astype(jnp.float32), mode="drop"))
        w = jnp.where(drop, 0.0, w)
        keep = mask & ~drop & ~corrupt
        return state._replace(key=key, sched=sched), rows, w, keep

    def finite_guard(state, idx, rows, w):
        """Receive-side non-finite guard: a NaN/Inf row is zero-weighted
        out of the fold and counted as a STRIKE against its sender —
        ``quarantine_after`` strikes exclude the client from selection."""
        finite = jnp.all(jnp.isfinite(rows), axis=1)
        bad = (~finite) & (w > 0.0)
        sched = state.sched._replace(
            strikes=state.sched.strikes.at[idx].add(
                bad.astype(jnp.float32), mode="drop"))
        return state._replace(sched=sched), jnp.where(finite, w, 0.0)

    def train_aggregate(state, idx, mask, images, labels, sizes, d=None):
        """Local training of ``idx`` + store + aggregate (masked weights).
        ``mask is None`` marks the all-device initial round — fault
        injection only arms on real (masked) selections."""
        state, rows = train_rows(state, idx, images, labels)
        w = sizes[idx]
        if mask is not None:
            w = jnp.where(mask, w, 0.0)
        keep = mask
        if faults_on and mask is not None:
            state, rows, w, keep = inject_faults(state, idx, mask, rows, w,
                                                 d)
        if track_faults and mask is not None:
            state, w = finite_guard(state, idx, rows, w)
        new_gvec, opt_state = aggregator.aggregate_flat(
            state.params, rows, w, state.opt_state)
        if faults_on and mask is not None:
            # all-failed degradation: when every upload of the round was
            # lost the global row and optimizer state pass through
            # unchanged instead of folding an empty (zeroed) cohort
            any_ok = jnp.any(w > 0.0)
            new_gvec = jnp.where(any_ok, new_gvec, state.params)
            opt_state = jax.tree_util.tree_map(
                lambda new, old: jnp.where(any_ok, new, old),
                opt_state, state.opt_state)
        if plane == "full":
            # ONE scatter into the [N, P] plane; sentinel rows are out of
            # bounds -> dropped (failed uploads are re-pointed at the
            # sentinel so a lost/corrupted row never lands)
            store_idx = idx
            if faults_on and keep is not None:
                store_idx = jnp.where(keep, idx, N)
            new_client = state.client_params.at[store_idx].set(rows)
        else:
            # stats plane: the carry holds no [N, P] buffer — the caller
            # persists rows through its ClientStore at the host boundary
            new_client = state.client_params
        return state._replace(params=new_gvec, client_params=new_client,
                              opt_state=opt_state)

    def init_round(state, images, labels, sizes, arr, inr_round,
                   test_images, test_labels):
        """Round 0 (Alg. 1 line 1 + Alg. 2): all devices train, aggregate,
        K-means-cluster on the chosen feature layer, evaluate + allocate.
        ``inr_round`` (dynamic interference, all devices active) folds into
        the allocation's rate; None otherwise."""
        all_idx = jnp.arange(N)
        state = train_aggregate(state, all_idx, None, images, labels, sizes)
        feats = extract_features_flat(state.client_params, feature_layer,
                                      spec)
        key, sub = jax.random.split(state.key)
        _, k_labels, _ = kmeans_fit(sub, feats, tctx.num_clusters)
        state = state._replace(key=key, labels=k_labels.astype(jnp.int32))
        acc0, _ = eval_fn(unflatten_vector(spec, state.params),
                          test_images, test_labels)
        state, arr = step_channel(state, arr)
        if inr_round is not None:
            arr = dict(arr)
            arr["inr"] = arr["inr"] + inr_round
        T0, E0, _, _ = allocator.allocate_traced(arr, B, None)
        return state, (acc0, T0, E0)

    def select_phase(state, arr):
        """(fade →) divergence → select. The fading draw precedes
        selection so channel-aware policies (icas, rra) see the round's
        actual gains; returns the faded ``arr`` for the allocation."""
        state, arr = step_channel(state, arr)
        if not selector.needs_divergence:
            div = jnp.zeros((N,), jnp.float32)
        elif plane == "stats":
            # the store's refreshed per-client table rides the carry —
            # O(N) read, no [N, P] plane to reduce
            div = state.sched.divergence
        else:
            div = weight_divergence_flat(state.client_params, state.params)
        if selector.needs_rng:
            key, k_sel = jax.random.split(state.key)
            state = state._replace(key=key)
        else:
            k_sel = None
        idx, mask = selector.select_traced(k_sel, div, state.labels, arr,
                                           tctx)
        if quarantine_after > 0:
            # quarantine: clients with >= quarantine_after strikes are
            # filtered out of the selection exactly like avail=False
            # (same okpad pattern as the async in-flight filter)
            okpad = jnp.concatenate(
                [state.sched.strikes < float(quarantine_after),
                 jnp.zeros((1,), bool)])
            mask = mask & okpad[idx]
            idx = jnp.where(mask, idx, N).astype(idx.dtype)
        return state, arr, idx, mask

    def finish_phase(state, arr, idx, mask, inr_round, images, labels,
                     sizes, test_images, test_labels):
        """allocate → train → aggregate → eval for one cell's selection.
        ``inr_round`` adds the round's selection-driven interference on top
        of any build-time ``inr`` before the solvers fold it into J."""
        arr_sel = {k: v[idx] for k, v in arr.items()}
        if inr_round is not None:
            arr_sel["inr"] = arr_sel["inr"] + inr_round
        T, E, b_sel, f_sel = allocator.allocate_traced(arr_sel, B, mask)
        d = None
        if faults_on and faults.deadline > 0.0:
            # the same eq.-(5)+(8) pricing the async engine fires on: an
            # update past the deadline is a straggler the server abandons
            d = completion_times(arr_sel, b_sel, f_sel, mask)
        state = train_aggregate(state, idx, mask, images, labels, sizes, d)
        acc, _ = eval_fn(unflatten_vector(spec, state.params),
                         test_images, test_labels)
        return state, RoundOutputs(
            accuracy=acc, T=T, E=E, selected=idx, mask=mask,
            inr=None if inr_round is None else inr_round[0])

    return SimpleNamespace(
        spec=spec, N=N, B=B, aggregator=aggregator, plane=plane,
        init_channel=init_channel, step_channel=step_channel,
        train_gathered=train_gathered, train_rows=train_rows,
        train_aggregate=train_aggregate, init_round=init_round,
        select_phase=select_phase, finish_phase=finish_phase)


@functools.lru_cache(maxsize=32)
def _traced_round_program(cfg: EngineConfig, selector, allocator,
                          agg_name: str, agg_params: tuple, compressor,
                          tctx: TracedContext, feature_layer: str,
                          channel=None, cells: int = 1, faults=None,
                          quarantine_after: int = 0):
    """The pure (unjitted) traced experiment fn for one strategy bundle.

    All arguments are hashable trace-time constants: ``selector`` /
    ``allocator`` / ``compressor`` / ``channel`` are frozen strategy
    dataclasses and the (stateful, unhashable) aggregator travels as its
    registry spec. The cache makes sweeps over seeds/σ share one Python
    closure → one XLA program per (rounds, with_init, cohort) variant.

    ``channel`` (a registered ``ChannelModel``) redraws per-round fading
    INSIDE the scan — memoryless models via ``apply_traced``, stateful
    models (``gauss-markov``) via ``init_state``/``step_traced`` with the
    fading state riding in the ``RoundState.channel`` carry slot; a model
    with ``needs_rng=False`` and ``stateful=False`` (``static``,
    ``multicell-interference``) leaves both the PRNG stream and the
    compiled program untouched.

    ``cells > 1`` gives every per-cell argument (state, data, fleet
    arrays) a leading cells axis INSIDE one traced program: each round is
    an inner vmap over per-cell select → allocate → train → aggregate,
    with one cross-cell reduction in between when the channel is dynamic
    (``multicell-dynamic``) — each BS's I/N0 is summed from the cross-gain
    rows of the devices the OTHER cells actually selected that round.

    Model weights travel on the FLAT PARAMETER PLANE: the carry holds the
    global model as one [P] row and all N client models as one [N, P]
    buffer (layout = ``model_flat_spec(cfg.model_cfg)``). Local training
    gathers the selected rows' data, unflattens the global row to the
    workload's trainable pytree for the vmapped SGD steps, then flattens
    the results back — so
    weight divergence is ONE fused row-norm reduction, eq.-(4) aggregation
    ONE masked weighted row-reduction (``ops.flat_aggregate``), K-means
    features a zero-copy column slice, and compression a per-row segment
    op; no per-leaf ``tree_map`` survives in the round body.
    """
    from repro.api.registry import AGGREGATORS

    aggregator = AGGREGATORS.resolve({"name": agg_name,
                                      "params": dict(agg_params)})
    ph = build_round_phases(cfg, aggregator, selector, allocator, compressor,
                            tctx, feature_layer, channel, faults=faults,
                            quarantine_after=quarantine_after)
    N = ph.N
    track_faults = ((faults is not None and faults.active)
                    or quarantine_after > 0)
    init_channel, init_round = ph.init_channel, ph.init_round
    select_phase, finish_phase = ph.select_phase, ph.finish_phase
    dynamic = (cells > 1 and channel is not None
               and getattr(channel, "dynamic", False))

    def run(state, images, labels, sizes, arr, test_images, test_labels,
            rounds: int, with_init: bool):
        arr = dict(arr)
        xg = arr.pop("xgain", None)          # [(cells,) N, C] cross gains

        if cells == 1:
            # ---- single-cell layout (the PR-2 scanned program) --------
            state = init_channel(state, arr)
            if track_faults and state.sched is None:
                # fault counters / quarantine need the stats table riding
                # the carry; the cohort path has no host table to ship in
                from repro.core.store import ClientStats
                state = state._replace(sched=ClientStats.create_traced(N))
            init_out = None
            if with_init:
                state, init_out = init_round(state, images, labels, sizes,
                                             arr, None, test_images,
                                             test_labels)

            def step(s, _):
                s, arr_f, idx, mask = select_phase(s, arr)
                return finish_phase(s, arr_f, idx, mask, None, images,
                                    labels, sizes, test_images, test_labels)
        else:
            # ---- cells axis inside the program: inner vmap over cells,
            # one cross-cell interference reduction per round ------------
            state = jax.vmap(init_channel)(state, arr)

            def cell_inr(part):
                """[C, N] participation → [C, 1] I/N0 at each BS (summed
                selected cross-gain rows; own-cell columns are 0)."""
                return jnp.einsum("cn,cnk->k", part, xg)[:, None]

            def dense_part(idx, mask):
                """Scatter each cell's padded selection to a dense [C, N]
                participation map (the OOB sentinel lanes drop)."""
                return jax.vmap(
                    lambda i, m: jnp.zeros((N,), jnp.float32)
                    .at[i].add(m.astype(jnp.float32), mode="drop"))(idx, mask)

            sel_v = jax.vmap(select_phase)
            fin_v = jax.vmap(finish_phase,
                             in_axes=(0, 0, 0, 0, 0 if dynamic else None,
                                      0, 0, 0, None, None))
            init_v = jax.vmap(init_round,
                              in_axes=(0, 0, 0, 0, 0,
                                       0 if dynamic else None, None, None))

            init_out = None
            if with_init:
                inr0 = (cell_inr(jnp.ones((cells, N), jnp.float32))
                        if dynamic else None)
                state, init_out = init_v(state, images, labels, sizes, arr,
                                         inr0, test_images, test_labels)

            def step(s, _):
                s, arr_f, idx, mask = sel_v(s, arr)
                inr_r = (cell_inr(dense_part(idx, mask))
                         if dynamic else None)
                return fin_v(s, arr_f, idx, mask, inr_r, images, labels,
                             sizes, test_images, test_labels)

        state, outs = lax.scan(step, state, None, length=rounds)
        if init_out is None:
            return TracedRunResult(state=state, rounds=outs)
        acc0, T0, E0 = init_out
        return TracedRunResult(state=state, rounds=outs, init_accuracy=acc0,
                               init_T=T0, init_E=E0)

    return run


# LRU-bounded like RoundEngine._CACHE: sweeps over many distinct
# (strategies, rounds) combos must not pin every XLA executable forever.
_RUN_FN_CACHE: "OrderedDict[tuple, Any]" = OrderedDict()
_RUN_FN_CACHE_MAX = 64


def aggregator_cache_key(aggregator) -> tuple:
    """Hashable identity of a (possibly stateful) aggregator instance."""
    return (aggregator.registry_name,
            tuple(sorted(aggregator.params().items())))


def run_rounds(cfg: EngineConfig, *, selector, allocator, aggregator,
               compressor, tctx: TracedContext, feature_layer: str,
               rounds: int, with_init: bool, cohort: bool = False,
               test_shared: bool = True, mesh=None, channel=None,
               cells: int = 1, churn=None, faults=None,
               quarantine_after: int = 0):
    """The compiled multi-round experiment fn for one strategy bundle.

    Returns a jitted callable
    ``(state, images, labels, sizes, arr, test_images, test_labels)
    -> TracedRunResult`` executing ``rounds`` full FL rounds as ONE XLA
    program (plus the Alg.-2 initial round when ``with_init``). With
    ``cohort=True`` every data/state argument gains a leading cohort axis
    (vmapped) — the ``CohortRunner`` path; ``test_shared`` keeps the
    evaluation set un-mapped (one copy across the cohort).

    ``cells > 1`` declares a cells axis INSIDE the program, right after
    the cohort axis: per-cell state/data leaves are ``[C, ...]`` (or
    ``[cohort, C, ...]``), each round inner-vmaps over the cells, and a
    dynamic-interference channel couples them through one cross-cell
    reduction per round. The evaluation set is always cell-shared.

    ``mesh`` (a 1-axis ``jax.sharding.Mesh`` named ``"cohort"``) splits the
    cohort axis across local devices via ``shard_map``: each device runs
    its slice of seeds as an independent per-shard vmap — embarrassingly
    parallel, no cross-device collectives inside the round.

    Compiled callables are cached process-wide, so sweeps that differ only
    in seed/data reuse one executable.

    The ``state`` argument is DONATED (``donate_argnums=(0,)``): its
    buffers — notably the ``[cohort, N, P]`` flat client plane — are
    reused in place for the returned state, so pass freshly-built (or
    no-longer-needed) arrays and rebind every reference from the result.

    An ASYNC-CAPABLE aggregator (``fedbuff:M[:alpha]``) swaps the round
    barrier for the buffered-asynchronous tick loop
    (``repro.core.async_engine``) — same signature, same single scanned
    program, but rounds become virtual-time ticks and ``churn`` (a
    ``(p_leave, p_join)`` pair of per-tick Bernoulli probabilities) may
    flip the per-client availability mask riding the carry.
    """
    churn_t = ((0.0, 0.0) if churn is None
               else (float(churn[0]), float(churn[1])))
    is_async = getattr(aggregator, "async_capable", False)
    if not is_async and churn_t != (0.0, 0.0):
        raise ValueError(
            "client churn is a property of the buffered-asynchronous "
            "engine; configure an async-capable aggregator "
            "(e.g. 'fedbuff:4') to enable it")
    if is_async and cells > 1:
        raise ValueError(
            "the buffered-asynchronous engine runs single-cell programs "
            "only; run multi-cell fleets with a synchronous aggregator")
    track_faults = ((faults is not None and faults.active)
                    or quarantine_after > 0)
    if track_faults and cells > 1:
        raise ValueError(
            "fault injection / quarantine runs single-cell programs only")
    mesh_key = (None if mesh is None
                else tuple(d.id for d in mesh.devices.flat))
    key = (cfg, selector, allocator, aggregator_cache_key(aggregator),
           compressor, tctx, feature_layer, rounds, with_init, cohort,
           test_shared, mesh_key, channel, cells, churn_t, faults,
           quarantine_after)
    fn = _RUN_FN_CACHE.get(key)
    if fn is None:
        if is_async:
            from repro.core.async_engine import _traced_async_program
            prog = _traced_async_program(
                cfg, selector, allocator, aggregator.registry_name,
                tuple(sorted(aggregator.params().items())), compressor,
                tctx, feature_layer, channel, churn_t, faults,
                quarantine_after)
        else:
            prog = _traced_round_program(
                cfg, selector, allocator, aggregator.registry_name,
                tuple(sorted(aggregator.params().items())), compressor,
                tctx, feature_layer, channel, cells, faults,
                quarantine_after)
        core = functools.partial(prog, rounds=rounds, with_init=with_init)
        if cohort:
            test_ax = None if test_shared else 0
            core = jax.vmap(core, in_axes=(0, 0, 0, 0, 0, test_ax, test_ax))
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                data_spec = P("cohort")
                test_spec = P() if test_shared else P("cohort")
                core = shard_map(
                    core, mesh=mesh,
                    in_specs=(data_spec,) * 5 + (test_spec, test_spec),
                    out_specs=data_spec, check_rep=False)
        # donate the carry: the (possibly [cohort, N, P]-sized) RoundState
        # buffers update in place across dispatches instead of double-
        # buffering — callers must treat the passed-in state as consumed
        # (FLExperiment/CohortRunner immediately replace their references
        # from the returned state)
        fn = _RUN_FN_CACHE[key] = jax.jit(core, donate_argnums=(0,))
        while len(_RUN_FN_CACHE) > _RUN_FN_CACHE_MAX:
            _RUN_FN_CACHE.popitem(last=False)
    else:
        _RUN_FN_CACHE.move_to_end(key)
    return fn
