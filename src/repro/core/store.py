"""The client parameter store — dense device plane or paged active/cold
split — behind one ``ClientStore`` contract, plus the per-client
statistics table.

The paper's regime is N ≫ K: "a large number of wireless mobile devices"
of which only K≪N train per round. The PR-5 flat ``[N, P]`` plane makes
every round O(N·P) in memory even when K=10; at N=1e6 and the paper CNN's
P≈1e5 that is a 400 GB buffer. This module splits the store:

``DenseStore``
    The PR-5 layout verbatim: one device-resident ``[N, P]`` buffer,
    donated in-place row scatter. The default (``store="dense"``), pinned
    bit-identical to the pre-split driver.

``PagedStore``
    Host-resident cold store. All clients start equal to the broadcast
    ``base`` row (one ``[P]`` vector — the post-init global), so the store
    begins O(P) regardless of N. Trained rows land in a sparse overlay
    (``{client: [P] row}``); once a ``chunk_size``-aligned block has
    enough touched rows the overlay promotes to a dense ``[chunk, P]``
    block. Reads assemble any range on demand (``iter_chunks``), so the
    full plane never materializes: peak memory is
    O(#touched·P + chunk·P), and an untrained million-client fleet costs
    one row. Device traffic is only the K gathered/scattered rows of the
    round's cohort — the active plane.

``ClientStats``
    The compact ``[N]`` table (divergence, divergence-staleness drift
    bound, age, in-flight completion time, availability, cell id, and the
    scheduler's virtual clock) that is the ONLY O(N) state any driver
    keeps hot: selectors read it instead of reducing the ``[N, P]`` plane
    (cf. Perazzone et al., arXiv 2201.07912, which schedules
    million-device fleets from per-client scalars). It is a NamedTuple —
    hence a JAX pytree — so the same table serves as the host-side truth
    (numpy leaves, mutated in place) and as the async engine's traced
    scheduler carry (``RoundState.sched``, device leaves). There is no
    second bookkeeping structure: the async tick loop and the paged host
    loop read and write the same columns.

Both stores expose the same contract (``ClientStore``): ``gather(idx)``
returns the ``[K, P]`` active rows, ``scatter(idx, rows)`` persists
trained rows (donated in-place on dense, host write-back on paged),
``stats`` is the single source of per-client truth, and the staging API
(``stage`` / ``gather_staged`` / ``release_staged``) keeps in-flight rows
warm on device between an async dispatch and the buffered fire that
consumes them.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterator, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ClientStats", "ClientStore", "DenseStore", "PagedStore",
           "build_store"]


class ClientStats(NamedTuple):
    """Per-client scalar statistics — O(N) total, one table for every
    driver.

    ``divergence`` is ‖w_n − w_g‖ as of each client's last refresh;
    ``drift`` bounds its staleness: the accumulated ‖g_now − g_ref‖ since
    that refresh, so the true divergence lies within ``divergence ±
    drift`` (triangle inequality). ``age`` counts rounds (sync) or fire
    events (async) since the client last contributed; ``t_done`` is the
    virtual completion time of the client's in-flight update (+inf when
    idle — finiteness IS the in-flight flag); ``avail`` is the churn mask
    selection filters on; ``cell`` records the serving cell; ``faults``
    counts fault events charged to the client (lost/corrupted/deadline-
    dropped uploads — the O(N) fault-counter column); ``strikes`` counts
    non-finite payloads detected at the fold — once it reaches
    ``quarantine_after`` the client is excluded from selection exactly
    like ``avail=False``; ``t_now`` is the scheduler's virtual clock
    (0-d scalar).

    As a NamedTuple this is a JAX pytree: the async engine carries it
    through ``lax.scan`` with device leaves, while the host drivers keep
    a numpy-leaved instance and mutate columns in place
    (``stats.avail[gone] = False``). ``device()`` / ``load()`` convert
    between the two without ever rebinding fields.
    """
    divergence: np.ndarray            # [N] f32  ‖w_n − w_g‖ at last refresh
    drift: np.ndarray                 # [N] f32  staleness bound on divergence
    age: np.ndarray                   # [N] f32  rounds/fires since contribution
    t_done: np.ndarray                # [N] f32  in-flight completion (+inf idle)
    avail: np.ndarray                 # [N] bool churn/availability mask
    cell: np.ndarray                  # [N] i32  serving cell id
    faults: np.ndarray                # [N] f32  fault events charged
    strikes: np.ndarray               # [N] f32  non-finite payloads caught
    t_now: np.ndarray                 # []  f32  scheduler virtual clock

    @classmethod
    def create(cls, num_clients: int, cell: int = 0) -> "ClientStats":
        return cls(divergence=np.zeros(num_clients, np.float32),
                   drift=np.zeros(num_clients, np.float32),
                   age=np.zeros(num_clients, np.float32),
                   t_done=np.full(num_clients, np.inf, np.float32),
                   avail=np.ones(num_clients, bool),
                   cell=np.full(num_clients, cell, np.int32),
                   faults=np.zeros(num_clients, np.float32),
                   strikes=np.zeros(num_clients, np.float32),
                   t_now=np.zeros((), np.float32))

    @classmethod
    def create_traced(cls, num_clients: int, cell: int = 0) -> "ClientStats":
        """The same fresh table with device leaves — constructible inside
        a traced program (the cohort path has no host table to ship in)."""
        return cls(divergence=jnp.zeros(num_clients, jnp.float32),
                   drift=jnp.zeros(num_clients, jnp.float32),
                   age=jnp.zeros(num_clients, jnp.float32),
                   t_done=jnp.full(num_clients, jnp.inf, jnp.float32),
                   avail=jnp.ones(num_clients, bool),
                   cell=jnp.full(num_clients, cell, jnp.int32),
                   faults=jnp.zeros(num_clients, jnp.float32),
                   strikes=jnp.zeros(num_clients, jnp.float32),
                   t_now=jnp.zeros((), jnp.float32))

    def device(self) -> "ClientStats":
        """A device-leaved copy — the traced scheduler carry."""
        return jax.tree_util.tree_map(jnp.asarray, self)

    def load(self, other: "ClientStats") -> None:
        """Copy ``other``'s columns into this table IN PLACE (no field
        rebinding) — the end-of-scan carry folding back into the host
        source of truth."""
        for dst, src in zip(self, other):
            np.copyto(dst, np.asarray(src))

    @property
    def nbytes(self) -> int:
        return int(sum(np.asarray(leaf).nbytes for leaf in self))


class ClientStore(Protocol):
    """What every driver — host round loop, scanned cohort, async tick
    engine — consumes. ``stats`` is the single source of per-client
    truth; there is no driver-private copy of age/availability."""

    kind: str
    stats: ClientStats

    @property
    def num_clients(self) -> int: ...

    @property
    def row_size(self) -> int: ...

    def gather(self, idx) -> jnp.ndarray:
        """``[K, P]`` device rows for ``idx`` — the active plane."""
        ...

    def scatter(self, idx, rows) -> None:
        """Persist trained rows (rows may be donated on dense)."""
        ...

    def iter_chunks(self, chunk_size: int) -> Iterator[np.ndarray]:
        """Stream the (virtual) plane as host blocks."""
        ...

    # -- device staging for in-flight rows (async dispatch → fire) -----
    def stage(self, idx, rows) -> None:
        """Persist ``rows`` AND keep them warm on device until released."""
        ...

    def gather_staged(self, idx) -> jnp.ndarray:
        """Like ``gather`` but serves staged rows from device."""
        ...

    def release_staged(self, idx) -> None:
        """Drop the device copies of ``idx`` (their update fired)."""
        ...

    @property
    def nbytes(self) -> int: ...


class DenseStore:
    """The PR-5 device-resident ``[N, P]`` plane behind the store API."""

    kind = "dense"

    def __init__(self, base_row: jnp.ndarray, num_clients: int, engine,
                 cell: int = 0):
        self._engine = engine
        # identical construction to the pre-split driver: broadcast the
        # global row, one copy (bit-parity anchor for the tier-1 pins)
        self.buffer = jnp.broadcast_to(
            base_row, (num_clients, base_row.shape[0])).copy()
        self.stats = ClientStats.create(num_clients, cell)

    @property
    def num_clients(self) -> int:
        return self.buffer.shape[0]

    @property
    def row_size(self) -> int:
        return self.buffer.shape[1]

    def gather(self, idx) -> jnp.ndarray:
        return self.buffer[jnp.asarray(np.asarray(idx))]

    def scatter(self, idx, rows) -> None:
        """Donated in-place row scatter (the engine's jitted op)."""
        self.buffer = self._engine.scatter_rows(
            self.buffer, jnp.asarray(np.asarray(idx)), rows)

    def iter_chunks(self, chunk_size: int) -> Iterator[np.ndarray]:
        for start in range(0, self.num_clients, chunk_size):
            yield np.asarray(self.buffer[start:start + chunk_size])

    # the whole plane lives on device, so staging degenerates: every row
    # is already "warm" and release is a no-op
    def stage(self, idx, rows) -> None:
        self.scatter(idx, rows)

    def gather_staged(self, idx) -> jnp.ndarray:
        return self.gather(idx)

    def release_staged(self, idx) -> None:
        pass

    @property
    def nbytes(self) -> int:
        return int(self.buffer.size) * 4


class PagedStore:
    """Host-paged cold store: base row + sparse overlay + dense blocks."""

    kind = "paged"

    #: promote a chunk's overlay rows to a dense block once this fraction
    #: of the chunk has been touched (dict-of-rows beats a block below it,
    #: a block beats per-row dict lookups above it)
    PROMOTE_FRAC = 0.5

    def __init__(self, base_row: np.ndarray, num_clients: int,
                 chunk_size: int, cell: int = 0,
                 stage_rows: Optional[int] = None):
        self.base = np.ascontiguousarray(base_row, dtype=np.float32)
        self.n = int(num_clients)
        self.chunk = int(chunk_size)
        if self.chunk <= 0:
            raise ValueError(f"chunk_size must be positive; got {chunk_size}")
        self._rows: Dict[int, np.ndarray] = {}        # sparse overlay
        self._blocks: Dict[int, np.ndarray] = {}      # chunk id -> [c, P]
        self.touched = np.zeros(self.n, bool)
        self.stats = ClientStats.create(self.n, cell)
        # device LRU of in-flight rows: async dispatch stages here so the
        # buffered fire reads the EXACT device values back without a host
        # round-trip (f32 round-trips are value-preserving, so a cache
        # miss is a perf fallback, never a correctness change). Bounded at
        # ``stage_rows`` rows — O(k_max·P) device memory.
        self.stage_rows = int(stage_rows) if stage_rows else 0
        self._staged: "OrderedDict[int, jnp.ndarray]" = OrderedDict()

    # -- geometry ------------------------------------------------------
    @property
    def num_clients(self) -> int:
        return self.n

    @property
    def row_size(self) -> int:
        return self.base.shape[0]

    def _bounds(self, cid: int):
        start = cid * self.chunk
        return start, min(start + self.chunk, self.n)

    # -- reads ---------------------------------------------------------
    def row(self, i: int) -> np.ndarray:
        cid = i // self.chunk
        block = self._blocks.get(cid)
        if block is not None:
            return block[i - cid * self.chunk]
        r = self._rows.get(i)
        return self.base if r is None else r

    def gather(self, idx) -> jnp.ndarray:
        """Assemble the rows of ``idx`` and ship them to device —
        the active plane's O(K·P) read."""
        idx = np.asarray(idx, np.int64).ravel()
        out = np.empty((idx.shape[0], self.row_size), np.float32)
        for j, i in enumerate(idx):
            out[j] = self.row(int(i))
        return jnp.asarray(out)

    def assemble(self, start: int, stop: int) -> np.ndarray:
        """Materialize rows ``[start, stop)`` as one contiguous block."""
        stop = min(stop, self.n)
        cid0 = start // self.chunk
        if (cid0 in self._blocks and start == cid0 * self.chunk
                and stop == min(start + self.chunk, self.n)):
            return self._blocks[cid0]
        out = np.broadcast_to(self.base, (stop - start, self.row_size)).copy()
        lo, hi = start // self.chunk, (max(stop - 1, start)) // self.chunk
        for cid in range(lo, hi + 1):
            block = self._blocks.get(cid)
            if block is None:
                continue
            b0, b1 = self._bounds(cid)
            s, e = max(b0, start), min(b1, stop)
            out[s - start:e - start] = block[s - b0:e - b0]
        if self._rows:
            for i in range(start, stop):
                r = self._rows.get(i)
                if r is not None:
                    out[i - start] = r
        return out

    def iter_chunks(self, chunk_size: Optional[int] = None
                    ) -> Iterator[np.ndarray]:
        """Stream the whole (virtual) plane as assembled blocks — the
        input shape ``ops.chunked_client_divergence`` / ``chunked_pairwise``
        consume. Never holds more than one block."""
        c = self.chunk if chunk_size is None else int(chunk_size)
        for start in range(0, self.n, c):
            yield self.assemble(start, start + c)

    # -- writes --------------------------------------------------------
    def scatter(self, idx, rows) -> None:
        """Write trained rows back to the cold store (device → host copy;
        the donated on-device scatter has no target here — the plane it
        would write into intentionally does not exist)."""
        idx = np.asarray(idx, np.int64).ravel()
        rows = np.asarray(rows, dtype=np.float32)
        if rows.ndim != 2 or rows.shape[0] != idx.shape[0]:
            raise ValueError(f"scatter: rows {rows.shape} do not match "
                             f"idx {idx.shape}")
        dirty_chunks = set()
        for j, i in enumerate(idx):
            i = int(i)
            cid = i // self.chunk
            block = self._blocks.get(cid)
            if block is not None:
                block[i - cid * self.chunk] = rows[j]
            else:
                self._rows[i] = rows[j].copy()
                dirty_chunks.add(cid)
        self.touched[idx] = True
        for cid in dirty_chunks:
            self._maybe_promote(cid)

    def _maybe_promote(self, cid: int) -> None:
        b0, b1 = self._bounds(cid)
        if self.touched[b0:b1].sum() < self.PROMOTE_FRAC * (b1 - b0):
            return
        block = np.broadcast_to(self.base,
                                (b1 - b0, self.row_size)).copy()
        for i in range(b0, b1):
            r = self._rows.pop(i, None)
            if r is not None:
                block[i - b0] = r
        self._blocks[cid] = block

    # -- device staging ------------------------------------------------
    def stage(self, idx, rows) -> None:
        """Write-through: persist to the cold store AND keep the device
        rows warm (LRU, ≤ ``stage_rows``) so the fire that consumes them
        skips the host round-trip."""
        idx_h = np.asarray(idx, np.int64).ravel()
        self.scatter(idx_h, rows)
        if not self.stage_rows:
            return
        for j, i in enumerate(idx_h):
            i = int(i)
            self._staged.pop(i, None)
            self._staged[i] = rows[j]
        while len(self._staged) > self.stage_rows:
            self._staged.popitem(last=False)

    def gather_staged(self, idx) -> jnp.ndarray:
        idx_h = np.asarray(idx, np.int64).ravel()
        if not self._staged:
            return self.gather(idx_h)
        parts = [self._staged.get(int(i)) for i in idx_h]
        if all(p is not None for p in parts):
            return jnp.stack(parts)
        cold = self.gather(idx_h)
        return jnp.stack([cold[j] if p is None else p
                          for j, p in enumerate(parts)])

    def release_staged(self, idx) -> None:
        for i in np.asarray(idx, np.int64).ravel():
            self._staged.pop(int(i), None)

    # -- accounting ----------------------------------------------------
    @property
    def num_touched(self) -> int:
        return int(self.touched.sum())

    @property
    def nbytes(self) -> int:
        return (self.base.nbytes
                + sum(r.nbytes for r in self._rows.values())
                + sum(b.nbytes for b in self._blocks.values())
                + self.touched.nbytes)


def build_store(kind: str, base_row, num_clients: int, engine,
                chunk_size: int, cell: int = 0,
                stage_rows: Optional[int] = None):
    if kind == "dense":
        return DenseStore(base_row, num_clients, engine, cell)
    if kind == "paged":
        return PagedStore(np.asarray(base_row), num_clients, chunk_size,
                          cell, stage_rows)
    raise ValueError(f"unknown client store {kind!r}; "
                     "expected 'dense' or 'paged'")
