"""The client parameter store — dense device plane or paged active/cold
split — plus the per-client statistics table.

The paper's regime is N ≫ K: "a large number of wireless mobile devices"
of which only K≪N train per round. The PR-5 flat ``[N, P]`` plane makes
every round O(N·P) in memory even when K=10; at N=1e6 and the paper CNN's
P≈1e5 that is a 400 GB buffer. This module splits the store:

``DenseStore``
    The PR-5 layout verbatim: one device-resident ``[N, P]`` buffer,
    donated in-place row scatter. The default (``store="dense"``), pinned
    bit-identical to the pre-split driver.

``PagedStore``
    Host-resident cold store. All clients start equal to the broadcast
    ``base`` row (one ``[P]`` vector — the post-init global), so the store
    begins O(P) regardless of N. Trained rows land in a sparse overlay
    (``{client: [P] row}``); once a ``chunk_size``-aligned block has
    enough touched rows the overlay promotes to a dense ``[chunk, P]``
    block. Reads assemble any range on demand (``iter_chunks``), so the
    full plane never materializes: peak memory is
    O(#touched·P + chunk·P), and an untrained million-client fleet costs
    one row. Device traffic is only the K gathered/scattered rows of the
    round's cohort — the active plane.

``ClientStats``
    The compact ``[N]`` table (divergence, divergence-staleness drift
    bound, age, availability, cell id) that is the ONLY O(N) state the
    paged round loop keeps hot: selectors read it instead of reducing the
    ``[N, P]`` plane (cf. Perazzone et al., arXiv 2201.07912, which
    schedules million-device fleets from per-client scalars).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["ClientStats", "DenseStore", "PagedStore", "build_store"]


@dataclass
class ClientStats:
    """Per-client scalar statistics — O(N) total, all host numpy.

    ``divergence`` is ‖w_n − w_g‖ as of each client's last refresh;
    ``drift`` bounds its staleness: the accumulated ‖g_now − g_ref‖ since
    that refresh, so the true divergence lies within ``divergence ±
    drift`` (triangle inequality). ``age`` counts rounds since the client
    last trained; ``avail`` is the churn mask the paged loop flips and
    selection filters on; ``cell`` records the serving cell.
    """
    divergence: np.ndarray            # [N] f32
    drift: np.ndarray                 # [N] f32 staleness bound on divergence
    age: np.ndarray                   # [N] i32 rounds since participation
    avail: np.ndarray                 # [N] bool churn/availability mask
    cell: np.ndarray                  # [N] i32 serving cell id

    @classmethod
    def create(cls, num_clients: int, cell: int = 0) -> "ClientStats":
        return cls(divergence=np.zeros(num_clients, np.float32),
                   drift=np.zeros(num_clients, np.float32),
                   age=np.zeros(num_clients, np.int32),
                   avail=np.ones(num_clients, bool),
                   cell=np.full(num_clients, cell, np.int32))

    @property
    def nbytes(self) -> int:
        return (self.divergence.nbytes + self.drift.nbytes + self.age.nbytes
                + self.avail.nbytes + self.cell.nbytes)


class DenseStore:
    """The PR-5 device-resident ``[N, P]`` plane behind the store API."""

    kind = "dense"

    def __init__(self, base_row: jnp.ndarray, num_clients: int, engine):
        self._engine = engine
        # identical construction to the pre-split driver: broadcast the
        # global row, one copy (bit-parity anchor for the tier-1 pins)
        self.buffer = jnp.broadcast_to(
            base_row, (num_clients, base_row.shape[0])).copy()

    @property
    def num_clients(self) -> int:
        return self.buffer.shape[0]

    @property
    def row_size(self) -> int:
        return self.buffer.shape[1]

    def gather(self, idx) -> jnp.ndarray:
        return self.buffer[jnp.asarray(np.asarray(idx))]

    def scatter(self, idx, rows) -> None:
        """Donated in-place row scatter (the engine's jitted op)."""
        self.buffer = self._engine.scatter_rows(
            self.buffer, jnp.asarray(np.asarray(idx)), rows)

    def iter_chunks(self, chunk_size: int) -> Iterator[np.ndarray]:
        for start in range(0, self.num_clients, chunk_size):
            yield np.asarray(self.buffer[start:start + chunk_size])

    @property
    def nbytes(self) -> int:
        return int(self.buffer.size) * 4


class PagedStore:
    """Host-paged cold store: base row + sparse overlay + dense blocks."""

    kind = "paged"

    #: promote a chunk's overlay rows to a dense block once this fraction
    #: of the chunk has been touched (dict-of-rows beats a block below it,
    #: a block beats per-row dict lookups above it)
    PROMOTE_FRAC = 0.5

    def __init__(self, base_row: np.ndarray, num_clients: int,
                 chunk_size: int):
        self.base = np.ascontiguousarray(base_row, dtype=np.float32)
        self.n = int(num_clients)
        self.chunk = int(chunk_size)
        if self.chunk <= 0:
            raise ValueError(f"chunk_size must be positive; got {chunk_size}")
        self._rows: Dict[int, np.ndarray] = {}        # sparse overlay
        self._blocks: Dict[int, np.ndarray] = {}      # chunk id -> [c, P]
        self.touched = np.zeros(self.n, bool)

    # -- geometry ------------------------------------------------------
    @property
    def num_clients(self) -> int:
        return self.n

    @property
    def row_size(self) -> int:
        return self.base.shape[0]

    def _bounds(self, cid: int):
        start = cid * self.chunk
        return start, min(start + self.chunk, self.n)

    # -- reads ---------------------------------------------------------
    def row(self, i: int) -> np.ndarray:
        cid = i // self.chunk
        block = self._blocks.get(cid)
        if block is not None:
            return block[i - cid * self.chunk]
        r = self._rows.get(i)
        return self.base if r is None else r

    def gather(self, idx) -> jnp.ndarray:
        """Assemble the rows of ``idx`` and ship them to device —
        the active plane's O(K·P) read."""
        idx = np.asarray(idx, np.int64).ravel()
        out = np.empty((idx.shape[0], self.row_size), np.float32)
        for j, i in enumerate(idx):
            out[j] = self.row(int(i))
        return jnp.asarray(out)

    def assemble(self, start: int, stop: int) -> np.ndarray:
        """Materialize rows ``[start, stop)`` as one contiguous block."""
        stop = min(stop, self.n)
        cid0 = start // self.chunk
        if (cid0 in self._blocks and start == cid0 * self.chunk
                and stop == min(start + self.chunk, self.n)):
            return self._blocks[cid0]
        out = np.broadcast_to(self.base, (stop - start, self.row_size)).copy()
        lo, hi = start // self.chunk, (max(stop - 1, start)) // self.chunk
        for cid in range(lo, hi + 1):
            block = self._blocks.get(cid)
            if block is None:
                continue
            b0, b1 = self._bounds(cid)
            s, e = max(b0, start), min(b1, stop)
            out[s - start:e - start] = block[s - b0:e - b0]
        if self._rows:
            for i in range(start, stop):
                r = self._rows.get(i)
                if r is not None:
                    out[i - start] = r
        return out

    def iter_chunks(self, chunk_size: Optional[int] = None
                    ) -> Iterator[np.ndarray]:
        """Stream the whole (virtual) plane as assembled blocks — the
        input shape ``ops.chunked_client_divergence`` / ``chunked_pairwise``
        consume. Never holds more than one block."""
        c = self.chunk if chunk_size is None else int(chunk_size)
        for start in range(0, self.n, c):
            yield self.assemble(start, start + c)

    # -- writes --------------------------------------------------------
    def scatter(self, idx, rows) -> None:
        """Write trained rows back to the cold store (device → host copy;
        the donated on-device scatter has no target here — the plane it
        would write into intentionally does not exist)."""
        idx = np.asarray(idx, np.int64).ravel()
        rows = np.asarray(rows, dtype=np.float32)
        if rows.ndim != 2 or rows.shape[0] != idx.shape[0]:
            raise ValueError(f"scatter: rows {rows.shape} do not match "
                             f"idx {idx.shape}")
        dirty_chunks = set()
        for j, i in enumerate(idx):
            i = int(i)
            cid = i // self.chunk
            block = self._blocks.get(cid)
            if block is not None:
                block[i - cid * self.chunk] = rows[j]
            else:
                self._rows[i] = rows[j].copy()
                dirty_chunks.add(cid)
        self.touched[idx] = True
        for cid in dirty_chunks:
            self._maybe_promote(cid)

    def _maybe_promote(self, cid: int) -> None:
        b0, b1 = self._bounds(cid)
        if self.touched[b0:b1].sum() < self.PROMOTE_FRAC * (b1 - b0):
            return
        block = np.broadcast_to(self.base,
                                (b1 - b0, self.row_size)).copy()
        for i in range(b0, b1):
            r = self._rows.pop(i, None)
            if r is not None:
                block[i - b0] = r
        self._blocks[cid] = block

    # -- accounting ----------------------------------------------------
    @property
    def num_touched(self) -> int:
        return int(self.touched.sum())

    @property
    def nbytes(self) -> int:
        return (self.base.nbytes
                + sum(r.nbytes for r in self._rows.values())
                + sum(b.nbytes for b in self._blocks.values())
                + self.touched.nbytes)


def build_store(kind: str, base_row, num_clients: int, engine,
                chunk_size: int):
    if kind == "dense":
        return DenseStore(base_row, num_clients, engine)
    if kind == "paged":
        return PagedStore(np.asarray(base_row), num_clients, chunk_size)
    raise ValueError(f"unknown client store {kind!r}; "
                     "expected 'dense' or 'paged'")
