"""CohortRunner — vmapped (seeds × cells) cohorts over the device-resident
round pipeline.

The paper's headline figures are all sweeps (many seeds × selectors × σ);
with the whole experiment traced (``engine.run_rounds``), a cohort of
seeds/fleet draws becomes ONE compiled program: the per-seed
state/data/fleet pytrees are stacked on a leading cohort axis, ``vmap``
maps the scanned multi-round run over it, and ``jax.sharding`` splits that
axis across the local devices. One dispatch, one device→host transfer for
the entire cohort history.

Multi-cell ``FleetSpec`` scenarios stack the cells axis next to the cohort
axis: lane ``s·C + c`` is (seed ``s``, cell ``c``) — each cell an
independent FL system whose fleet carries the cross-cell interference term
— so an interference sweep is the SAME single scanned program, just vmapped
over more lanes.

    runner = CohortRunner(ExperimentSpec(..., cohort=8))
    ch = runner.run()                  # 8 seeds (× cells), one XLA program
    ch.accuracy                        # [8·C, rounds+1]
    ch.history(3)                      # lane 3's FLHistory view
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import TracedRunResult, run_rounds
from repro.core.fedavg import FLExperiment, FLHistory
from repro.core.wireless import fleet_arrays

__all__ = ["CohortHistory", "CohortRunner"]


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def cohort_mesh(cohort_size: int):
    """A 1-axis ``("cohort",)`` mesh over the largest local-device count
    dividing the cohort, or None on a single-device host (plain vmap)."""
    devs = jax.devices()
    n = len(devs)
    while n > 1 and cohort_size % n:
        n -= 1
    if n <= 1:
        return None
    return jax.sharding.Mesh(np.array(devs[:n]), ("cohort",))


def _shard_cohort(tree, mesh):
    """Pre-place every leaf's leading (cohort) axis onto the mesh devices,
    so the sharded program starts without a host→device reshuffle."""
    if mesh is None:
        return tree
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("cohort"))
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), tree)


@dataclass
class CohortHistory:
    """Stacked round histories for a (seeds × cells) cohort; the leading
    axis is the lane ``seed_index · cells + cell`` (``cells == 1`` keeps
    the old seed-only layout)."""
    seeds: List[int]                  # per-lane seed
    accuracy: np.ndarray              # [B, rounds(+1)]
    T_k: np.ndarray                   # [B, rounds(+1)]
    E_k: np.ndarray                   # [B, rounds(+1)]
    selected: np.ndarray              # [B, rounds, S_pad] padded indices
    mask: np.ndarray                  # [B, rounds, S_pad] participation
    with_init: bool
    num_devices: int
    cells: int = 1                    # cells per seed (lane = s·cells + c)

    @property
    def lane_cells(self) -> List[int]:
        """Per-lane cell index (parallel to ``seeds``)."""
        return [i % self.cells for i in range(len(self.seeds))]

    def __len__(self) -> int:
        return len(self.seeds)

    def history(self, i: int) -> FLHistory:
        """Seed ``i``'s run as a plain ``FLHistory`` (padding stripped)."""
        hist = FLHistory()
        hist.accuracy = [float(a) for a in self.accuracy[i]]
        hist.T_k = [float(t) for t in self.T_k[i]]
        hist.E_k = [float(e) for e in self.E_k[i]]
        if self.with_init:
            hist.selected.append(np.arange(self.num_devices))
        hist.selected.extend(self.selected[i][k][self.mask[i][k]]
                             for k in range(self.selected.shape[1]))
        return hist

    @property
    def final_accuracy(self) -> np.ndarray:
        return self.accuracy[:, -1]


class CohortRunner:
    """Run one ``ExperimentSpec`` across a batch of seeds as a single
    compiled, device-sharded program.

    Per-seed datasets/partitions/fleets are materialized host-side through
    the normal ``build_experiment`` factory (so seed-derivation semantics
    match single runs exactly), stacked, and handed to the vmapped
    ``engine.run_rounds``. Requires every configured strategy to be
    traceable (``FLExperiment.traceable``).

    Note on stochastic selection: random/kmeans_random/rra draw from
    ``jax.random`` here (keyed off each seed's PRNG stream), not the host
    numpy Generator the Python loop uses — per-seed histories are
    reproducible run-to-run but differ from a host-loop run of the same
    seed. Deterministic selectors (divergence, icas) match the host loop
    bit-for-bit.
    """

    def __init__(self, spec):
        self.spec = spec
        self.experiments: List[FLExperiment] = []

    # ------------------------------------------------------------------
    @property
    def num_cells(self) -> int:
        return getattr(self.spec, "num_cells", 1)

    def _build(self, seeds: Sequence[int]) -> List[FLExperiment]:
        from repro.api.build import build_experiment
        exps = [build_experiment(self.spec.replace(seed=int(s)), cell=c)
                for s in seeds for c in range(self.num_cells)]
        counts = {e.fed.num_clients for e in exps}
        if len(counts) > 1:
            raise ValueError(
                "CohortRunner stacks (seed, cell) lanes into one vmapped "
                f"program; all cells need equal device counts, got {counts}")
        return exps

    def run(self, seeds: Optional[Sequence[int]] = None,
            rounds: Optional[int] = None,
            reuse_experiments: bool = False) -> CohortHistory:
        """Execute the cohort. ``reuse_experiments=True`` skips rebuilding
        the per-seed datasets/fleets when this runner already holds them
        (benchmarking repeat runs; training state continues where it was)."""
        if seeds is None:
            seeds = [self.spec.seed + i
                     for i in range(max(int(getattr(self.spec, "cohort", 1)),
                                        1))]
        seeds = [int(s) for s in seeds]
        cells = self.num_cells
        lane_seeds = [s for s in seeds for _ in range(cells)]
        rounds = rounds or self.spec.rounds
        if reuse_experiments and len(self.experiments) == len(lane_seeds):
            exps = self.experiments
        else:
            exps = self.experiments = self._build(seeds)
        e0 = exps[0]
        if not e0.traceable():
            raise ValueError(
                "CohortRunner needs an all-traceable strategy bundle; "
                f"got selector={e0.selector.registry_name!r}, "
                f"allocator={e0.allocator.registry_name!r}, "
                f"aggregator={e0.aggregator.registry_name!r}, "
                f"compressor={e0.compressor.registry_name!r}")

        # per-lane pytrees, stacked on the cohort axis and device-sharded
        B = len(lane_seeds)
        mesh = cohort_mesh(B)
        state = _shard_cohort(_tree_stack([e.traced_state() for e in exps]),
                              mesh)
        images = _shard_cohort(jnp.stack([e._images for e in exps]), mesh)
        labels = _shard_cohort(jnp.stack([e._labels for e in exps]), mesh)
        sizes = _shard_cohort(jnp.stack([e._sizes for e in exps]), mesh)
        arr = _shard_cohort(
            _tree_stack([fleet_arrays(e.fleet) for e in exps]), mesh)
        # the evaluation set is shared across the cohort iff every seed
        # resolves the same test data (the common sweep protocol)
        test_shared = len({e.spec.resolved_test_seed if hasattr(e, "spec")
                           else id(e) for e in exps}) == 1
        if test_shared:
            test_images, test_labels = e0.test_images, e0.test_labels
        else:
            test_images = _shard_cohort(
                jnp.stack([e.test_images for e in exps]), mesh)
            test_labels = _shard_cohort(
                jnp.stack([e.test_labels for e in exps]), mesh)

        fn = run_rounds(e0.engine.cfg, selector=e0.selector,
                        allocator=e0.allocator, aggregator=e0.aggregator,
                        compressor=e0.compressor, tctx=e0.traced_context(),
                        feature_layer=e0.fl.feature_layer, rounds=rounds,
                        with_init=True, cohort=True,
                        test_shared=test_shared, mesh=mesh,
                        channel=e0.channel)
        res: TracedRunResult = fn(state, images, labels, sizes, arr,
                                  test_images, test_labels)

        # sync each lane's final state back into its host experiment
        for i, e in enumerate(exps):
            e.load_traced_state(jax.tree_util.tree_map(lambda x, i=i: x[i],
                                                       res.state))
        return self._history(lane_seeds, res, e0.fed.num_clients,
                             cells=cells)

    @staticmethod
    def _history(seeds, res: TracedRunResult,
                 num_devices: int, cells: int = 1) -> CohortHistory:
        accs, Ts, Es, sel, msk = (np.asarray(x) for x in (
            res.rounds.accuracy, res.rounds.T, res.rounds.E,
            res.rounds.selected, res.rounds.mask))
        acc0, T0, E0 = (np.asarray(x)[:, None] for x in (
            res.init_accuracy, res.init_T, res.init_E))
        return CohortHistory(
            seeds=list(seeds),
            accuracy=np.concatenate([acc0, accs], axis=1),
            T_k=np.concatenate([T0, Ts], axis=1),
            E_k=np.concatenate([E0, Es], axis=1),
            selected=sel, mask=msk, with_init=True,
            num_devices=num_devices, cells=cells)
