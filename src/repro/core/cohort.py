"""CohortRunner — vmapped (seeds × cells) cohorts over the device-resident
round pipeline.

The paper's headline figures are all sweeps (many seeds × selectors × σ);
with the whole experiment traced (``engine.run_rounds``), a cohort of
seeds/fleet draws becomes ONE compiled program: the per-seed
state/data/fleet pytrees are stacked on a leading cohort axis, ``vmap``
maps the scanned multi-round run over it, and ``jax.sharding`` splits that
axis across the local devices. One dispatch, one device→host transfer for
the entire cohort history.

Multi-cell ``FleetSpec`` scenarios with a dynamic-interference channel
(``multicell-dynamic``) vmap over SEEDS only: each seed's cells ride an
inner ``[C]`` axis INSIDE the traced program (``engine``'s cells axis),
where one cross-cell reduction per round couples their selections.
Uncoupled multi-cell sweeps (build-time interference) keep the flat
(seed, cell) lane layout so the mesh shards every lane across devices.
Either way the history exposes the flat lane layout ``s·C + c`` (seed
``s``, cell ``c``) and the sweep is ONE scanned program.

    runner = CohortRunner(ExperimentSpec(..., cohort=8))
    ch = runner.run()                  # 8 seeds (× cells), one XLA program
    ch.accuracy                        # [8·C, rounds+1]
    ch.history(3)                      # lane 3's FLHistory view

The scanned program DONATES its state argument (the stacked
``[cohort, N, P]`` flat client plane updates in place); ``stack`` builds
fresh stacked buffers per dispatch and every experiment's references are
rebound from the result, so the donation is invisible to callers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import TracedRunResult, run_rounds
from repro.core.fedavg import FLExperiment, FLHistory
from repro.core.wireless import fleet_arrays

__all__ = ["CohortHistory", "CohortRunner"]


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def cohort_mesh(cohort_size: int):
    """A 1-axis ``("cohort",)`` mesh over ``min(local devices, cohort)``
    devices, or None on a single-device host (plain vmap).

    The cohort axis need not divide the device count: the runner PADS it up
    to the next multiple (``_mesh_pad``) and strips the pad lanes from the
    history, so no local device idles. (The old behavior — shrink to the
    largest divisor — silently serialized awkward sizes: 5 lanes on 4
    devices degenerated to a single-device vmap running all 5
    sequentially.)"""
    devs = jax.devices()
    n = min(len(devs), cohort_size)
    if n <= 1:
        return None
    return jax.sharding.Mesh(np.array(devs[:n]), ("cohort",))


def _mesh_pad(lanes: int, mesh) -> int:
    """How many pad lanes make ``lanes`` divide the mesh's device count."""
    if mesh is None:
        return 0
    return (-lanes) % mesh.devices.size


def _shard_cohort(tree, mesh):
    """Pre-place every leaf's leading (cohort) axis onto the mesh devices,
    so the sharded program starts without a host→device reshuffle."""
    if mesh is None:
        return tree
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("cohort"))
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), tree)


@dataclass
class CohortHistory:
    """Stacked round histories for a (seeds × cells) cohort; the leading
    axis is the lane ``seed_index · cells + cell`` (``cells == 1`` keeps
    the old seed-only layout)."""
    seeds: List[int]                  # per-lane seed
    accuracy: np.ndarray              # [B, rounds(+1)]
    T_k: np.ndarray                   # [B, rounds(+1)]
    E_k: np.ndarray                   # [B, rounds(+1)]
    selected: np.ndarray              # [B, rounds, S_pad] padded indices
    mask: np.ndarray                  # [B, rounds, S_pad] participation
    with_init: bool
    num_devices: int
    cells: int = 1                    # cells per seed (lane = s·cells + c)
    inr: Optional[np.ndarray] = None  # [B, rounds] per-round selection-
                                      # driven I/N0 at each lane's BS
                                      # (dynamic-interference channels only)
    # buffered-asynchronous per-tick traces (None on synchronous runs):
    participation: Optional[np.ndarray] = None  # [B, rounds] updates folded
    staleness: Optional[np.ndarray] = None      # [B, rounds] mean fired age
    active: Optional[np.ndarray] = None         # [B, rounds] available fleet

    @property
    def lane_cells(self) -> List[int]:
        """Per-lane cell index (parallel to ``seeds``)."""
        return [i % self.cells for i in range(len(self.seeds))]

    def __len__(self) -> int:
        return len(self.seeds)

    def history(self, i: int) -> FLHistory:
        """Seed ``i``'s run as a plain ``FLHistory`` (padding stripped)."""
        hist = FLHistory()
        hist.accuracy = [float(a) for a in self.accuracy[i]]
        hist.T_k = [float(t) for t in self.T_k[i]]
        hist.E_k = [float(e) for e in self.E_k[i]]
        if self.with_init:
            hist.selected.append(np.arange(self.num_devices))
        hist.selected.extend(self.selected[i][k][self.mask[i][k]]
                             for k in range(self.selected.shape[1]))
        return hist

    @property
    def final_accuracy(self) -> np.ndarray:
        return self.accuracy[:, -1]


class CohortRunner:
    """Run one ``ExperimentSpec`` across a batch of seeds as a single
    compiled, device-sharded program.

    Per-seed datasets/partitions/fleets are materialized host-side through
    the normal ``build_experiment`` factory (so seed-derivation semantics
    match single runs exactly), stacked, and handed to the vmapped
    ``engine.run_rounds``. Requires every configured strategy to be
    traceable (``FLExperiment.traceable``).

    Note on stochastic selection: random/kmeans_random/rra draw from
    ``jax.random`` here (keyed off each seed's PRNG stream), not the host
    numpy Generator the Python loop uses — per-seed histories are
    reproducible run-to-run but differ from a host-loop run of the same
    seed. Deterministic selectors (divergence, icas) match the host loop
    bit-for-bit.
    """

    def __init__(self, spec):
        if getattr(spec, "store", "dense") != "dense":
            raise ValueError(
                "CohortRunner scans the dense [N, P] client plane as a "
                "vmapped carry; a paged ClientStore serves rows on demand "
                "(store.gather / iter_client_trees) through the host "
                "drivers instead — run the seeds one at a time via "
                "build_experiment(spec) / FLExperiment.run")
        self.spec = spec
        self.experiments: List[FLExperiment] = []

    # ------------------------------------------------------------------
    @property
    def num_cells(self) -> int:
        return getattr(self.spec, "num_cells", 1)

    def _build(self, seeds: Sequence[int]) -> List[FLExperiment]:
        from repro.api.build import build_experiment
        exps = [build_experiment(self.spec.replace(seed=int(s)), cell=c)
                for s in seeds for c in range(self.num_cells)]
        counts = {e.fed.num_clients for e in exps}
        if len(counts) > 1:
            raise ValueError(
                "CohortRunner stacks (seed, cell) lanes into one vmapped "
                f"program; all cells need equal device counts, got {counts}")
        return exps

    def run(self, seeds: Optional[Sequence[int]] = None,
            rounds: Optional[int] = None,
            reuse_experiments: bool = False,
            transfer_guard: bool = False) -> CohortHistory:
        """Execute the cohort. ``reuse_experiments=True`` skips rebuilding
        the per-seed datasets/fleets when this runner already holds them
        (benchmarking repeat runs; training state continues where it was).

        ``transfer_guard=True`` wraps the single program dispatch in
        ``jax.transfer_guard_device_to_host("disallow")`` — the CI bench
        gate proving the whole multi-round cohort really executes as ONE
        scanned program with no per-round host round-trips (any mid-run
        device→host sync raises instead of silently serializing).
        """
        if seeds is None:
            seeds = [self.spec.seed + i
                     for i in range(max(int(getattr(self.spec, "cohort", 1)),
                                        1))]
        seeds = [int(s) for s in seeds]
        cells = self.num_cells
        lane_seeds = [s for s in seeds for _ in range(cells)]
        rounds = rounds or self.spec.rounds
        if reuse_experiments and len(self.experiments) == len(lane_seeds):
            exps = self.experiments
        else:
            exps = self.experiments = self._build(seeds)
        e0 = exps[0]
        if not e0.traceable():
            raise ValueError(
                "CohortRunner needs an all-traceable strategy bundle; "
                f"got selector={e0.selector.registry_name!r}, "
                f"allocator={e0.allocator.registry_name!r}, "
                f"aggregator={e0.aggregator.registry_name!r}, "
                f"compressor={e0.compressor.registry_name!r}")

        # A dynamic-interference channel needs the cells of one seed INSIDE
        # one program instance (the engine's cells axis) so their per-round
        # selections can couple — then the cohort axis is SEEDS. Uncoupled
        # multi-cell sweeps keep the flat (seed, cell) lane layout so the
        # mesh can still shard every lane across devices. Pad lanes
        # replicate the last group up to a device-count multiple and are
        # stripped from the history.
        dynamic = cells > 1 and getattr(e0.channel, "dynamic", False)
        prog_cells = cells if dynamic else 1
        if dynamic:
            groups = [exps[i * cells:(i + 1) * cells]
                      for i in range(len(seeds))]
        else:
            groups = [[e] for e in exps]
        mesh = cohort_mesh(len(groups))
        pad = _mesh_pad(len(groups), mesh)
        groups = groups + [groups[-1]] * pad

        def stack(fn):
            per_lane = [(_tree_stack([fn(e) for e in g]) if prog_cells > 1
                         else fn(g[0])) for g in groups]
            return _shard_cohort(_tree_stack(per_lane), mesh)

        state = stack(lambda e: e.traced_state())
        images = stack(lambda e: e._images)
        labels = stack(lambda e: e._labels)
        sizes = stack(lambda e: e._sizes)
        arr = stack(lambda e: fleet_arrays(e.fleet))
        # the evaluation set is shared across the cohort iff every seed
        # resolves the same test data (the common sweep protocol); it is
        # stacked per outer lane — never on the inner cells axis, which a
        # seed's cells always share
        test_shared = len({e.spec.resolved_test_seed if hasattr(e, "spec")
                           else id(e) for e in exps}) == 1
        if test_shared:
            test_images, test_labels = e0.test_images, e0.test_labels
        else:
            test_images = _shard_cohort(
                jnp.stack([g[0].test_images for g in groups]), mesh)
            test_labels = _shard_cohort(
                jnp.stack([g[0].test_labels for g in groups]), mesh)

        fn = run_rounds(e0.engine.cfg, selector=e0.selector,
                        allocator=e0.allocator, aggregator=e0.aggregator,
                        compressor=e0.compressor, tctx=e0.traced_context(),
                        feature_layer=e0.fl.feature_layer, rounds=rounds,
                        with_init=True, cohort=True,
                        test_shared=test_shared, mesh=mesh,
                        channel=e0.channel, cells=prog_cells,
                        churn=getattr(e0, "churn", (0.0, 0.0)))
        if transfer_guard:
            with jax.transfer_guard_device_to_host("disallow"):
                res: TracedRunResult = fn(state, images, labels, sizes, arr,
                                          test_images, test_labels)
        else:
            res = fn(state, images, labels, sizes, arr,
                     test_images, test_labels)

        # sync each real lane's final state back into its host experiment
        # (pad lanes are dropped)
        for i, e in enumerate(exps):
            s, c = divmod(i, prog_cells)
            pick = ((lambda x, s=s, c=c: x[s, c]) if prog_cells > 1
                    else (lambda x, s=s: x[s]))
            e.load_traced_state(jax.tree_util.tree_map(pick, res.state))
        return self._history(lane_seeds, res, e0.fed.num_clients,
                             cells=cells, prog_cells=prog_cells)

    @staticmethod
    def _history(seeds, res: TracedRunResult, num_devices: int,
                 cells: int = 1, prog_cells: int = 1) -> CohortHistory:
        if prog_cells > 1:
            def lanes_first(x):
                """[S, R, C, ...] → [S·C, R, ...] (lane = s·cells + c)."""
                x = np.moveaxis(np.asarray(x), 2, 1)
                return x.reshape((-1,) + x.shape[2:])
        else:
            lanes_first = np.asarray
        accs, Ts, Es, sel, msk = (lanes_first(x) for x in (
            res.rounds.accuracy, res.rounds.T, res.rounds.E,
            res.rounds.selected, res.rounds.mask))
        acc0, T0, E0 = (np.asarray(x).reshape(-1)[:, None] for x in (
            res.init_accuracy, res.init_T, res.init_E))
        def extra(x):
            """Optional [B, R] trace (inr / async): lane-major, pads off."""
            return None if x is None else lanes_first(x)[:len(seeds)]
        B = len(seeds)                 # true lane count; pads sliced off
        return CohortHistory(
            seeds=list(seeds),
            accuracy=np.concatenate([acc0, accs], axis=1)[:B],
            T_k=np.concatenate([T0, Ts], axis=1)[:B],
            E_k=np.concatenate([E0, Es], axis=1)[:B],
            selected=sel[:B], mask=msk[:B], with_init=True,
            num_devices=num_devices, cells=cells,
            inr=extra(res.rounds.inr),
            participation=extra(res.rounds.participation),
            staleness=extra(res.rounds.staleness),
            active=extra(res.rounds.active))
