"""Wireless system model — paper §III-B, eqs (5)–(11) and §VI parameters.

Every quantity uses the scaled unit system documented in ``docs/UNITS.md``
(frequency GHz, bandwidth MHz, model size Mbit, power W, time s, energy J,
CPU work Gcycles, noise W/Hz), chosen so the whole SAO pipeline is
float32-safe. The FDMA rate (7) becomes r[Mbit/s] = b[MHz]·log2(1 + J/b)
with J = h·p/N0 expressed in MHz; with inter-cell interference the SINR
denominator grows to N0·(1 + inr), i.e. J_eff = J / (1 + inr).

The per-device physical state is a :class:`Fleet` — a pytree-registered
dataclass, so fleets trace through ``jit``/``vmap``/``lax.scan`` (the
device-resident round pipeline) as plain arrays. Declarative construction
(multi-cell topologies, channel models) lives in ``repro.api.scenario``;
:func:`sample_fleet` remains the paper's §VI single-cell sampler.
"""
from __future__ import annotations

from dataclasses import dataclass, fields

import jax
import jax.numpy as jnp
import numpy as np

LN2 = float(np.log(2.0))

# §VI experiment constants
PATHLOSS_DB = lambda d_km: 128.1 + 37.6 * np.log10(np.maximum(d_km, 1e-3))
SHADOW_STD_DB = 8.0
NOISE_DBM_PER_HZ = -174.0
CELL_RADIUS_KM = 0.3
DEFAULT_P_DBM = 23.0
DEFAULT_B_MHZ = 20.0
DEFAULT_F_MAX_GHZ = 2.0
DEFAULT_F_MIN_GHZ = 0.2
DEFAULT_Z_MBIT = 448 * 8 * 1024 / 1e6        # 448 KB model (MNIST CNN, Table II)
DEFAULT_ALPHA = 2e-28                         # effective capacitance 2·(α/2)
DEFAULT_LOCAL_ITERS = 5
DEFAULT_CYCLES_PER_SAMPLE = 2e4
DEFAULT_SAMPLES = 500
# §VI device-population draws — shared by sample_fleet and the scenario
# API's CellSpec defaults (the build_fleet ≡ sample_fleet bit-identity pin
# relies on there being exactly one copy of these)
DEFAULT_E_CONS_RANGE = (30e-3, 60e-3)
DEFAULT_CYCLES_RANGE = (1e4, 3e4)
DEFAULT_SAMPLES_RANGE = (300, 700)


def dbm_to_watt(dbm):
    return 10.0 ** (np.asarray(dbm) / 10.0) / 1e3


def watt_to_dbm(w):
    return 10.0 * np.log10(np.asarray(w) * 1e3)


@dataclass
class Fleet:
    """Per-device physical parameters for N devices.

    A registered pytree: the per-device arrays are leaves (so a ``Fleet``
    passes through ``jit``/``vmap``/``lax.scan`` directly), while ``L``,
    ``N0`` and ``n_cells`` are static aux data. Constructed either by
    :func:`sample_fleet` (the paper's §VI single-cell draw) or
    declaratively from a ``FleetSpec`` via
    ``repro.api.scenario.build_fleet`` (multi-cell topologies, pluggable
    channel models).
    """
    h: np.ndarray            # channel gain (linear)
    p: np.ndarray            # transmit power [W]
    z: np.ndarray            # model size [Mbit]
    C: np.ndarray            # cycles per sample
    D: np.ndarray            # local dataset size [samples]
    L: int                   # local iterations
    alpha: np.ndarray        # capacitance coefficient (the paper's α; e_cmp uses α/2)
    f_min: np.ndarray        # [GHz]
    f_max: np.ndarray        # [GHz]
    e_cons: np.ndarray       # per-device energy budget [J]
    N0: float                # noise PSD [W/Hz]
    cell: np.ndarray = None  # serving-cell index per device (0 for single cell)
    inr: np.ndarray = None   # interference-to-noise ratio I/N0 at the serving BS
    xgain: np.ndarray = None  # [N, C] per-device inr contribution at every BS
                              # when the device transmits (dynamic-interference
                              # channels; own-cell column is 0), else None
    n_cells: int = None      # topology cell count — STATIC aux metadata, so
                              # ``num_cells`` is trace-safe (no np.max on a
                              # possibly-traced ``cell`` leaf, no host sync)

    def __post_init__(self):
        if self.cell is None:
            self.cell = np.zeros(np.shape(self.h), np.int32)
        if self.inr is None:
            self.inr = np.zeros(np.shape(self.h), np.float64)
        if self.n_cells is None and not isinstance(self.cell, jax.core.Tracer):
            n = int(np.max(np.asarray(self.cell))) + 1 if len(self.h) else 1
            object.__setattr__(self, "n_cells", n)

    @property
    def num_devices(self) -> int:
        return len(self.h)

    @property
    def num_cells(self) -> int:
        """Cell count of the topology this fleet was drawn from (host
        metadata; a sub-fleet keeps its parent topology's count)."""
        if self.n_cells is None:
            raise ValueError(
                "Fleet.num_cells is unknown: this Fleet was constructed "
                "from traced arrays without n_cells= metadata; pass "
                "n_cells explicitly when building fleets inside jit")
        return self.n_cells

    # --- the paper's composite constants, eqs (15)-(18), scaled units ---
    def J_mhz(self):
        """J_n = h p / N0, expressed in MHz (divide Hz by 1e6)."""
        return self.h * self.p / self.N0 / 1e6

    def U_gcycles(self):
        """U_n = L·C_n·D_n in Gcycles (eq. 16)."""
        return self.L * self.C * self.D / 1e9

    def G_joule_per_ghz2(self):
        """G_n = (α/2)·L·C_n·D_n so that e_cmp = G·f² with f in GHz (eq. 17)."""
        return 0.5 * self.alpha * self.L * self.C * self.D * 1e18

    def H_joule(self):
        """H_n = z_n·p_n: e_com = H / (b·log2(1+J/b)) with b in MHz, z in Mbit."""
        return self.z * self.p

    def select(self, idx) -> "Fleet":
        idx = np.asarray(idx)
        return Fleet(
            h=self.h[idx], p=self.p[idx], z=self.z[idx], C=self.C[idx],
            D=self.D[idx], L=self.L, alpha=self.alpha[idx],
            f_min=self.f_min[idx], f_max=self.f_max[idx],
            e_cons=self.e_cons[idx], N0=self.N0, cell=self.cell[idx],
            inr=self.inr[idx],
            xgain=None if self.xgain is None else self.xgain[idx],
            n_cells=self.n_cells)

    def cell_fleet(self, c: int) -> "Fleet":
        """The sub-fleet served by cell ``c`` (device order preserved;
        ``num_cells`` stays the parent topology's count)."""
        return self.select(np.flatnonzero(np.asarray(self.cell) == c))

    def with_power(self, p_watt) -> "Fleet":
        p = np.broadcast_to(np.asarray(p_watt, np.float64),
                            self.h.shape).copy()
        # xgain rows are proportional to the device's transmit power
        # (X[n, c] = load·g·p_n / (B·N0)), so rescale them with it
        xgain = (None if self.xgain is None
                 else self.xgain * (p / self.p)[:, None])
        return Fleet(
            h=self.h, p=p,
            z=self.z, C=self.C, D=self.D, L=self.L, alpha=self.alpha,
            f_min=self.f_min, f_max=self.f_max, e_cons=self.e_cons,
            N0=self.N0, cell=self.cell, inr=self.inr, xgain=xgain,
            n_cells=self.n_cells)


_FLEET_LEAVES = tuple(f.name for f in fields(Fleet)
                      if f.name not in ("L", "N0", "n_cells"))


def _fleet_flatten(fl: Fleet):
    return (tuple(getattr(fl, n) for n in _FLEET_LEAVES),
            (fl.L, fl.N0, fl.n_cells))


def _fleet_unflatten(aux, children):
    kw = dict(zip(_FLEET_LEAVES, children))
    return Fleet(L=aux[0], N0=aux[1], n_cells=aux[2], **kw)


jax.tree_util.register_pytree_node(Fleet, _fleet_flatten, _fleet_unflatten)

# NOTE: the ``DeviceFleet`` deprecation alias promised for one release was
# removed here — use :class:`Fleet` (identical fields).


def sample_fleet(num_devices: int = 100, seed: int = 0, *,
                 p_dbm: float = DEFAULT_P_DBM,
                 z_mbit: float = DEFAULT_Z_MBIT,
                 e_cons_range=DEFAULT_E_CONS_RANGE,
                 cycles_range=DEFAULT_CYCLES_RANGE,
                 samples_range=DEFAULT_SAMPLES_RANGE,
                 local_iters: int = DEFAULT_LOCAL_ITERS) -> Fleet:
    """§VI setup: N devices uniform in a 300 m cell, 3GPP path loss + 8 dB
    lognormal shadowing, -174 dBm/Hz noise."""
    rng = np.random.default_rng(seed)
    # uniform over the disc
    r_km = CELL_RADIUS_KM * np.sqrt(rng.uniform(0.01, 1.0, num_devices))
    pl_db = PATHLOSS_DB(r_km) + rng.normal(0.0, SHADOW_STD_DB, num_devices)
    h = 10.0 ** (-pl_db / 10.0)
    return Fleet(
        h=h,
        p=np.full(num_devices, dbm_to_watt(p_dbm)),
        z=np.full(num_devices, z_mbit),
        C=rng.uniform(*cycles_range, num_devices),
        D=rng.integers(samples_range[0], samples_range[1] + 1,
                       num_devices).astype(np.float64),
        L=local_iters,
        alpha=np.full(num_devices, DEFAULT_ALPHA),
        f_min=np.full(num_devices, DEFAULT_F_MIN_GHZ),
        f_max=np.full(num_devices, DEFAULT_F_MAX_GHZ),
        e_cons=rng.uniform(*e_cons_range, num_devices),
        N0=dbm_to_watt(NOISE_DBM_PER_HZ),
    )


# --- eqs (5)-(9) as jnp functions over scaled quantities -------------------


def rate_mbps(b_mhz, J_mhz):
    """Achievable FDMA rate, eq (7): r = b·log2(1 + J/b) [Mbit/s]."""
    b = jnp.maximum(b_mhz, 1e-12)
    return b * jnp.log2(1.0 + J_mhz / b)


def t_cmp(U_gcycles, f_ghz):
    """Computation delay, eq (5): t = L·C·D / f."""
    return U_gcycles / jnp.maximum(f_ghz, 1e-12)


def e_cmp(G, f_ghz):
    """Computation energy, eq (6): e = (α/2)·L·C·D·f²."""
    return G * jnp.square(f_ghz)


def t_com(z_mbit, b_mhz, J_mhz):
    """Communication delay, eq (8): t = z / r."""
    return z_mbit / rate_mbps(b_mhz, J_mhz)


def e_com(H, b_mhz, J_mhz):
    """Communication energy, eq (9): e = p·t_com = H / (b·log2(1+J/b))."""
    return H / rate_mbps(b_mhz, J_mhz)


def effective_arrays(arr):
    """Fold the inter-cell interference term into the channel constant.

    With interference the FDMA SINR denominator is ``(N0 + I)·b``, so the
    rate (7) keeps its shape with ``J_eff = J / (1 + inr)`` where
    ``inr = I/N0``. All solvers call this at entry; dicts without an
    ``"inr"`` key (hand-built, pre-scenario-API) pass through unchanged,
    and ``inr == 0`` divides by exactly 1.0 — bit-identical to no
    interference. The returned copy drops the ``"inr"`` key, making the
    fold idempotent.
    """
    if not isinstance(arr, dict) or "inr" not in arr:
        return arr
    out = dict(arr)
    inr = out.pop("inr")
    out["J"] = arr["J"] / (1.0 + inr)
    return out


def completion_times(arr, b_mhz, f_ghz, mask=None):
    """Per-device completion delay of one dispatched update under an
    allocation: d_n = t_com(z, b, J) + t_cmp(U, f) (eqs. 5+8) — the delay
    model the buffered-asynchronous engine prices in-flight updates with.

    ``arr`` is a (selected) ``fleet_arrays`` dict; interference folds into
    J via :func:`effective_arrays` exactly once (idempotent). Masked-out
    lanes return +inf — a padding lane never completes, so it can never
    enter the aggregation buffer.
    """
    fa = effective_arrays(arr)
    d = t_com(fa["z"], b_mhz, fa["J"]) + t_cmp(fa["U"], f_ghz)
    if mask is None:
        return d
    return jnp.where(mask, d, jnp.inf)


def masked_max(x, mask=None, empty=0.0):
    """Max over the real lanes of a fixed-size padded selection (the one
    padding convention every solver shares: pads are -inf for maxes).

    An all-False ``mask`` (empty selection — e.g. a participation policy
    that admitted nobody this round) returns ``empty`` instead of the
    ``-inf`` that would otherwise poison every downstream scanned-history
    reduction. ``jnp.where(True, v, empty)`` is exactly ``v``, so
    non-empty selections are bit-identical to the unguarded form.
    """
    if mask is None:
        return jnp.max(x)
    return jnp.where(jnp.any(mask), jnp.max(jnp.where(mask, x, -jnp.inf)),
                     empty)


def masked_sum(x, mask=None):
    """Sum over the real lanes (pads contribute exactly 0)."""
    return jnp.sum(x) if mask is None else jnp.sum(jnp.where(mask, x, 0.0))


def round_totals(fleet_arrays, b_mhz, f_ghz):
    """Per-round totals, eqs (10)-(11): (T_k, E_k, per-device t, per-device e).

    ``fleet_arrays`` is a dict with J, U, G, H, z (jnp arrays).
    """
    fa = effective_arrays(fleet_arrays)
    J, U, G, H, z = (fa[k] for k in ("J", "U", "G", "H", "z"))
    t = t_com(z, b_mhz, J) + t_cmp(U, f_ghz)
    e = e_com(H, b_mhz, J) + e_cmp(G, f_ghz)
    return jnp.max(t), jnp.sum(e), t, e


def fleet_arrays(fleet: Fleet):
    """Pack the solver-facing constants (15)-(18) into jnp arrays.

    ``inr`` rides along so the solvers can fold interference into J
    (:func:`effective_arrays`); it is zeros for single-cell fleets.
    ``xgain`` ([N, C] per-device inr contribution at each BS) rides along
    only for dynamic-interference fleets; the scanned round pipeline pops
    it before any solver sees the dict.
    """
    out = {
        "J": jnp.asarray(fleet.J_mhz(), jnp.float32),
        "U": jnp.asarray(fleet.U_gcycles(), jnp.float32),
        "G": jnp.asarray(fleet.G_joule_per_ghz2(), jnp.float32),
        "H": jnp.asarray(fleet.H_joule(), jnp.float32),
        "z": jnp.asarray(fleet.z, jnp.float32),
        "e_cons": jnp.asarray(fleet.e_cons, jnp.float32),
        "f_min": jnp.asarray(fleet.f_min, jnp.float32),
        "f_max": jnp.asarray(fleet.f_max, jnp.float32),
        "inr": jnp.asarray(fleet.inr, jnp.float32),
    }
    if fleet.xgain is not None:
        out["xgain"] = jnp.asarray(fleet.xgain, jnp.float32)
    return out
