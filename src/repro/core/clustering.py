"""K-means device clustering — paper §IV-A/B, Algorithms 2-3.

The paper's finding (Fig. 4/8/9): training K-means on the weights of a
single late layer (``w_fc2``) is both faster (feature dim 2240 vs 113744)
and *more* discriminative of the client's majority class than using all
weights. ``extract_features`` implements exactly that layer selection; the
K-means itself is jitted Lloyd iterations with k-means++ seeding.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.utils.trees import StackFlattenSpec


# ---------------------------------------------------------------------------
# feature extraction (paper: model weights of ONE layer as the feature)
# ---------------------------------------------------------------------------


def extract_features(stacked_params: Dict, layer: str = "auto") -> jnp.ndarray:
    """Feature matrix [N_clients, F] from a client-stacked param tree.

    layer="auto" picks the paper's choice: ``w_fc2`` for the paper CNN,
    otherwise the last 2-D projection-like leaf (lm_head / out_proj).
    layer="all" flattens everything (the slow baseline of Fig. 8).
    A specific leaf name ("w_c1", "b_fc2", ...) selects that leaf.
    """
    if layer == "all":
        leaves = jax.tree_util.tree_leaves(stacked_params)
        return jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves], axis=1)
    if layer == "auto":
        if isinstance(stacked_params, dict) and "w_fc2" in stacked_params:
            layer = "w_fc2"
        elif isinstance(stacked_params, dict) and "lm_head" in stacked_params:
            layer = "lm_head"
        else:  # fall back to the largest final leaf
            flat = jax.tree_util.tree_leaves_with_path(stacked_params)
            path, leaf = flat[-1]
            return leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
    leaf = _lookup(stacked_params, layer)
    return leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)


def _lookup(tree, name):
    if isinstance(tree, dict):
        if name in tree:
            return tree[name]
        for v in tree.values():
            try:
                return _lookup(v, name)
            except KeyError:
                continue
    raise KeyError(name)


def _resolve_flat_layer(spec: StackFlattenSpec, layer: str):
    """Bare leaf name -> full spec name (nested specs use ``a/b`` paths;
    mirror :func:`_lookup`'s bare-key recursion by suffix matching)."""
    if layer in spec.names:
        return layer
    hits = [n for n in spec.names if n.endswith("/" + layer)]
    return hits[0] if hits else None


def extract_features_flat(client_flat: jnp.ndarray, layer: str,
                          spec: StackFlattenSpec) -> jnp.ndarray:
    """Feature matrix from the ``[N, P]`` flat client plane — a zero-copy
    column slice of the buffer (``layer="all"`` IS the buffer), replacing
    the per-round leaf concatenate of :func:`extract_features`.

    Column ranges come from the static flatten spec, so the slice matches
    ``extract_features`` on the equivalent stacked pytree bit for bit
    (bare leaf names resolve through nested paths like ``_lookup`` does).
    """
    cols = resolve_feature_columns(spec, layer)
    return client_flat if cols is None else client_flat[:, cols]


def resolve_feature_columns(spec: StackFlattenSpec, layer: str):
    """The feature layer's column slice of a flat row (``None`` = the whole
    row, i.e. ``layer="all"``). Shared by the dense zero-copy slice above
    and the paged store's chunk-at-a-time feature assembly, so both views
    read identical columns."""
    if layer == "all":
        return None
    if layer == "auto":
        layer = (_resolve_flat_layer(spec, "w_fc2")
                 or _resolve_flat_layer(spec, "lm_head")
                 or spec.names[-1])     # fall back to the last leaf
    else:
        resolved = _resolve_flat_layer(spec, layer)
        if resolved is None:
            raise KeyError(layer)
        layer = resolved
    return spec.columns(layer)


# ---------------------------------------------------------------------------
# K-means (Lloyd + k-means++), jitted
# ---------------------------------------------------------------------------


def _pairwise_sq_dists(x, c):
    """[N, F] × [C, F] -> [N, C] squared Euclidean distances — the shared
    ``repro.kernels.ops`` implementation (Pallas kernel on TPU, clamped
    streaming expansion elsewhere)."""
    return ops.pairwise_sq_dists(x, c)


def kmeans_plus_plus_init(key, x, c: int):
    """k-means++ seeding."""
    n = x.shape[0]
    keys = jax.random.split(key, c)
    idx0 = jax.random.randint(keys[0], (), 0, n)
    centroids = jnp.zeros((c, x.shape[1]), x.dtype).at[0].set(x[idx0])

    def add_centroid(i, centroids):
        d = _pairwise_sq_dists(x, centroids)
        # distance to nearest chosen centroid (unchosen rows are zeros ->
        # mask them by only taking first i columns via where)
        col_mask = jnp.arange(centroids.shape[0]) < i
        d = jnp.where(col_mask[None, :], d, jnp.inf)
        dmin = jnp.min(d, axis=1)
        p = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        idx = jax.random.choice(keys[i], n, p=p)
        return centroids.at[i].set(x[idx])

    return jax.lax.fori_loop(1, c, add_centroid, centroids)


@functools.partial(jax.jit, static_argnames=("c", "iters"))
def kmeans_fit(key, x: jnp.ndarray, c: int, iters: int = 50):
    """Lloyd's algorithm, eqs (13)-(14). Returns (centroids, labels, inertia)."""
    x = x.astype(jnp.float32)
    centroids = kmeans_plus_plus_init(key, x, c)

    def step(_, centroids):
        d = _pairwise_sq_dists(x, centroids)                 # (13)
        labels = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
        counts = jnp.sum(onehot, axis=0)
        sums = onehot.T @ x
        new = sums / jnp.maximum(counts, 1.0)[:, None]       # (14)
        # keep old centroid for empty clusters
        return jnp.where((counts > 0)[:, None], new, centroids)

    centroids = jax.lax.fori_loop(0, iters, step, centroids)
    d = _pairwise_sq_dists(x, centroids)
    labels = jnp.argmin(d, axis=1)
    inertia = jnp.sum(jnp.min(d, axis=1))
    return centroids, labels, inertia


def kmeans_predict(centroids, x):
    return jnp.argmin(_pairwise_sq_dists(x.astype(jnp.float32), centroids), axis=1)


@functools.partial(jax.jit, static_argnames=("c",))
def _chunk_assign_stats(x, centroids, c: int):
    """One chunk's Lloyd-pass statistics: (per-cluster feature sums [C, F],
    per-cluster counts [C], chunk inertia)."""
    d = _pairwise_sq_dists(x, centroids)
    labels = jnp.argmin(d, axis=1)
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    return onehot.T @ x, jnp.sum(onehot, axis=0), jnp.sum(jnp.min(d, axis=1))


def kmeans_fit_minibatch(key, chunks, c: int, iters: int = 50):
    """Streaming Lloyd over an O(chunk)-memory feature stream.

    ``chunks`` is a CALLABLE returning a fresh iterator of host ``[n_i, F]``
    feature blocks (e.g. the paged store's ``iter_client_features``), so the
    fit never materializes the ``[N, F]`` matrix: each Lloyd pass folds
    per-chunk assignment statistics (sums/counts) into [C, F] accumulators
    and updates the centroids once per pass — mathematically full-batch
    Lloyd, evaluated chunk-at-a-time, hence deterministic for a fixed chunk
    stream.

    A SINGLE-chunk stream short-circuits to :func:`kmeans_fit` verbatim, so
    small fleets stay bit-identical to the full fit (the parity pin).
    Multi-chunk streams seed k-means++ on the first chunk only.

    Returns ``(centroids, labels, inertia)`` with labels covering every
    streamed row in stream order — the same contract as :func:`kmeans_fit`.
    """
    first = None
    multi = False
    for block in chunks():
        if first is None:
            first = jnp.asarray(block, jnp.float32)
        else:
            multi = True
            break
    if first is None:
        raise ValueError("kmeans_fit_minibatch: empty feature stream")
    if not multi:
        return kmeans_fit(key, first, c, iters=iters)

    centroids = kmeans_plus_plus_init(key, first, c)
    for _ in range(iters):
        sums = jnp.zeros_like(centroids)
        counts = jnp.zeros((c,), jnp.float32)
        for block in chunks():
            s, n, _ = _chunk_assign_stats(jnp.asarray(block, jnp.float32),
                                          centroids, c)
            sums = sums + s
            counts = counts + n
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        centroids = jnp.where((counts > 0)[:, None], new, centroids)

    labels, inertia = [], 0.0
    for block in chunks():
        x = jnp.asarray(block, jnp.float32)
        d = _pairwise_sq_dists(x, centroids)
        labels.append(np.asarray(jnp.argmin(d, axis=1)))
        inertia += float(jnp.sum(jnp.min(d, axis=1)))
    return centroids, jnp.asarray(np.concatenate(labels)), inertia


def clusters_from_labels(labels: np.ndarray, c: int):
    """Algorithm 2 output form: list of index arrays {N_1..N_c}."""
    labels = np.asarray(labels)
    return [np.flatnonzero(labels == i) for i in range(c)]


# ---------------------------------------------------------------------------
# Adjusted Rand Index (Fig. 9 metric)
# ---------------------------------------------------------------------------


def adjusted_rand_index(pred: np.ndarray, truth: np.ndarray) -> float:
    """Standard ARI (Hubert & Arabie 1985) — the paper's eq. (24) metric."""
    pred = np.asarray(pred)
    truth = np.asarray(truth)
    n = len(pred)
    pv, pi = np.unique(pred, return_inverse=True)
    tv, ti = np.unique(truth, return_inverse=True)
    cont = np.zeros((len(pv), len(tv)), np.int64)
    np.add.at(cont, (pi, ti), 1)
    comb = lambda x: x * (x - 1) / 2.0
    sum_ij = comb(cont).sum()
    a = comb(cont.sum(axis=1)).sum()
    b = comb(cont.sum(axis=0)).sum()
    expected = a * b / comb(n)
    max_index = 0.5 * (a + b)
    if max_index == expected:
        return 1.0
    return float((sum_ij - expected) / (max_index - expected))
