"""Spectrum-allocation baselines the paper compares against (§VI-A).

Baseline 1 — equal bandwidth: b_n = B/S; each device then runs the fastest
CPU frequency its energy budget allows.

Baseline 2 — FEDL [27]: jointly minimize  Σ_n e_n + λ·T_k  subject to the
band budget and the frequency box, WITHOUT per-device energy constraints.
Implemented as an exact-ish convex solve: outer grid/golden search on T,
inner bandwidth waterfilling (equal marginal energy-per-MHz via a dual
bisection, per-device slope found by autodiff + bisection).

Both accept the participation ``mask`` of the traced round pipeline
(fixed-size padded selections) and the ``inr`` interference term of
multi-cell fleets, and the §VI-A λ tuning ("λ makes the worst device just
meet its energy budget") is ported into the traced program as
:func:`tune_fedl_lambda` — a ``lax.while_loop`` bisection, so FEDL baseline
sweeps run device-resident on the cohort engine.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sao import _Q, SAOSolution
from repro.core.wireless import (LN2, effective_arrays, masked_max,
                                 masked_sum)


class AllocResult(NamedTuple):
    T: jnp.ndarray
    b: jnp.ndarray
    f: jnp.ndarray
    e: jnp.ndarray            # per-device energy
    feasible: jnp.ndarray     # per-device energy constraint satisfied


def equal_bandwidth(arr: Dict[str, jnp.ndarray], B: float,
                    mask=None) -> AllocResult:
    """Baseline 1. Every device gets B/S; f maximal within its own budget.

    ``mask`` (optional, [S] bool) marks the real devices of a fixed-size
    padded selection (traced round pipeline): the band splits over the
    masked count only, and padded lanes are excluded from the reductions
    and zeroed in the returned ``b``/``f``/``e``.
    """
    arr = effective_arrays(arr)
    if mask is None:
        n = arr["J"].shape[0]
        b = jnp.full((n,), B / n, jnp.float32)
        b_q = b
    else:
        n = jnp.maximum(jnp.sum(mask), 1)
        b = jnp.where(mask, B / n, 0.0)
        b_q = jnp.where(mask, b, 1.0)        # keep Q well-defined on pads
    ecom = arr["H"] / _Q(b_q, arr["J"])
    resid = arr["e_cons"] - ecom
    f = jnp.sqrt(jnp.maximum(resid, 0.0) / arr["G"])
    f = jnp.clip(f, arr["f_min"], arr["f_max"])
    t = arr["z"] / _Q(b_q, arr["J"]) + arr["U"] / f
    e = arr["G"] * jnp.square(f) + ecom
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
        f = jnp.where(mask, f, 0.0)
    # masked_max guards the empty-selection edge: an all-False mask (a
    # participation policy that admitted nobody) yields T = 0, not the
    # -inf that would poison the scanned history
    return AllocResult(T=masked_max(t, mask), b=b, f=f, e=e,
                       feasible=e <= arr["e_cons"] + 1e-6)


# ---------------------------------------------------------------------------
# Baseline 2 — FEDL-style  min Σe + λT
# ---------------------------------------------------------------------------


def _device_energy(b, T, arr):
    """Energy of one device at bandwidth b given deadline T (f minimal)."""
    tcom = arr["z"] / _Q(b, arr["J"])
    slack = jnp.maximum(T - tcom, 1e-9)
    f = jnp.clip(arr["U"] / slack, arr["f_min"], arr["f_max"])
    return arr["G"] * jnp.square(f) + arr["H"] / _Q(b, arr["J"]), f


def _b_required(T, arr):
    """Minimal b for the deadline to be *meetable* at f_max:
    Q(b) ≥ z / (T − U/f_max). Returns b_req (or +inf if impossible)."""
    slack = T - arr["U"] / arr["f_max"]
    target = arr["z"] / jnp.maximum(slack, 1e-9)
    feasible = (slack > 0.0) & (target < arr["J"] / LN2 * 0.999999)

    lo = jnp.full_like(arr["J"], 1e-9)
    hi = jnp.full_like(arr["J"], 1e9)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ge = _Q(mid, arr["J"]) >= target
        return jnp.where(ge, lo, mid), jnp.where(ge, mid, hi)

    lo, hi = lax.fori_loop(0, 60, body, (lo, hi))
    return jnp.where(feasible, 0.5 * (lo + hi), jnp.inf)


def _waterfill_b(T, arr, B, n_iters: int = 40, mask=None):
    """Minimize Σ_n e_n(b_n; T) s.t. Σ b_n = B, b_n ≥ b_req_n.

    Equal-marginal condition: de_n/db_n = −μ for unconstrained devices.
    de/db is monotone ↑ (convex energy in b), so per-device bisection on b
    nested in a dual bisection on μ. Masked (padding) lanes are pinned to
    ``b = 0`` and excluded from the band sum.
    """
    b_req = _b_required(T, arr)
    if mask is not None:
        b_req = jnp.where(mask, b_req, 0.0)
    # per-device slope de/db via autodiff of the summed energy (elementwise)
    energy_fn = lambda b: _device_energy(b, T, arr)[0]
    slope_fn = jax.grad(lambda b: jnp.sum(energy_fn(b)))      # elementwise slope

    b_hi_cap = (jnp.full_like(b_req, B) if mask is None
                else jnp.where(mask, B, 0.0))

    def b_of_mu(mu):
        lo = b_req
        hi = b_hi_cap

        def body(_, carry):
            lo, hi = carry
            mid = 0.5 * (lo + hi)
            s = slope_fn(mid)
            move_up = s < -mu          # slope still steeper than -mu -> grow b
            return jnp.where(move_up, mid, lo), jnp.where(move_up, hi, mid)

        lo, hi = lax.fori_loop(0, n_iters, body, (lo, hi))
        return jnp.clip(0.5 * (lo + hi), b_req, b_hi_cap)

    def mu_body(_, carry):
        mu_lo, mu_hi = carry
        mu = 0.5 * (mu_lo + mu_hi)
        tot = jnp.sum(b_of_mu(mu))
        over = tot > B                 # too much band -> need larger μ
        return jnp.where(over, mu, mu_lo), jnp.where(over, mu_hi, mu)

    mu_lo, mu_hi = lax.fori_loop(0, n_iters, mu_body,
                                 (jnp.asarray(0.0), jnp.asarray(1e3)))
    b = b_of_mu(0.5 * (mu_lo + mu_hi))
    # rescale any residual mismatch onto unconstrained devices
    excess = B - jnp.sum(b)
    free = b > b_req + 1e-9
    if mask is not None:
        free = free & mask
    b = b + jnp.where(free, excess / jnp.maximum(jnp.sum(free), 1), 0.0)
    return jnp.maximum(b, b_req)


def arr_ith(arr, i):  # helper retained for API completeness
    return {k: v[i] for k, v in arr.items()}


def _fedl_solve(arr, B, lam, n_grid: int, mask):
    """The (unjitted) FEDL core over an already-interference-folded ``arr``;
    shared by :func:`fedl_lambda` and the traced λ tuner."""
    B = jnp.asarray(B, jnp.float32)
    n = (arr["J"].shape[0] if mask is None
         else jnp.maximum(jnp.sum(mask), 1))      # real lanes only — the
    # bracket must not depend on how much padding rode along
    T_min = masked_max(LN2 * arr["z"] / arr["J"]
                       + arr["U"] / arr["f_max"], mask) * 1.02
    T_max = masked_max(arr["z"] / _Q(B / n * 0.05, arr["J"])
                       + arr["U"] / arr["f_min"], mask)
    Ts = jnp.exp(jnp.linspace(jnp.log(T_min), jnp.log(T_max), n_grid))

    def eval_T(T):
        b = _waterfill_b(T, arr, B, mask=mask)
        e, f = _device_energy(b, T, arr)
        infeasible = masked_sum(_b_required(T, arr), mask) > B
        obj = masked_sum(e, mask) + lam * T
        return jnp.where(infeasible, jnp.inf, obj), (b, f, e)

    objs, (bs, fs, es) = lax.map(eval_T, Ts)
    i = jnp.argmin(objs)
    b, f, e = bs[i], fs[i], es[i]
    b_q = b if mask is None else jnp.where(mask, b, 1.0)
    t = arr["z"] / _Q(b_q, arr["J"]) + arr["U"] / f
    if mask is not None:
        b, f, e = (jnp.where(mask, v, 0.0) for v in (b, f, e))
    # masked_max: empty selections return T = 0 instead of -inf
    return AllocResult(T=masked_max(t, mask), b=b, f=f, e=e,
                       feasible=e <= arr["e_cons"] + 1e-6)


@functools.partial(jax.jit, static_argnames=("n_grid",))
def fedl_lambda(arr: Dict[str, jnp.ndarray], B: float, lam: float,
                n_grid: int = 120, *, mask=None) -> AllocResult:
    """Baseline 2: grid-refined solve of min_{T,b,f} Σe + λT.

    ``mask`` marks the real lanes of a fixed-size padded selection (traced
    round pipeline); an ``"inr"`` interference entry in ``arr`` folds into
    J at entry.
    """
    return _fedl_solve(effective_arrays(arr), B, lam, n_grid, mask)


@functools.partial(jax.jit, static_argnames=("iters", "n_grid"))
def tune_fedl_lambda(arr: Dict[str, jnp.ndarray], B: float, *, mask=None,
                     lam_lo: float = 1e-3, lam_hi: float = 1e4,
                     iters: int = 24, n_grid: int = 120) -> jnp.ndarray:
    """§VI-A λ tuning as a traced ``lax.while_loop`` bisection.

    'λ is tuned to make the device with the highest energy cost just meet
    the energy constraint': larger λ weights delay more → more energy, so
    bisect λ (geometrically) down until max(e − e_cons) ≤ 0 over the real
    lanes. Fully traced — FEDL baseline sweeps run inside the scanned
    round pipeline / cohort engine instead of a host-driven loop.
    Returns the largest feasible λ found (a jnp scalar).
    """
    arr = effective_arrays(arr)

    def cond(carry):
        i, lo, hi = carry
        return (i < iters) & (hi > lo * (1.0 + 1e-3))

    def body(carry):
        i, lo, hi = carry
        mid = jnp.sqrt(lo * hi)
        res = _fedl_solve(arr, B, mid, n_grid, mask)
        worst = masked_max(res.e - arr["e_cons"], mask)
        viol = worst > 0.0
        return (i + 1, jnp.where(viol, lo, mid), jnp.where(viol, mid, hi))

    _, lo, _ = lax.while_loop(
        cond, body, (0, jnp.asarray(lam_lo, jnp.float32),
                     jnp.asarray(lam_hi, jnp.float32)))
    return lo


def tune_fedl_lambda_for_constraints(arr, B, *, lam_lo=1e-3, lam_hi=1e4,
                                     iters=24):
    """Host-facing wrapper over :func:`tune_fedl_lambda` (kept for the
    figure benchmarks; the value is identical to the old host bisection up
    to the while_loop's early-exit tolerance)."""
    return float(tune_fedl_lambda(arr, B, lam_lo=lam_lo, lam_hi=lam_hi,
                                  iters=iters))
