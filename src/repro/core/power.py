"""Appendix E — optimal shared transmit power (Algorithm 6).

Binary search on a common transmit power p ∈ [p_min, p_max]: larger p raises
J (faster uplink) but also H = z·p (more comm energy), which squeezes the
compute-energy budget and forces f down. T_k(p) is unimodal; Algorithm 6
refines the bracket by comparing each T_k against the best seen so far.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.sao import solve_sao
from repro.core.wireless import Fleet, fleet_arrays, dbm_to_watt


class PowerOptResult(NamedTuple):
    p_star_watt: float
    p_star_dbm: float
    T_star: float
    history: list            # [(p_watt, T_k)]


def optimal_transmit_power(fleet: Fleet, B: float, *,
                           p_min_dbm: float = 10.0, p_max_dbm: float = 23.0,
                           eps3: float = 1e-3,
                           max_epochs: int = 40) -> PowerOptResult:
    """Algorithm 6, wrapping Algorithm 5 (solve_sao) per probe."""
    p_low = dbm_to_watt(p_min_dbm)
    p_up = dbm_to_watt(p_max_dbm)

    def T_of(p):
        arr = fleet_arrays(fleet.with_power(p))
        return float(solve_sao(arr, B).T)

    history = []
    p = p_low
    epoch = 0
    best_T = np.inf
    while 1.0 - p_low / p_up > eps3 and epoch < max_epochs:
        T_k = T_of(p)
        history.append((float(p), T_k))
        if epoch > 0:
            if T_k <= best_T:
                p_low = p
            else:
                p_up = p
        best_T = min(best_T, T_k)
        p = 0.5 * (p_up + p_low)
        epoch += 1
    p_star = 0.5 * (p_up + p_low)
    T_star = T_of(p_star)
    from repro.core.wireless import watt_to_dbm
    return PowerOptResult(p_star_watt=float(p_star),
                          p_star_dbm=float(watt_to_dbm(p_star)),
                          T_star=float(T_star), history=history)
