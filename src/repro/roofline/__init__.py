from repro.roofline.analysis import (RooflineReport, analyze_compiled,
                                     collective_bytes, model_flops)
