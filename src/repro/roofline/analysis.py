"""Roofline analysis from a compiled dry-run artifact (§Roofline).

Three terms, per (arch × shape × mesh):

    compute    = HLO_FLOPs_global / (chips × peak_FLOP/s)
    memory     = HLO_bytes_global / (chips × HBM_bw)
    collective = collective_bytes_global / (chips × link_bw)

``compiled.cost_analysis()`` reports the per-device SPMD program, so global
= per-device × chips (verified in tests/test_roofline.py on a sharded
matmul). Collective bytes are parsed from the post-SPMD HLO text — they are
NOT in cost_analysis.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.launch.mesh import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %ag = bf16[8,2048,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# tuple-result collectives:  = (bf16[..], bf16[..]) all-reduce(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-type output bytes of the per-device HLO module.

    '-start' ops are counted, matching '-done' twins are skipped.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue                      # avoid double counting async pairs
        m = _OP_RE.search(line)
        if m:
            dtype, dims, op = m.groups()
            out[op] += _shape_bytes(dtype, dims)
            counts[op] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, op = m.groups()
            for sm in _SHAPE_RE.finditer(shapes):
                out[op] += _shape_bytes(*sm.groups())
            counts[op] += 1
    out_total = sum(out.values())
    return {"total": out_total, "counts": counts, **out}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    peak_memory_per_device: Optional[float] = None
    collectives: Dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / TPU_V5E["peak_bf16_flops"]

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / TPU_V5E["hbm_bandwidth"]

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / TPU_V5E["ici_bandwidth"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_global — remat/redundancy waste detector."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def step_time_s(self) -> float:
        """No-overlap roofline estimate of the step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "peak_memory_per_device": self.peak_memory_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "collectives": self.collectives,
        }


def model_flops(cfg, shape, *, include_backward: bool) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params."""
    n = cfg.num_params(active_only=cfg.moe is not None)
    if shape.is_decode:
        tokens = shape.global_batch                       # one new token each
    else:
        tokens = shape.global_batch * shape.seq_len
    mult = 6.0 if include_backward else 2.0
    return mult * n * tokens


def analyze_compiled(compiled, *, arch: str, shape, mesh_name: str,
                     chips: int, cfg, include_backward: bool,
                     hlo_text: Optional[str] = None) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak_mem = float(getattr(ma, "temp_size_in_bytes", 0)
                             + getattr(ma, "argument_size_in_bytes", 0)
                             + getattr(ma, "output_size_in_bytes", 0)
                             - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=float(coll["total"]),
        model_flops_global=model_flops(cfg, shape,
                                       include_backward=include_backward),
        peak_memory_per_device=peak_mem,
        collectives=coll)
