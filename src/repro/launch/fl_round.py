"""The paper's FL round as a SHARDED datacenter workload (dry-run target).

This is the paper's technique mapped onto the mesh (DESIGN.md §2): client
models live stacked on a client axis sharded over (pod, data); one round =

  1. weight divergence ‖w_n − w_g‖ for every client      (Alg. 4 signal)
  2. K-means assignment of late-layer features            (Alg. 2/3)
  3. top-1-divergence-per-cluster selection mask          (Alg. 4)
  4. D_n-weighted FedAvg aggregation of selected clients  (eq. 4)

Every step is a reduction over `model`-sharded parameter dims crossed with
the client-sharded axis — the collective pattern the hillclimb's third pair
studies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import shapes as shp
from repro.sharding import specs as sh


def fl_round_step(client_params, global_params, centroids, sizes, *,
                  num_clusters: int, feature_slice: int = 0):
    """client_params: leaves [N, ...]; centroids: [c, F] K-means model on
    the lm_head feature layer. Returns (new_global, divergence, labels).

    ``feature_slice`` > 0 clusters on only the first ``feature_slice``
    feature dims — the paper's §IV-B insight (one cheap late layer beats
    all-weights) applied at LM scale (hillclimb lever, §Perf pair C)."""
    # 1. weight divergence over ALL layers (paper §IV-C)
    def leaf_sq(cl, gl):
        d = cl.astype(jnp.float32) - gl.astype(jnp.float32)[None]
        return jnp.sum(jnp.square(d).reshape(d.shape[0], -1), axis=1)

    sq = jax.tree_util.tree_map(leaf_sq, client_params, global_params)
    div = jnp.sqrt(sum(jax.tree_util.tree_leaves(sq)))          # [N]

    # 2. K-means assignment on the feature layer (lm_head — the w_fc2
    #    analogue for LM clients)
    feat_leaf = client_params.get("lm_head", client_params["embed"])
    feats = feat_leaf.reshape(div.shape[0], -1)
    if feature_slice:
        feats = feats[:, :feature_slice]
    feats = feats.astype(jnp.float32)
    fn = jnp.sum(jnp.square(feats), axis=1, keepdims=True)
    cn = jnp.sum(jnp.square(centroids), axis=1)[None, :]
    d2 = fn + cn - 2.0 * feats @ centroids.T
    labels = jnp.argmin(d2, axis=1)                             # [N]

    # 3. top-1 divergence per cluster -> selection mask
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)  # [N, c]
    masked = onehot * div[:, None] - (1.0 - onehot) * 1e30
    best = jnp.argmax(masked, axis=0)                           # [c]
    has_member = jnp.max(onehot, axis=0) > 0.0                  # empty-cluster guard
    sel = jnp.zeros_like(div).at[best].add(
        has_member.astype(jnp.float32))
    sel = jnp.minimum(sel, 1.0)

    # 4. eq. (4) weighted aggregation over the selected set
    w = sel * sizes
    w = w / jnp.maximum(jnp.sum(w), 1e-9)

    def agg(cl, gl):
        ww = w.reshape((-1,) + (1,) * (cl.ndim - 1)).astype(jnp.float32)
        return jnp.sum(cl.astype(jnp.float32) * ww, axis=0).astype(gl.dtype)

    new_global = jax.tree_util.tree_map(agg, client_params, global_params)
    return new_global, div, labels


def lower_fl_round(cfg: ModelConfig, mesh: Mesh, *, num_clients: int = 128,
                   num_clusters: int = 10, feature_slice: int = 0):
    """Lower+compile the sharded FL round for ``num_clients`` copies of the
    client architecture."""
    p_struct = shp.param_structs(cfg, jnp.bfloat16)
    p_shard = sh.params_shardings(p_struct, mesh)
    ba = sh.batch_axes(mesh, num_clients)

    def stack(leaf):
        return jax.ShapeDtypeStruct((num_clients,) + tuple(leaf.shape),
                                    leaf.dtype)

    def stack_shard(shard):
        return NamedSharding(mesh, P(ba if ba else None, *shard.spec))

    c_struct = jax.tree_util.tree_map(stack, p_struct)
    c_shard = jax.tree_util.tree_map(stack_shard, p_shard)

    feat_dim = feature_slice or cfg.d_model * cfg.vocab_size
    cent = jax.ShapeDtypeStruct((num_clusters, feat_dim), jnp.float32)
    sizes = jax.ShapeDtypeStruct((num_clients,), jnp.float32)
    rep = NamedSharding(mesh, P())

    step = functools.partial(fl_round_step, num_clusters=num_clusters,
                             feature_slice=feature_slice)
    jitted = jax.jit(step,
                     in_shardings=(c_shard, p_shard, rep, rep),
                     out_shardings=(p_shard, rep, rep))
    with mesh:
        return jitted.lower(c_struct, p_struct, cent, sizes)


def lower_fl_round_from_spec(spec, mesh: Mesh, *, feature_slice: int = 0):
    """Spec-API entry point: lower the sharded round for an
    ``ExperimentSpec`` whose ``model`` names an assigned LM architecture
    (``spec.clients`` LM clients, ``spec.num_clusters`` K-means clusters)."""
    from repro.configs import get_config

    if spec.model == "auto":
        raise ValueError("spec.model must name an arch id (e.g. "
                         "'tinyllama-1.1b') for the sharded fl_round path")
    return lower_fl_round(get_config(spec.model), mesh,
                          num_clients=spec.clients,
                          num_clusters=spec.num_clusters,
                          feature_slice=feature_slice)
