"""Serving driver: batched generation from any --arch (reduced variant on
CPU; full configs are exercised via the dry-run).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import init_model
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sampler", default="greedy",
                    choices=["greedy", "temperature"])
    ap.add_argument("--temp", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.gen + 1)
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 32, cfg.d_model)) * 0.1
    t0 = time.time()
    out = eng.generate(prompts, num_tokens=args.gen, sampler=args.sampler,
                       key=jax.random.PRNGKey(args.seed + 2), temp=args.temp,
                       **kw)
    dt = time.time() - t0
    print(f"{args.arch}: {args.batch}×{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    for i, row in enumerate(out):
        print(f"  [{i}] {row.tolist()}")


if __name__ == "__main__":
    main()
