"""Single-host training driver (example-scale): train any --arch smoke/full
variant on the synthetic token stream.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.data.synthetic import make_token_stream
from repro.models.transformer import init_model
from repro.train.train_step import make_train_step
from repro.train.metrics import MetricsLogger
from repro.train.checkpoint import save_checkpoint


def batches_from_stream(tokens: np.ndarray, batch: int, seq: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, batch)
        yield {"tokens": jnp.asarray(
            np.stack([tokens[i:i + seq] for i in idx]))}


def make_vlm_audio_extras(cfg, batch, seq):
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.zeros(
            (batch, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        extras["src_embeds"] = jnp.zeros((batch, seq, cfg.d_model), jnp.float32)
    return extras


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--moe-impl", default="dense")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-csv", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1))
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    opt_init, train_step = make_train_step(cfg, tc, moe_impl=args.moe_impl,
                                           q_chunk=64, kv_chunk=64)
    opt_state = opt_init(params)
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    stream = make_token_stream(cfg.vocab_size, 200_000, seed=args.seed)
    gen = batches_from_stream(stream, args.batch, args.seq, args.seed)
    extras = make_vlm_audio_extras(cfg, args.batch, args.seq)

    logger = MetricsLogger(args.log_csv)
    t0 = time.time()
    for step in range(args.steps):
        batch = {**next(gen), **extras}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % max(args.steps // 20, 1) == 0 or step == args.steps - 1:
            logger.log(step, metrics)
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
    logger.flush()
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print("checkpoint saved to", args.ckpt)
    return logger


if __name__ == "__main__":
    main()
