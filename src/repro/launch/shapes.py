"""ShapeDtypeStruct stand-ins + shardings for every (arch × input-shape).

``input_specs`` builds exactly what the dry-run lowers against: no device
allocation, weak-type-correct, shardable.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, InputShape, INPUT_SHAPES,
                                LONG_CONTEXT_WINDOW, TrainConfig)
from repro.models.transformer import init_model, init_cache, ENC_MEMORY_LEN
from repro.sharding import specs as sh


def struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def decode_window(cfg: ModelConfig, shape: InputShape):
    """The SWA ring-buffer window used for long_500k on full-attention
    families (mixtral's native window is kept as-is)."""
    if shape.name == "long_500k" and cfg.num_heads and cfg.attn_period == 0:
        return cfg.sliding_window or LONG_CONTEXT_WINDOW
    return cfg.sliding_window


def batch_structs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                  dtype=jnp.bfloat16) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(structs, shardings) for the step-function ``batch`` argument."""
    B, S = shape.global_batch, shape.seq_len
    tok_sh = NamedSharding(mesh, sh.token_spec(mesh, B))
    emb_sh3 = NamedSharding(mesh, sh.token_spec(mesh, B, extra_dims=2))
    if shape.is_decode:
        structs = {"tokens": struct((B, 1), jnp.int32)}
        shards = {"tokens": tok_sh}
        return structs, shards
    structs = {"tokens": struct((B, S), jnp.int32)}
    shards = {"tokens": tok_sh}
    if cfg.family == "vlm":
        structs["image_embeds"] = struct((B, cfg.num_image_tokens, cfg.d_model),
                                         dtype)
        shards["image_embeds"] = emb_sh3
    if cfg.is_encoder_decoder:
        structs["src_embeds"] = struct((B, S, cfg.d_model), dtype)
        shards["src_embeds"] = emb_sh3
    return structs, shards


def param_structs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_model, cfg, dtype=dtype), jax.random.PRNGKey(0))


def cache_structs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                  dtype=jnp.bfloat16):
    window = decode_window(cfg, shape)
    cache = jax.eval_shape(functools.partial(
        init_cache, cfg, shape.global_batch, shape.seq_len, dtype=dtype,
        window=window))
    shards = sh.cache_shardings(cfg, cache, mesh, shape.global_batch)
    return cache, shards
