"""Production mesh construction (DESIGN.md §7).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod, 256 chips) or 2×16×16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline (§Roofline)
TPU_V5E = {
    "peak_bf16_flops": 197e12,        # per chip
    "hbm_bandwidth": 819e9,           # bytes/s per chip
    "ici_bandwidth": 50e9,            # bytes/s per link
    "hbm_bytes": 16e9,                # 16 GB HBM per chip
}
