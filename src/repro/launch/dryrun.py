import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) on the production meshes, with no allocation
(ShapeDtypeStruct inputs), and emit the roofline terms (deliverable g).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
"""

import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_input_shape, INPUT_SHAPES
from repro.configs.base import TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import shapes as shp
from repro.models.transformer import decode_step
from repro.roofline.analysis import analyze_compiled
from repro.sharding import specs as sh
from repro.sharding.ctx import activation_sharding
from repro.train.train_step import make_train_step, make_loss_fn


def _pad_vocab(cfg, multiple: int):
    """Pad the PHYSICAL vocab so the embedding/logits dims divide the mesh
    model axis (hillclimb lever: stops GSPMD replicating [B,S,V] logits for
    non-divisible vocabs like seamless 256206 / granite 49155). The logical
    vocab (token-id range) is unchanged."""
    if multiple <= 0 or cfg.vocab_size % multiple == 0:
        return cfg
    padded = ((cfg.vocab_size + multiple - 1) // multiple) * multiple
    return cfg.replace(vocab_size=padded)


def _act_specs(mesh, cfg, batch):
    ba = sh.batch_axes(mesh, batch)
    specs = {"act": P(ba if ba else None, None, None)}
    m = mesh.shape.get("model", 1)
    if cfg.vocab_size % m == 0:
        specs["logits"] = P(ba if ba else None, None, "model")
    return specs



def _replicated(mesh):
    return NamedSharding(mesh, P())


def lower_train(cfg, shape, mesh, *, moe_impl: str, q_chunk: int,
                kv_chunk: int, remat: bool, unroll: int = 1,
                donate: bool = True, moment_dtype: str = "float32"):
    tc = TrainConfig(param_dtype="bfloat16", remat=remat,
                     moment_dtype=moment_dtype)
    opt_init, train_step = make_train_step(cfg, tc, moe_impl=moe_impl,
                                           q_chunk=q_chunk, kv_chunk=kv_chunk,
                                           unroll=unroll)
    p_struct = shp.param_structs(cfg, jnp.bfloat16)
    p_shard = sh.params_shardings(p_struct, mesh)
    o_struct = jax.eval_shape(opt_init, p_struct)
    o_shard = sh.opt_state_shardings(o_struct, p_shard, mesh)
    b_struct, b_shard = shp.batch_structs(cfg, shape, mesh)
    metrics_shard = {k: _replicated(mesh) for k in
                     ("loss", "ce", "aux", "lr", "gnorm")}
    jitted = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate_argnums=(0, 1) if donate else ())
    with mesh:
        lowered = jitted.lower(p_struct, o_struct, b_struct)
    return lowered


def lower_prefill(cfg, shape, mesh, *, moe_impl: str, q_chunk: int,
                  kv_chunk: int, unroll: int = 1):
    """Inference prefill: forward logits only (no cache materialization —
    the decode shapes exercise the cache path)."""
    tc = TrainConfig(param_dtype="bfloat16")
    loss_fn = make_loss_fn(cfg, tc, moe_impl=moe_impl, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, unroll=unroll)

    def prefill_step(params, batch):
        loss, parts = loss_fn(params, batch)     # forward-only scoring pass
        return parts["ce"]

    p_struct = shp.param_structs(cfg, jnp.bfloat16)
    p_shard = sh.params_shardings(p_struct, mesh)
    b_struct, b_shard = shp.batch_structs(cfg, shape, mesh)
    jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                     out_shardings=_replicated(mesh))
    with mesh:
        lowered = jitted.lower(p_struct, b_struct)
    return lowered


def lower_decode(cfg, shape, mesh, *, moe_impl: str, unroll: int = 1):
    p_struct = shp.param_structs(cfg, jnp.bfloat16)
    p_shard = sh.params_shardings(p_struct, mesh)
    c_struct, c_shard = shp.cache_structs(cfg, shape, mesh)
    b_struct, b_shard = shp.batch_structs(cfg, shape, mesh)
    logits_shard = NamedSharding(
        mesh, sh.token_spec(mesh, shape.global_batch, extra_dims=2))

    step = functools.partial(decode_step, cfg, moe_impl=moe_impl,
                             unroll=unroll)
    jitted = jax.jit(
        lambda p, b, c: step(p, b, c),
        in_shardings=(p_shard, b_shard, c_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(2,))
    with mesh:
        lowered = jitted.lower(p_struct, b_struct, c_struct)
    return lowered


def _lower(cfg, shape, mesh, *, moe_impl, q_chunk, kv_chunk, remat, unroll,
           act_constraints=False, moment_dtype="float32"):
    import contextlib
    ctx = (activation_sharding(_act_specs(mesh, cfg, shape.global_batch))
           if act_constraints else contextlib.nullcontext())
    with ctx:
        if shape.kind == "train":
            return lower_train(cfg, shape, mesh, moe_impl=moe_impl,
                               q_chunk=q_chunk, kv_chunk=kv_chunk,
                               remat=remat, unroll=unroll,
                               moment_dtype=moment_dtype), True
        if shape.kind == "prefill":
            return lower_prefill(cfg, shape, mesh, moe_impl=moe_impl,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk,
                                 unroll=unroll), False
        return lower_decode(cfg, shape, mesh, moe_impl=moe_impl,
                            unroll=unroll), False


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            moe_impl: str = "dense", q_chunk: int = 512, kv_chunk: int = 1024,
            remat: bool = None, verbose: bool = True, twin: bool = True,
            pad_vocab: int = 0, act_constraints: bool = False,
            moment_dtype: str = "float32", ssd_chunk: int = 0):
    """Two compiles per combo:

    1. PRODUCTION variant (scanned layers, blocked attention, remat for
       train): proves lowering + SPMD partitioning and gives
       memory_analysis (the "does it fit" proof).
    2. ROOFLINE TWIN (fully unrolled layers, unblocked attention): gives
       correct FLOPs / bytes / collective bytes — XLA's cost_analysis
       counts while-loop bodies once, so the scanned variant under-reports
       by ~num_layers× (validated in tests/test_roofline.py).
    """
    cfg = get_config(arch)
    if pad_vocab:
        cfg = _pad_vocab(cfg, pad_vocab)
    if ssd_chunk and cfg.ssm is not None:
        import dataclasses
        cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm,
                                                  chunk_size=ssd_chunk))
    shape = get_input_shape(shape_name)
    if remat is None:
        remat = shape.kind == "train"
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256

    # --- production compile ---
    t0 = time.time()
    lowered, include_backward = _lower(cfg, shape, mesh, moe_impl=moe_impl,
                                       q_chunk=q_chunk, kv_chunk=kv_chunk,
                                       remat=remat, unroll=1,
                                       act_constraints=act_constraints,
                                       moment_dtype=moment_dtype)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    report = analyze_compiled(compiled, arch=arch, shape=shape,
                              mesh_name=mesh_kind, chips=chips, cfg=cfg,
                              include_backward=include_backward)
    d = report.to_dict()
    d["lower_s"] = round(t_lower, 1)
    d["compile_s"] = round(t_compile, 1)
    d["moe_impl"] = moe_impl
    d["remat"] = remat
    d["pad_vocab"] = pad_vocab
    d["act_constraints"] = act_constraints
    d["moment_dtype"] = moment_dtype
    mem_stats = None
    try:
        mem_stats = compiled.memory_analysis()
    except Exception:
        pass
    del lowered, compiled

    # --- roofline twin (layer-extrapolated) ---
    # Fully unrolling a 72-80 layer model makes SPMD partitioning take tens
    # of minutes on this 1-core host. Layer cost is exactly linear in the
    # unrolled op graph, so we compile unrolled twins at k1 and k2 layers
    # and extrapolate: total(L) = total(k1) + (total(k2)-total(k1))
    # × (L-k1)/(k2-k1). Exact for per-layer-homogeneous stacks (all ours —
    # the hybrid uses whole groups as the unit).
    if twin:
        t0 = time.time()
        big = shape.seq_len if shape.kind != "decode" else q_chunk
        unit = cfg.attn_period if cfg.attn_period else 1
        L_full = cfg.num_layers
        k1, k2 = unit, 2 * unit
        if L_full <= k2:                         # tiny stacks: direct twin
            k1 = k2 = L_full

        def _twin_metrics(n_layers):
            cfg_k = cfg.replace(num_layers=n_layers)
            lowered2, _ = _lower(cfg_k, shape, mesh, moe_impl=moe_impl,
                                 q_chunk=big, kv_chunk=big, remat=remat,
                                 unroll=0, act_constraints=act_constraints,
                                 moment_dtype=moment_dtype)
            compiled2 = lowered2.compile()
            r = analyze_compiled(compiled2, arch=arch, shape=shape,
                                 mesh_name=mesh_kind, chips=chips, cfg=cfg_k,
                                 include_backward=include_backward)
            out = (r.flops_per_device, r.bytes_per_device,
                   r.collective_bytes_per_device, r.collectives)
            del lowered2, compiled2
            return out

        f1, b1, c1, coll1 = _twin_metrics(k1)
        if k2 > k1:
            f2, b2, c2, coll2 = _twin_metrics(k2)
            scale = (L_full - k1) / float(k2 - k1)
            flops = f1 + (f2 - f1) * scale
            byts = b1 + (b2 - b1) * scale
            coll = c1 + (c2 - c1) * scale
            coll_mix = {kk: (coll1.get(kk, 0) +
                             (coll2.get(kk, 0) - coll1.get(kk, 0)) * scale)
                        for kk in coll2 if kk != "counts"}
        else:
            flops, byts, coll = f1, b1, c1
            coll_mix = {kk: v for kk, v in coll1.items() if kk != "counts"}
        d["twin_compile_s"] = round(time.time() - t0, 1)
        d["twin_layers"] = [k1, k2]
        from repro.roofline.analysis import RooflineReport
        r2 = RooflineReport(
            arch=arch, shape=shape.name, mesh=mesh_kind, chips=chips,
            flops_per_device=flops, bytes_per_device=byts,
            collective_bytes_per_device=coll,
            model_flops_global=d["model_flops_global"])
        for k in ("flops_per_device", "bytes_per_device",
                  "collective_bytes_per_device", "compute_s", "memory_s",
                  "collective_s", "bottleneck", "useful_ratio"):
            d[k] = r2.to_dict()[k]
        d["collectives"] = coll_mix

    if verbose:
        if mem_stats is not None:
            print(mem_stats)
        print(json.dumps({k: v for k, v in d.items() if k != "collectives"},
                         indent=1, default=str))
    return d


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape)")
    ap.add_argument("--moe-impl", choices=["dense", "dispatch"],
                    default="dense")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=1024)
    ap.add_argument("--remat", action="store_true", default=None)
    ap.add_argument("--no-twin", action="store_true")
    ap.add_argument("--pad-vocab", type=int, default=0,
                    help="pad physical vocab to this multiple (e.g. 128)")
    ap.add_argument("--act-constraints", action="store_true")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--ssd-chunk", type=int, default=0)
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.all else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch} × {shape} × {mesh_kind}"
                print(f"=== dry-run {tag} ===", flush=True)
                try:
                    d = run_one(arch, shape, mesh_kind,
                                moe_impl=args.moe_impl, q_chunk=args.q_chunk,
                                kv_chunk=args.kv_chunk, remat=args.remat,
                                twin=not args.no_twin,
                                pad_vocab=args.pad_vocab,
                                act_constraints=args.act_constraints,
                                moment_dtype=args.moment_dtype,
                                ssd_chunk=args.ssd_chunk)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(d, default=str) + "\n")
                except Exception as e:
                    traceback.print_exc()
                    failures.append((tag, str(e)))
    if failures:
        print(f"FAILED {len(failures)}:")
        for tag, err in failures:
            print(" ", tag, "->", err[:200])
        sys.exit(1)
    print("all dry-runs OK")


if __name__ == "__main__":
    main()
