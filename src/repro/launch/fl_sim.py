"""FL simulation driver — the paper's full framework (Fig. 2) end to end.

  PYTHONPATH=src python -m repro.launch.fl_sim --dataset mnist \
      --selection divergence --rounds 30 --clients 40
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import FLConfig
from repro.configs.paper_cnn import CNN_CONFIGS
from repro.core import FLExperiment, sample_fleet, adjusted_rand_index
from repro.data import make_dataset, partition_bias


def run(dataset: str, selection: str, *, rounds: int, clients: int,
        per_round: int, sigma, local_iters: int, allocator: str = "sao",
        box_correct: bool = False, seed: int = 0, samples_per_client: int = 128,
        train_samples: int = 4000, test_samples: int = 1000,
        target_accuracy: float = 0.0, lr: float = 0.05):
    ds = make_dataset(dataset, train_samples, seed=seed)
    test = make_dataset(dataset, test_samples, seed=seed + 10_000)
    fed = partition_bias(ds, clients, samples_per_client, sigma, seed=seed + 1)
    fleet = sample_fleet(clients, seed=seed)
    fl = FLConfig(num_devices=clients, devices_per_round=per_round,
                  local_iters=local_iters, num_clusters=10,
                  learning_rate=lr, max_rounds=rounds,
                  target_accuracy=target_accuracy)
    exp = FLExperiment(CNN_CONFIGS[dataset], fed, test.images, test.labels,
                       fleet, fl, allocator=allocator, seed=seed,
                       box_correct=box_correct)
    hist = exp.run(selection, rounds=rounds,
                   target_accuracy=target_accuracy or None)
    ari = adjusted_rand_index(exp.cluster_labels, fed.majority)
    return exp, hist, ari


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["mnist", "cifar10", "fashion"],
                    default="mnist")
    ap.add_argument("--selection", default="divergence",
                    choices=["divergence", "kmeans_random", "random", "icas",
                             "rra"])
    ap.add_argument("--allocator", default="sao")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--per-round", type=int, default=10)
    ap.add_argument("--sigma", default="0.8")
    ap.add_argument("--local-iters", type=int, default=20)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--target-acc", type=float, default=0.0)
    ap.add_argument("--box-correct", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    sigma = args.sigma if args.sigma == "H" else float(args.sigma)

    exp, hist, ari = run(args.dataset, args.selection, rounds=args.rounds,
                         clients=args.clients, per_round=args.per_round,
                         sigma=sigma, local_iters=args.local_iters,
                         allocator=args.allocator, lr=args.lr,
                         box_correct=args.box_correct, seed=args.seed,
                         target_accuracy=args.target_acc)
    result = {
        "dataset": args.dataset, "selection": args.selection,
        "allocator": args.allocator, "sigma": args.sigma,
        "final_accuracy": hist.accuracy[-1],
        "accuracy": hist.accuracy,
        "total_T_s": hist.total_T, "total_E_J": hist.total_E,
        "rounds_to_target": hist.rounds_to_target,
        "clustering_ari": ari,
    }
    print(json.dumps({k: v for k, v in result.items() if k != "accuracy"},
                     indent=1))
    print("accuracy curve:", np.round(hist.accuracy, 3).tolist())
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(result) + "\n")


if __name__ == "__main__":
    main()
