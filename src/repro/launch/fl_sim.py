"""FL simulation driver — the paper's full framework (Fig. 2) end to end,
declared as an ``ExperimentSpec``.

  PYTHONPATH=src python -m repro.launch.fl_sim --dataset mnist \
      --selection divergence --rounds 30 --clients 40

  # or fully declaratively:
  PYTHONPATH=src python -m repro.launch.fl_sim --spec my_experiment.json
  PYTHONPATH=src python -m repro.launch.fl_sim --dump-spec   # print + exit
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.api import (ExperimentSpec, FleetSpec, build_cohort,
                       build_experiment, multicell_fleet_spec,
                       SELECTORS, ALLOCATORS, CHANNELS)
from repro.core import adjusted_rand_index


def run_spec(spec: ExperimentSpec, *, checkpoint_every: int = 0,
             checkpoint_dir: str = None):
    """Build + run one experiment; returns (exp, history, clustering ARI)."""
    exp = build_experiment(spec)
    hist = exp.run(rounds=spec.rounds,
                   target_accuracy=spec.target_accuracy or None,
                   checkpoint_every=checkpoint_every,
                   checkpoint_dir=checkpoint_dir,
                   checkpoint_spec=(spec.to_dict() if checkpoint_every
                                    else None))
    # Cluster-free drivers (e.g. paged async with a divergence-ranked
    # selector) never fit Alg. 2's K-means; there is no partition to score.
    ari = (adjusted_rand_index(exp.cluster_labels, exp.fed.majority)
           if exp.cluster_labels is not None else None)
    return exp, hist, ari


def resume_spec(directory: str):
    """The (authoritative) spec a checkpoint directory was taken under,
    plus its completed-round count."""
    from repro.train import checkpoint as ckpt
    path = ckpt.latest_checkpoint(directory)
    extra = ckpt.checkpoint_extra(path)
    if not extra.get("spec"):
        raise SystemExit(
            f"checkpoint {path!r} carries no ExperimentSpec (it was saved "
            "by FLExperiment.save_checkpoint without spec_dict); rebuild "
            "the experiment yourself and call exp.load_checkpoint")
    return ExperimentSpec.from_dict(extra["spec"]), int(extra["round"])


def run_resume(directory: str, *, rounds: int = 0,
               checkpoint_every: int = 0):
    """Rebuild from a checkpoint's own recorded spec, restore, and run the
    remaining rounds as a bit-identical continuation of the killed run."""
    spec, done = resume_spec(directory)
    total = rounds or spec.rounds
    exp = build_experiment(spec)
    rnd, hist = exp.load_checkpoint(directory, expected_spec=spec.to_dict())
    remaining = max(total - rnd, 0)
    if remaining:
        hist = exp.run(rounds=remaining, include_initial_round=False,
                       target_accuracy=spec.target_accuracy or None,
                       checkpoint_every=checkpoint_every,
                       checkpoint_dir=directory if checkpoint_every else None,
                       checkpoint_offset=rnd,
                       checkpoint_spec=spec.to_dict(),
                       history=hist)
    ari = (adjusted_rand_index(exp.cluster_labels, exp.fed.majority)
           if exp.cluster_labels is not None else None)
    return exp, hist, ari


def run_cohort_spec(spec: ExperimentSpec):
    """Run seeds ``seed..seed+cohort-1`` as ONE compiled vmapped program.

    Returns (runner, CohortHistory); per-seed ``FLHistory`` views come from
    ``cohort_hist.history(i)``.
    """
    runner = build_cohort(spec)
    return runner, runner.run()


def _allocator_ref(allocator: str, box_correct: bool):
    """Fold the legacy --box-correct flag into the sao allocator params."""
    if box_correct and allocator.partition(":")[0] == "sao":
        return {"name": "sao", "params": {"box_correct": True}}
    return allocator


def run(dataset: str, selection: str, *, rounds: int, clients: int,
        per_round: int, sigma, local_iters: int, allocator: str = "sao",
        box_correct: bool = False, seed: int = 0, samples_per_client: int = 128,
        train_samples: int = 4000, test_samples: int = 1000,
        target_accuracy: float = 0.0, lr: float = 0.05):
    """Back-compat kwargs shim over :func:`run_spec`."""
    alloc = _allocator_ref(allocator, box_correct)
    spec = ExperimentSpec(dataset=dataset, selection=selection,
                          rounds=rounds, clients=clients,
                          devices_per_round=per_round, sigma=sigma,
                          local_iters=local_iters, allocator=alloc,
                          seed=seed, samples_per_client=samples_per_client,
                          train_samples=train_samples,
                          test_samples=test_samples,
                          target_accuracy=target_accuracy,
                          learning_rate=lr)
    return run_spec(spec)


def _fleet_from_args(args):
    """--fleet-spec file (+--channel override) or --cells/--channel
    shorthand; None (legacy sample_fleet) when neither is given."""
    if getattr(args, "fleet_spec", None):
        if getattr(args, "cells", 0):
            raise SystemExit("--cells conflicts with --fleet-spec (the "
                             "file defines the cells); edit the spec or "
                             "drop one flag")
        with open(args.fleet_spec) as f:
            fs = FleetSpec.from_json(f.read())
        if getattr(args, "channel", None):
            fs = fs.replace(channel=args.channel)
        return fs
    cells = getattr(args, "cells", 0) or 0
    channel = getattr(args, "channel", None)
    if cells <= 0 and channel is None:
        return None
    return multicell_fleet_spec(max(cells, 1),
                                **({"channel": channel} if channel else {}))


def spec_from_args(args) -> ExperimentSpec:
    if args.spec:
        with open(args.spec) as f:
            return ExperimentSpec.from_json(f.read())
    sigma = args.sigma if args.sigma == "H" else float(args.sigma)
    extra = {}
    if getattr(args, "aggregator", None):
        extra["aggregator"] = args.aggregator
    if getattr(args, "async_buffer", 0):
        if extra.get("aggregator"):
            raise SystemExit("--async-buffer selects the fedbuff aggregator "
                             "itself; it conflicts with --aggregator")
        # --async-buffer M routes the run onto the buffered-asynchronous
        # tick engine via the fedbuff:M[:alpha] aggregator
        extra["aggregator"] = (
            f"fedbuff:{args.async_buffer}:{args.staleness_alpha}")
    if getattr(args, "churn", None):
        from repro.core.async_engine import parse_churn
        leave, join = parse_churn(args.churn)
        extra["churn_leave"], extra["churn_join"] = leave, join
    if getattr(args, "store", "dense") != "dense":
        extra["store"] = args.store
    if getattr(args, "k_max", 0):
        extra["k_max"] = args.k_max
    if getattr(args, "div_refresh_every", 0):
        extra["div_refresh_every"] = args.div_refresh_every
    if getattr(args, "faults", None):
        extra["faults"] = args.faults
    if getattr(args, "quarantine_after", 0):
        extra["quarantine_after"] = args.quarantine_after
    return ExperimentSpec(dataset=args.dataset, selection=args.selection,
                          allocator=_allocator_ref(args.allocator,
                                                   args.box_correct),
                          rounds=args.rounds,
                          clients=args.clients,
                          devices_per_round=args.per_round, sigma=sigma,
                          local_iters=args.local_iters,
                          learning_rate=args.lr,
                          target_accuracy=args.target_acc, seed=args.seed,
                          cohort=args.cohort,
                          fleet=_fleet_from_args(args), **extra)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=None,
                    help="ExperimentSpec JSON file (overrides other flags)")
    ap.add_argument("--dataset", choices=["mnist", "cifar10", "fashion"],
                    default="mnist")
    ap.add_argument("--selection", default="divergence",
                    help=f"one of {SELECTORS.names()} (':arg' allowed)")
    ap.add_argument("--allocator", default="sao",
                    help=f"one of {ALLOCATORS.names()} (e.g. 'fedl:2.0')")
    ap.add_argument("--aggregator", default=None,
                    help="aggregation strategy (':arg' allowed), e.g. "
                         "'fedavgm:0.9', or the robust folds 'trimmed:0.1' "
                         "/ 'clipnorm:1.0'; default fedavg")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--per-round", type=int, default=10)
    ap.add_argument("--sigma", default="0.8")
    ap.add_argument("--local-iters", type=int, default=20)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--target-acc", type=float, default=0.0)
    ap.add_argument("--box-correct", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cohort", type=int, default=1,
                    help="run seeds seed..seed+N-1 as one vmapped, "
                         "device-sharded program (traceable strategies only)")
    ap.add_argument("--fleet-spec", default=None,
                    help="FleetSpec JSON file: declarative multi-cell "
                         "topology + channel model (repro.api.scenario)")
    ap.add_argument("--cells", type=int, default=0,
                    help="shorthand: N default cells on the auto layout "
                         "(N>1 implies the multicell-interference channel; "
                         "add --channel multicell-dynamic for selection-"
                         "driven per-round interference); runs (seeds × "
                         "cells) lanes on the cohort engine")
    ap.add_argument("--channel", default=None,
                    help=f"channel model override, one of {CHANNELS.names()} "
                         "(':arg' allowed, e.g. 'rayleigh-block:0.01')")
    ap.add_argument("--async-buffer", type=int, default=0, metavar="M",
                    help="buffered-asynchronous engine: fire the "
                         "aggregation buffer every M landed updates "
                         "(fedbuff:M aggregator); 0 = synchronous barrier")
    ap.add_argument("--staleness-alpha", type=float, default=0.0,
                    help="staleness discount exponent for --async-buffer: "
                         "fired weights scale by (1+age)^-alpha")
    ap.add_argument("--churn", default=None, metavar="P_LEAVE[:P_JOIN]",
                    help="per-tick Bernoulli client churn probabilities "
                         "(needs --async-buffer), e.g. '0.05:0.1'")
    ap.add_argument("--store", choices=["dense", "paged"], default="dense",
                    help="client-state backend: 'dense' keeps the [N, P] "
                         "plane on device; 'paged' pages cold rows to host "
                         "(O(k_max*P) device memory; composes with "
                         "--async-buffer and --churn)")
    ap.add_argument("--k-max", type=int, default=0,
                    help="paged store: active-plane rows kept on device "
                         "(0 = auto: max(per-round, 256) capped at N)")
    ap.add_argument("--div-refresh-every", type=int, default=0,
                    help="paged store: refresh exact divergences every R "
                         "selections/ticks (1 = exact dense signal every "
                         "time; 0 = lazy drift-bounded staleness)")
    ap.add_argument("--faults", default=None, metavar="KIND:RATE[,...]",
                    help="fault-injection spec, e.g. 'outage:0.1,"
                         "corrupt:0.05' — kinds: outage, chan_outage "
                         "(needs a stateful --channel, e.g. gauss-markov), "
                         "corrupt, byzantine[+byz_scale:S], deadline:T_s; "
                         "rates in [0,1]")
    ap.add_argument("--quarantine-after", type=int, default=0, metavar="K",
                    help="quarantine a client after K non-finite uploads "
                         "(0 = never); pairs with robust aggregators "
                         "--aggregator trimmed:f / clipnorm:c")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="K",
                    help="snapshot the full run state (global row, opt "
                         "state, stats, RNG, store rows) every K rounds "
                         "(atomic; needs --checkpoint-dir)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for round_* snapshots + LATEST pointer")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume from the latest complete snapshot under "
                         "DIR; the checkpoint's own recorded spec is "
                         "authoritative (other experiment flags ignored). "
                         "Continuation is bit-identical to the unkilled run")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved ExperimentSpec JSON and exit")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.checkpoint_every < 0:
        raise SystemExit("--checkpoint-every must be >= 0")
    if args.checkpoint_every and not (args.checkpoint_dir or args.resume):
        raise SystemExit("--checkpoint-every needs --checkpoint-dir "
                         "(or --resume, which keeps snapshotting in place)")

    if args.resume:
        if args.spec or args.cohort > 1 or args.cells:
            raise SystemExit("--resume restores the checkpoint's own spec; "
                             "it conflicts with --spec/--cohort/--cells")
        if args.checkpoint_dir and args.checkpoint_dir != args.resume:
            raise SystemExit("--resume continues snapshotting into the "
                             "resumed directory; drop --checkpoint-dir")
        exp, hist, ari = run_resume(args.resume,
                                    checkpoint_every=args.checkpoint_every)
        spec = exp.spec
        result = {
            "spec": spec.to_dict(),
            "resumed_from": args.resume,
            "final_accuracy": hist.accuracy[-1],
            "accuracy": hist.accuracy,
            "total_T_s": hist.total_T, "total_E_J": hist.total_E,
            "rounds_to_target": hist.rounds_to_target,
            "clustering_ari": ari,
        }
        print(json.dumps({k: v for k, v in result.items()
                          if k not in ("accuracy", "spec")}, indent=1))
        print("accuracy curve:", np.round(hist.accuracy, 3).tolist())
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(result) + "\n")
        return

    spec = spec_from_args(args)
    if args.dump_spec:
        print(spec.to_json(indent=1))
        return

    if spec.cohort > 1 or spec.num_cells > 1:
        if args.checkpoint_every:
            raise SystemExit("--checkpoint-every is a single-lane feature; "
                             "the vmapped cohort program has no host "
                             "boundary to snapshot at (drop --cohort/"
                             "--cells or the checkpoint flags)")
        if spec.target_accuracy:
            print(f"warning: --cohort runs all {spec.rounds} rounds as one "
                  "compiled program; target_accuracy early stopping is "
                  "ignored (compute rounds-to-target from the curves)",
                  file=sys.stderr)
        runner, ch = run_cohort_spec(spec)
        aris = [adjusted_rand_index(e.cluster_labels, e.fed.majority)
                for e in runner.experiments]
        result = {
            "spec": spec.to_dict(),
            "seeds": ch.seeds,
            "cells": ch.lane_cells,
            "final_accuracy_mean": float(np.mean(ch.final_accuracy)),
            "final_accuracy_std": float(np.std(ch.final_accuracy)),
            "final_accuracy_per_seed": ch.final_accuracy.tolist(),
            "total_T_s_per_seed": np.sum(ch.T_k, axis=1).tolist(),
            "total_E_J_per_seed": np.sum(ch.E_k, axis=1).tolist(),
            "clustering_ari_per_seed": aris,
        }
        print(json.dumps({k: v for k, v in result.items() if k != "spec"},
                         indent=1))
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(result) + "\n")
        return

    exp, hist, ari = run_spec(spec, checkpoint_every=args.checkpoint_every,
                              checkpoint_dir=args.checkpoint_dir)
    result = {
        "spec": spec.to_dict(),
        "final_accuracy": hist.accuracy[-1],
        "accuracy": hist.accuracy,
        "total_T_s": hist.total_T, "total_E_J": hist.total_E,
        "rounds_to_target": hist.rounds_to_target,
        "clustering_ari": ari,
    }
    print(json.dumps({k: v for k, v in result.items()
                      if k not in ("accuracy", "spec")}, indent=1))
    print("accuracy curve:", np.round(hist.accuracy, 3).tolist())
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(result) + "\n")


if __name__ == "__main__":
    main()
