"""jnp ports of the device-selection policies (paper §IV + baselines) for
the device-resident round pipeline.

Each port returns a FIXED-SIZE padded index set ``(idx, mask)`` so the
whole selection step traces under ``lax.scan`` / ``vmap``:

  * ``idx`` is int32 of a static length (``pad_size``); padding lanes hold
    the out-of-bounds sentinel ``num_devices`` — JAX gathers clamp and
    scatters DROP out-of-bounds indices, so padding is self-masking on both
    the read (client data) and write (client-param store) sides.
  * ``mask`` is True exactly on the valid lanes; it zeroes the padded
    lanes' aggregation weights and excludes them from the SAO reductions.

The host/numpy versions in ``repro.core.selection`` stay registered and
bit-authoritative for the legacy Python loop; parity between the two is
pinned by ``tests/test_traced_engine.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.wireless import effective_arrays, rate_mbps


def _per_cluster_topk(scores, labels, num_clusters: int, s: int,
                      num_devices: int):
    """Top-``s`` lanes per cluster of a masked score vector.

    Returns ``(idx, mask)`` of static length ``num_clusters * s``; clusters
    with fewer than ``s`` members pad with the sentinel. Cluster blocks are
    emitted in label order (matching the host loop's concatenation order),
    each block descending by score (``lax.top_k``).
    """
    member = labels[None, :] == jnp.arange(num_clusters)[:, None]   # [c, N]
    masked = jnp.where(member, scores[None, :], -jnp.inf)
    top_scores, top_idx = jax.lax.top_k(masked, s)                  # [c, s]
    valid = jnp.isfinite(top_scores)
    idx = jnp.where(valid, top_idx, num_devices)
    return idx.reshape(-1).astype(jnp.int32), valid.reshape(-1)


def select_divergence_traced(divergences, labels, *, num_clusters: int,
                             s: int, num_devices: int, avail=None):
    """Algorithm 4: top-s weight divergence per cluster (masked ``top_k``).

    ``avail`` (optional churn mask, 1.0/0.0 — the async engine's
    ``arr["avail"]``) sinks unavailable devices' scores to −inf, so they
    can never win a cluster slot; ``None`` is a static no-op branch (the
    traced program is unchanged — the dense bit-parity pins stay exact)."""
    if avail is not None:
        divergences = jnp.where(avail > 0.0, divergences, -jnp.inf)
    return _per_cluster_topk(divergences, labels, num_clusters, s, num_devices)


def select_kmeans_random_traced(key, labels, *, num_clusters: int, s: int,
                                num_devices: int):
    """Algorithm 3: s uniform devices per cluster — uniform random scores
    make per-cluster ``top_k`` a without-replacement uniform draw."""
    scores = jax.random.uniform(key, (num_devices,))
    return _per_cluster_topk(scores, labels, num_clusters, s, num_devices)


def select_random_traced(key, *, num_devices: int, S: int):
    """FedAvg: S uniform devices without replacement."""
    idx = jax.random.permutation(key, num_devices)[:S].astype(jnp.int32)
    return idx, jnp.ones((S,), bool)


def select_icas_traced(divergences, arr, *, bandwidth_mhz: float,
                       num_devices: int, S: int, beta: float):
    """ICAS: importance × channel-rate geometric blend, deterministic top-S.

    An ``arr["avail"]`` churn mask (1.0/0.0) sinks unavailable devices to
    −inf score and unmasks only the available winners; absent, the
    program (and its bit-parity with the host version) is unchanged."""
    avail = arr.get("avail") if isinstance(arr, dict) else None
    arr = effective_arrays(arr)
    rates = rate_mbps(bandwidth_mhz / num_devices, arr["J"])
    u = divergences / jnp.maximum(jnp.max(divergences), 1e-12)
    r = rates / jnp.maximum(jnp.max(rates), 1e-12)
    score = jnp.power(u, beta) * jnp.power(r, 1.0 - beta)
    if avail is None:
        _, idx = jax.lax.top_k(score, S)
        return idx.astype(jnp.int32), jnp.ones((S,), bool)
    top, idx = jax.lax.top_k(jnp.where(avail > 0.0, score, -jnp.inf), S)
    valid = jnp.isfinite(top)
    return (jnp.where(valid, idx, num_devices).astype(jnp.int32), valid)


def select_stochastic_sched_traced(key, arr, *, bandwidth_mhz: float,
                                   num_devices: int, S: int):
    """Churn-aware stochastic scheduling (Perazzone et al., arXiv
    2201.07912 style): each device participates independently with a
    probability proportional to its energy headroom over its per-round
    cost (transmission + computation energy at full clock), normalized so
    the EXPECTED participating-set size is ``S``. An ``arr["avail"]``
    vector (the async engine's churn mask, 1.0/0.0) zeroes unavailable
    devices' probabilities — a churned-out client is never sampled."""
    avail = arr.get("avail")
    arr = effective_arrays(arr)
    cost = (arr["H"] / rate_mbps(bandwidth_mhz / S, arr["J"])
            + arr["G"] * jnp.square(arr["f_max"]))
    ratio = arr["e_cons"] / jnp.maximum(cost, 1e-12)
    if avail is not None:
        ratio = ratio * avail
    p = jnp.clip(S * ratio / jnp.maximum(jnp.sum(ratio), 1e-12), 0.0, 1.0)
    mask = jax.random.uniform(key, (num_devices,)) < p
    # never empty: fall back to the highest-headroom (available) device
    mask = jnp.where(jnp.any(mask), mask,
                     jnp.arange(num_devices) == jnp.argmax(ratio))
    idx = jnp.where(mask, jnp.arange(num_devices), num_devices)
    return idx.astype(jnp.int32), mask


def select_rra_traced(key, arr, *, bandwidth_mhz: float, num_devices: int,
                      target_mean: int):
    """RRA: energy-efficiency thresholding as a fixed-size (N-lane) masked
    variant — the participating-set size varies through the mask, not the
    shape. Mirrors the host version including the scale clamp."""
    arr = effective_arrays(arr)
    e_eq = arr["H"] / rate_mbps(bandwidth_mhz / target_mean, arr["J"])
    eff = arr["e_cons"] / jnp.maximum(e_eq, 1e-12)
    q = 100.0 * min(1.0, target_mean / num_devices)
    p = jnp.clip(eff / jnp.percentile(eff, q), 0.0, 1.0)
    scale = jnp.minimum(1.0, target_mean / jnp.maximum(jnp.sum(p), 1e-9))
    mask = jax.random.uniform(key, (num_devices,)) < p * scale
    # never empty: fall back to the most efficient device
    mask = jnp.where(jnp.any(mask), mask,
                     jnp.arange(num_devices) == jnp.argmax(eff))
    idx = jnp.where(mask, jnp.arange(num_devices), num_devices)
    return idx.astype(jnp.int32), mask
