"""Registered server-side aggregation strategies: eq. (4) FedAvg and the
beyond-paper FedAvgM server-momentum variant.

Both implement the FLAT traced contract the scanned round pipeline
drives: the engine hands the aggregator the round's client rows as a
``[S, P]`` slab of the flat parameter plane plus the flat ``[P]`` global
row, and ``aggregate_flat`` lowers eq. (4) to ONE masked weighted
row-reduction (``repro.kernels.ops.flat_aggregate`` — the Pallas GEMV
kernel on TPU, its bit-matching jnp reference elsewhere).
``init_flat_state`` builds the server-optimizer state carried in
``RoundState.opt_state`` (``None``, or a flat ``[P]`` row for FedAvgM);
``load_flat_state`` syncs a finished scan back into the stateful host
object so a traced run can be continued by the Python loop. (The PR-2
stacked-pytree traced contract is gone — a custom aggregator without the
flat methods simply keeps the host loop, see ``FLExperiment.traceable``.)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.api.registry import AGGREGATORS, Strategy, StrategyError
from repro.core.algorithms import ServerMomentum
from repro.kernels import ops
from repro.utils.trees import (tree_flatten_vector,
                               tree_weighted_mean_stacked, unflatten_vector)


@AGGREGATORS.register("fedavg")
@dataclass
class FedAvgAggregator(Strategy):
    """Eq. (4): D_n-weighted average of the participating local models.
    Stateless, so the driver may fuse it into the jitted round step."""

    fuses_with_engine = True
    traceable = True

    def aggregate(self, global_params, stacked_params, weights):
        return tree_weighted_mean_stacked(stacked_params, weights)

    def reset(self):
        pass

    # -- flat-plane traced contract (the scanned hot path) --------------
    def init_flat_state(self, global_vec):
        return None

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        return ops.flat_aggregate(rows, weights), opt_state

    def load_flat_state(self, opt_state, spec):
        pass


@AGGREGATORS.register("fedbuff")
@dataclass
class FedBuffAggregator(Strategy):
    """FedBuff (Nguyen et al. 2022): buffered asynchronous aggregation.
    Spelled ``fedbuff:M[:alpha]`` in compact form — the buffer fires when
    ``m`` updates have landed, folding them with staleness-discounted
    weights ``w ∝ (1 + age)^(-alpha)``.

    Marking itself ``async_capable`` routes ``run_rounds`` to the
    buffered-asynchronous tick loop (``repro.core.async_engine``); the
    engine pre-discounts the weights via :meth:`staleness_weights`, so
    ``aggregate_flat`` is the same single masked row-reduction as FedAvg
    — which is exactly what makes the sync-degeneracy parity pin
    (``fedbuff:M>=K`` + ``alpha=0`` ≡ scanned fedavg) hold bit for bit.
    """

    m: int = 10
    alpha: float = 0.0

    fuses_with_engine = False
    traceable = True
    async_capable = True

    def __post_init__(self):
        if self.m < 1:
            raise StrategyError(
                f"fedbuff buffer size must be >= 1 (got {self.m})")
        if self.alpha < 0:
            raise StrategyError(
                f"fedbuff staleness exponent must be >= 0 (got {self.alpha})")

    @classmethod
    def from_string(cls, arg):
        """``fedbuff:M[:alpha]`` — Registry.resolve splits at the FIRST
        colon only, so ``arg`` may itself carry an ``M:alpha`` pair."""
        if arg is None or arg == "":
            return cls()
        m_s, _, alpha_s = arg.partition(":")
        try:
            m = int(m_s)
            alpha = float(alpha_s) if alpha_s else 0.0
        except ValueError:
            raise StrategyError(
                f"fedbuff:{arg}: expected 'M[:alpha]' with integer M and "
                "float alpha") from None
        return cls(m=m, alpha=alpha)

    @property
    def buffer_size(self) -> int:
        return self.m

    @property
    def staleness_alpha(self) -> float:
        return self.alpha

    def staleness_weights(self, age):
        """Per-client staleness discount ``(1 + age)^(-alpha)``. The
        ``alpha == 0`` branch is static so the degenerate config multiplies
        by nothing at all (bit-parity with plain fedavg weights)."""
        if self.alpha == 0.0:
            return jnp.ones_like(age)
        return jnp.power(1.0 + age, -self.alpha)

    def aggregate(self, global_params, stacked_params, weights):
        return tree_weighted_mean_stacked(stacked_params, weights)

    def reset(self):
        pass

    # -- flat-plane traced contract (the scanned hot path) --------------
    def init_flat_state(self, global_vec):
        return None

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        return ops.flat_aggregate(rows, weights), opt_state

    def load_flat_state(self, opt_state, spec):
        pass


@AGGREGATORS.register("fedavgm")
@dataclass
class FedAvgMAggregator(Strategy):
    """FedAvgM (Hsu et al. 2019): momentum over the server pseudo-gradient.
    Spelled ``fedavgm:<β>`` in compact form."""

    beta: float = 0.9
    lr: float = 1.0

    fuses_with_engine = False
    traceable = True

    def __post_init__(self):
        self._opt = ServerMomentum(self.beta, self.lr)

    def aggregate(self, global_params, stacked_params, weights):
        agg = tree_weighted_mean_stacked(stacked_params, weights)
        return self._opt.step(global_params, agg)

    def reset(self):
        self._opt = ServerMomentum(self.beta, self.lr)

    # -- flat-plane traced contract (the scanned hot path) --------------
    def init_flat_state(self, global_vec):
        if self._opt.v is not None:      # continue from host-loop momentum
            return tree_flatten_vector(self._opt.v)
        # fresh v starts at zeros: β·0 + Δ ≡ Δ matches the lazy-None init
        return jnp.zeros_like(global_vec)

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        agg = ops.flat_aggregate(rows, weights)
        v = self.beta * opt_state + (global_vec - agg)  # pseudo-gradient
        return global_vec - self.lr * v, v

    def load_flat_state(self, opt_state, spec):
        self._opt.v = unflatten_vector(spec, opt_state)
