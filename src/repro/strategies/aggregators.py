"""Registered server-side aggregation strategies: eq. (4) FedAvg and the
beyond-paper FedAvgM server-momentum variant.

Both implement the FLAT traced contract the scanned round pipeline
drives: the engine hands the aggregator the round's client rows as a
``[S, P]`` slab of the flat parameter plane plus the flat ``[P]`` global
row, and ``aggregate_flat`` lowers eq. (4) to ONE masked weighted
row-reduction (``repro.kernels.ops.flat_aggregate`` — the Pallas GEMV
kernel on TPU, its bit-matching jnp reference elsewhere).
``init_flat_state`` builds the server-optimizer state carried in
``RoundState.opt_state`` (``None``, or a flat ``[P]`` row for FedAvgM);
``load_flat_state`` syncs a finished scan back into the stateful host
object so a traced run can be continued by the Python loop. (The PR-2
stacked-pytree traced contract is gone — a custom aggregator without the
flat methods simply keeps the host loop, see ``FLExperiment.traceable``.)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.api.registry import AGGREGATORS, Strategy, StrategyError
from repro.core.algorithms import ServerMomentum
from repro.kernels import ops
from repro.utils.trees import (flatten_stacked, tree_flatten_vector,
                               tree_unflatten_vector,
                               tree_weighted_mean_stacked, unflatten_vector)


@AGGREGATORS.register("fedavg")
@dataclass
class FedAvgAggregator(Strategy):
    """Eq. (4): D_n-weighted average of the participating local models.
    Stateless, so the driver may fuse it into the jitted round step."""

    fuses_with_engine = True
    traceable = True

    def aggregate(self, global_params, stacked_params, weights):
        return tree_weighted_mean_stacked(stacked_params, weights)

    def reset(self):
        pass

    # -- flat-plane traced contract (the scanned hot path) --------------
    def init_flat_state(self, global_vec):
        return None

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        return ops.flat_aggregate(rows, weights), opt_state

    def load_flat_state(self, opt_state, spec):
        pass


@AGGREGATORS.register("fedbuff")
@dataclass
class FedBuffAggregator(Strategy):
    """FedBuff (Nguyen et al. 2022): buffered asynchronous aggregation.
    Spelled ``fedbuff:M[:alpha]`` in compact form — the buffer fires when
    ``m`` updates have landed, folding them with staleness-discounted
    weights ``w ∝ (1 + age)^(-alpha)``.

    Marking itself ``async_capable`` routes ``run_rounds`` to the
    buffered-asynchronous tick loop (``repro.core.async_engine``); the
    engine pre-discounts the weights via :meth:`staleness_weights`, so
    ``aggregate_flat`` is the same single masked row-reduction as FedAvg
    — which is exactly what makes the sync-degeneracy parity pin
    (``fedbuff:M>=K`` + ``alpha=0`` ≡ scanned fedavg) hold bit for bit.
    """

    m: int = 10
    alpha: float = 0.0

    fuses_with_engine = False
    traceable = True
    async_capable = True

    def __post_init__(self):
        if self.m < 1:
            raise StrategyError(
                f"fedbuff buffer size must be >= 1 (got {self.m})")
        if self.alpha < 0:
            raise StrategyError(
                f"fedbuff staleness exponent must be >= 0 (got {self.alpha})")

    @classmethod
    def from_string(cls, arg):
        """``fedbuff:M[:alpha]`` — Registry.resolve splits at the FIRST
        colon only, so ``arg`` may itself carry an ``M:alpha`` pair."""
        if arg is None or arg == "":
            return cls()
        m_s, _, alpha_s = arg.partition(":")
        try:
            m = int(m_s)
            alpha = float(alpha_s) if alpha_s else 0.0
        except ValueError:
            raise StrategyError(
                f"fedbuff:{arg}: expected 'M[:alpha]' with integer M and "
                "float alpha") from None
        return cls(m=m, alpha=alpha)

    @property
    def buffer_size(self) -> int:
        return self.m

    @property
    def staleness_alpha(self) -> float:
        return self.alpha

    def staleness_weights(self, age):
        """Per-client staleness discount ``(1 + age)^(-alpha)``. The
        ``alpha == 0`` branch is static so the degenerate config multiplies
        by nothing at all (bit-parity with plain fedavg weights)."""
        if self.alpha == 0.0:
            return jnp.ones_like(age)
        return jnp.power(1.0 + age, -self.alpha)

    def aggregate(self, global_params, stacked_params, weights):
        return tree_weighted_mean_stacked(stacked_params, weights)

    def reset(self):
        pass

    # -- flat-plane traced contract (the scanned hot path) --------------
    def init_flat_state(self, global_vec):
        return None

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        return ops.flat_aggregate(rows, weights), opt_state

    def load_flat_state(self, opt_state, spec):
        pass


@AGGREGATORS.register("fedavgm")
@dataclass
class FedAvgMAggregator(Strategy):
    """FedAvgM (Hsu et al. 2019): momentum over the server pseudo-gradient.
    Spelled ``fedavgm:<β>`` in compact form."""

    beta: float = 0.9
    lr: float = 1.0

    fuses_with_engine = False
    traceable = True

    def __post_init__(self):
        self._opt = ServerMomentum(self.beta, self.lr)

    def aggregate(self, global_params, stacked_params, weights):
        agg = tree_weighted_mean_stacked(stacked_params, weights)
        return self._opt.step(global_params, agg)

    def reset(self):
        self._opt = ServerMomentum(self.beta, self.lr)

    # -- flat-plane traced contract (the scanned hot path) --------------
    def init_flat_state(self, global_vec):
        if self._opt.v is not None:      # continue from host-loop momentum
            return tree_flatten_vector(self._opt.v)
        # fresh v starts at zeros: β·0 + Δ ≡ Δ matches the lazy-None init
        return jnp.zeros_like(global_vec)

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        agg = ops.flat_aggregate(rows, weights)
        v = self.beta * opt_state + (global_vec - agg)  # pseudo-gradient
        return global_vec - self.lr * v, v

    def load_flat_state(self, opt_state, spec):
        self._opt.v = unflatten_vector(spec, opt_state)


class _FlatRobustMixin:
    """Shared host plumbing of the robust aggregators: the stacked-pytree
    ``aggregate`` contract is served by routing through the FLAT fold, so
    the host loop and the scanned program share one implementation."""

    def reset(self):
        pass

    def init_flat_state(self, global_vec):
        return None

    def load_flat_state(self, opt_state, spec):
        pass

    def aggregate(self, global_params, stacked_params, weights):
        rows = flatten_stacked(stacked_params)
        gvec = tree_flatten_vector(global_params)
        vec, _ = self.aggregate_flat(
            gvec, rows, jnp.asarray(weights, jnp.float32), None)
        return tree_unflatten_vector(global_params, vec)


@AGGREGATORS.register("trimmed")
@dataclass
class TrimmedMeanAggregator(_FlatRobustMixin, Strategy):
    """Coordinate-wise trimmed mean (Yin et al. 2018): per coordinate,
    sort the participating updates, drop the ``⌊f·k⌋`` smallest and
    largest, average the rest UNWEIGHTED. Spelled ``trimmed:f`` with the
    trim fraction ``f ∈ [0, 0.5)`` — the defense holds while the
    adversarial fraction stays below ``f``; a byzantine update that
    negates-and-amplifies (``repro.core.faults``) lands in the trimmed
    tails coordinate by coordinate and never touches the fold.

    Zero-weight lanes (padding, dropped/failed uploads) are excluded by
    sorting them to ``+inf`` above every real value; ``f = 0``
    degenerates to the unweighted mean of the participants (NOT eq. (4):
    trimming is rank-based, so D_n-weighting does not compose with it).
    """

    f: float = 0.1

    fuses_with_engine = False
    traceable = True

    def __post_init__(self):
        if not 0.0 <= self.f < 0.5:
            raise StrategyError(
                f"trimmed-mean fraction must lie in [0, 0.5); got {self.f}")

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        valid = weights.astype(jnp.float32) > 0.0
        k = jnp.sum(valid.astype(jnp.int32))
        t = jnp.floor(self.f * k.astype(jnp.float32)).astype(jnp.int32)
        # invalid lanes sort above every real coordinate, so ranks
        # [0, k) are exactly the participants
        srt = jnp.sort(jnp.where(valid[:, None], rows, jnp.inf), axis=0)
        ranks = jnp.arange(rows.shape[0], dtype=jnp.int32)[:, None]
        keep = (ranks >= t) & (ranks < k - t)
        total = jnp.sum(jnp.where(keep, srt, 0.0), axis=0)
        denom = jnp.maximum(k - 2 * t, 1).astype(jnp.float32)
        return total / denom, opt_state


@AGGREGATORS.register("clipnorm")
@dataclass
class ClipNormAggregator(_FlatRobustMixin, Strategy):
    """Eq. (4) with per-client update-norm clipping: each row's delta
    from the global is rescaled to ``‖w_n − g‖ ≤ c`` before the weighted
    mean. Spelled ``clipnorm:c`` (``c > 0``, in flat-plane L2 units).
    Bounds any single client's pull on the global row — the
    magnitude-attack complement to ``trimmed:f``'s rank defense, and it
    PRESERVES the D_n weighting the trimmed mean must give up."""

    c: float = 1.0

    fuses_with_engine = False
    traceable = True

    def __post_init__(self):
        if not self.c > 0.0:
            raise StrategyError(
                f"clipnorm radius must be > 0; got {self.c}")

    def aggregate_flat(self, global_vec, rows, weights, opt_state):
        delta = rows - global_vec[None, :]
        nrm = jnp.sqrt(jnp.sum(jnp.square(delta), axis=1, keepdims=True))
        scale = jnp.minimum(1.0, self.c / jnp.maximum(nrm, 1e-12))
        clipped = global_vec[None, :] + delta * scale
        return ops.flat_aggregate(clipped, weights), opt_state
