"""Registered server-side aggregation strategies: eq. (4) FedAvg and the
beyond-paper FedAvgM server-momentum variant.

Both implement the traced contract used by the scanned round pipeline:
``init_traced_state(params)`` builds the server-optimizer pytree carried in
``RoundState.opt_state`` and ``aggregate_traced`` is a pure function
``(global, stacked, weights, opt_state) -> (new_global, new_opt_state)``.
``load_traced_state`` syncs the final scanned state back into the stateful
host object so a traced run can be continued by the Python loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.api.registry import AGGREGATORS, Strategy
from repro.core.algorithms import ServerMomentum
from repro.utils.trees import (tree_add, tree_scale, tree_sub,
                               tree_weighted_mean_stacked, tree_zeros_like)


@AGGREGATORS.register("fedavg")
@dataclass
class FedAvgAggregator(Strategy):
    """Eq. (4): D_n-weighted average of the participating local models.
    Stateless, so the driver may fuse it into the jitted round step."""

    fuses_with_engine = True
    traceable = True

    def aggregate(self, global_params, stacked_params, weights):
        return tree_weighted_mean_stacked(stacked_params, weights)

    def reset(self):
        pass

    # -- traced contract ------------------------------------------------
    def init_traced_state(self, global_params):
        return None

    def aggregate_traced(self, global_params, stacked_params, weights,
                         opt_state):
        return tree_weighted_mean_stacked(stacked_params, weights), opt_state

    def load_traced_state(self, opt_state):
        pass


@AGGREGATORS.register("fedavgm")
@dataclass
class FedAvgMAggregator(Strategy):
    """FedAvgM (Hsu et al. 2019): momentum over the server pseudo-gradient.
    Spelled ``fedavgm:<β>`` in compact form."""

    beta: float = 0.9
    lr: float = 1.0

    fuses_with_engine = False
    traceable = True

    def __post_init__(self):
        self._opt = ServerMomentum(self.beta, self.lr)

    def aggregate(self, global_params, stacked_params, weights):
        agg = tree_weighted_mean_stacked(stacked_params, weights)
        return self._opt.step(global_params, agg)

    def reset(self):
        self._opt = ServerMomentum(self.beta, self.lr)

    # -- traced contract ------------------------------------------------
    def init_traced_state(self, global_params):
        if self._opt.v is not None:      # continue from host-loop momentum
            return self._opt.v
        # fresh v starts at zeros: β·0 + Δ ≡ Δ matches the lazy-None init
        return tree_zeros_like(global_params)

    def aggregate_traced(self, global_params, stacked_params, weights,
                         opt_state):
        agg = tree_weighted_mean_stacked(stacked_params, weights)
        delta = tree_sub(global_params, agg)            # pseudo-gradient
        v = tree_add(tree_scale(opt_state, self.beta), delta)
        return tree_sub(global_params, tree_scale(v, self.lr)), v

    def load_traced_state(self, opt_state):
        self._opt.v = opt_state
