"""Registered server-side aggregation strategies: eq. (4) FedAvg and the
beyond-paper FedAvgM server-momentum variant.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.api.registry import AGGREGATORS, Strategy
from repro.core.algorithms import ServerMomentum
from repro.utils.trees import tree_weighted_mean_stacked


@AGGREGATORS.register("fedavg")
@dataclass
class FedAvgAggregator(Strategy):
    """Eq. (4): D_n-weighted average of the participating local models.
    Stateless, so the driver may fuse it into the jitted round step."""

    fuses_with_engine = True

    def aggregate(self, global_params, stacked_params, weights):
        return tree_weighted_mean_stacked(stacked_params, weights)

    def reset(self):
        pass


@AGGREGATORS.register("fedavgm")
@dataclass
class FedAvgMAggregator(Strategy):
    """FedAvgM (Hsu et al. 2019): momentum over the server pseudo-gradient.
    Spelled ``fedavgm:<β>`` in compact form."""

    beta: float = 0.9
    lr: float = 1.0

    fuses_with_engine = False

    def __post_init__(self):
        self._opt = ServerMomentum(self.beta, self.lr)

    def aggregate(self, global_params, stacked_params, weights):
        agg = tree_weighted_mean_stacked(stacked_params, weights)
        return self._opt.step(global_params, agg)

    def reset(self):
        self._opt = ServerMomentum(self.beta, self.lr)
