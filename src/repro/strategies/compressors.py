"""Registered uplink-compression strategies. Compression shrinks the
payload z_n, which enters SAO through H_n = z_n·p_n and t_com = z_n/r_n —
and is simulated faithfully (quantize→dequantize on the real update trees)
so the accuracy cost is measured, not assumed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.api.registry import COMPRESSORS, Strategy
from repro.core.compression import (compress_int8, compress_topk,
                                    payload_mbit)


class _DeltaCompressor(Strategy):
    """Shared delta-coding scaffold: compress the stacked client *updates*
    (w_new − w_global), then re-add the global model.

    ``apply`` is pure jnp over static shapes, so every built-in compressor
    is ``traceable`` inside the scanned round pipeline."""

    identity = False
    traceable = True

    def compress(self, tree):
        raise NotImplementedError

    def apply(self, stacked_new, global_params):
        deltas = jax.tree_util.tree_map(
            lambda n, g: n - g[None], stacked_new, global_params)
        deltas = self.compress(deltas)
        return jax.tree_util.tree_map(
            lambda d, g: g[None] + d, deltas, global_params)


@COMPRESSORS.register("none")
@dataclass(frozen=True)
class NoCompression(Strategy):
    """Full-precision uplink: updates and the fleet's own z_n untouched."""

    identity = True
    traceable = True

    def compress(self, tree):
        return tree

    def apply(self, stacked_new, global_params):
        return stacked_new

    def payload_mbit(self, num_params: int, num_leaves: int) -> Optional[float]:
        return None


@COMPRESSORS.register("int8")
@dataclass(frozen=True)
class Int8Compressor(_DeltaCompressor):
    """Per-leaf symmetric int8 quantization (8 bits + fp32 scale/leaf)."""

    def compress(self, tree):
        return compress_int8(tree)

    def payload_mbit(self, num_params: int, num_leaves: int) -> float:
        return payload_mbit(num_params, "int8", num_leaves)


@COMPRESSORS.register("topk")
@dataclass(frozen=True)
class TopKCompressor(_DeltaCompressor):
    """Magnitude top-k sparsification keeping ``fraction`` of entries
    (values fp32 + log2(n)-bit indices). Spelled ``topk:<fraction>``."""

    fraction: float = 0.01

    def compress(self, tree):
        return compress_topk(tree, self.fraction)

    def payload_mbit(self, num_params: int, num_leaves: int) -> float:
        return payload_mbit(num_params, f"topk:{self.fraction}", num_leaves)
