"""Registered uplink-compression strategies. Compression shrinks the
payload z_n, which enters SAO through H_n = z_n·p_n and t_com = z_n/r_n —
and is simulated faithfully (quantize→dequantize on the real update trees)
so the accuracy cost is measured, not assumed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.registry import COMPRESSORS, Strategy
from repro.core.compression import (compress_int8, compress_topk,
                                    payload_mbit)


class _DeltaCompressor(Strategy):
    """Shared delta-coding scaffold: compress the stacked client *updates*
    (w_new − w_global), then re-add the global model.

    ``apply`` is pure jnp over static shapes, so every built-in compressor
    is ``traceable`` inside the scanned round pipeline. ``apply_flat`` is
    the flat-plane form: rows are ``[S, P]`` slabs of the client-weight
    buffer and the per-leaf quantizers run on the spec's column segments —
    the same values in the same reduction order as the pytree leaves, so
    the two forms quantize bit-identically."""

    identity = False
    traceable = True

    def compress(self, tree):
        raise NotImplementedError

    def apply(self, stacked_new, global_params):
        deltas = jax.tree_util.tree_map(
            lambda n, g: n - g[None], stacked_new, global_params)
        deltas = self.compress(deltas)
        return jax.tree_util.tree_map(
            lambda d, g: g[None] + d, deltas, global_params)

    def apply_flat(self, rows, global_vec, spec):
        """Compress flat client rows [S, P] against the flat global [P].

        One subtract / add on the whole plane; the quantizer sees each
        leaf's column segment as a ``[S, size]`` block (scales and top-k
        thresholds stay per-leaf, matching the payload model)."""
        deltas = rows - global_vec[None, :]
        blocks = {n: deltas[:, spec.columns(n)] for n in spec.names}
        blocks = self.compress(blocks)
        return global_vec[None, :] + jnp.concatenate(
            [blocks[n] for n in spec.names], axis=1)


@COMPRESSORS.register("none")
@dataclass(frozen=True)
class NoCompression(Strategy):
    """Full-precision uplink: updates and the fleet's own z_n untouched."""

    identity = True
    traceable = True

    def compress(self, tree):
        return tree

    def apply(self, stacked_new, global_params):
        return stacked_new

    def apply_flat(self, rows, global_vec, spec):
        return rows

    def payload_mbit(self, num_params: int, num_leaves: int) -> Optional[float]:
        return None


@COMPRESSORS.register("int8")
@dataclass(frozen=True)
class Int8Compressor(_DeltaCompressor):
    """Per-leaf symmetric int8 quantization (8 bits + fp32 scale/leaf)."""

    def compress(self, tree):
        return compress_int8(tree)

    def payload_mbit(self, num_params: int, num_leaves: int) -> float:
        return payload_mbit(num_params, "int8", num_leaves)


@COMPRESSORS.register("topk")
@dataclass(frozen=True)
class TopKCompressor(_DeltaCompressor):
    """Magnitude top-k sparsification keeping ``fraction`` of entries
    (values fp32 + log2(n)-bit indices). Spelled ``topk:<fraction>``."""

    fraction: float = 0.01

    def compress(self, tree):
        return compress_topk(tree, self.fraction)

    def payload_mbit(self, num_params: int, num_leaves: int) -> float:
        return payload_mbit(num_params, f"topk:{self.fraction}", num_leaves)
