"""Built-in strategy implementations. Importing this package populates the
``repro.api`` registries; user code can register additional strategies at
any time with ``@SELECTORS.register(...)`` etc.
"""
from repro.strategies import selectors as selectors        # noqa: F401
from repro.strategies import allocators as allocators      # noqa: F401
from repro.strategies import aggregators as aggregators    # noqa: F401
from repro.strategies import compressors as compressors    # noqa: F401
