"""Registered spectrum-allocation strategies: SAO (Alg. 5, ours) and the
§VI-A baselines. Each takes the ``fleet_arrays`` dict of the *selected*
devices plus the band B [MHz] and returns an ``Allocation`` (T_k, E_k, b, f).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.api.protocols import Allocation
from repro.api.registry import ALLOCATORS, Strategy, StrategyError
from repro.core.baselines import equal_bandwidth, fedl_lambda
from repro.core.sao import _Q, solve_sao


@ALLOCATORS.register("sao")
@dataclass(frozen=True)
class SAOAllocator(Strategy):
    """Algorithm 5: per-device bandwidth + CPU frequency under per-device
    energy budgets. ``box_correct`` enables the beyond-paper KKT box fix."""

    box_correct: bool = False

    def allocate(self, arr, B: float) -> Allocation:
        s = solve_sao(arr, B, box_correct=self.box_correct)
        e = arr["G"] * jnp.square(s.f) + arr["H"] / _Q(s.b, arr["J"])
        return Allocation(T=float(s.T), E=float(jnp.sum(e)),
                          b=np.asarray(s.b), f=np.asarray(s.f))

    @classmethod
    def from_string(cls, arg):
        if arg in (None, ""):
            return cls()
        if arg in ("box", "box_correct"):
            return cls(box_correct=True)
        raise StrategyError(f"sao:{arg}: the only ':arg' is 'box' "
                            "(KKT box correction)")


@ALLOCATORS.register("equal")
@dataclass(frozen=True)
class EqualBandwidthAllocator(Strategy):
    """Baseline 1: b_n = B/S, fastest feasible frequency per device."""

    def allocate(self, arr, B: float) -> Allocation:
        r = equal_bandwidth(arr, B)
        return Allocation(T=float(r.T), E=float(jnp.sum(r.e)),
                          b=np.asarray(r.b), f=np.asarray(r.f))


@ALLOCATORS.register("fedl")
@dataclass(frozen=True)
class FEDLAllocator(Strategy):
    """Baseline 2 — FEDL [27]: min Σe + λ·T without per-device energy
    constraints. Spelled ``fedl:<λ>`` in compact form."""

    lam: float = 1.0

    def allocate(self, arr, B: float) -> Allocation:
        r = fedl_lambda(arr, B, self.lam)
        return Allocation(T=float(r.T), E=float(jnp.sum(r.e)),
                          b=np.asarray(r.b), f=np.asarray(r.f))
