"""Registered spectrum-allocation strategies: SAO (Alg. 5, ours) and the
§VI-A baselines. Each takes the ``fleet_arrays`` dict of the *selected*
devices plus the band B [MHz] and returns an ``Allocation`` (T_k, E_k, b, f).

``allocate`` keeps its outputs on device (jnp scalars/arrays) — the solves
are jitted and the host boundary (``FLHistory.append``) is the single place
values are pulled back, so the driver never blocks between the allocation
and the training dispatch.

Every built-in implements the traced contract (``allocate_traced``: padded
selected sets + participation masks) used by the scanned round pipeline —
including FEDL, whose §VI-A λ tuning is a ``lax.while_loop`` bisection
(``fedl_auto``) rather than the old host-driven loop, so baseline sweeps
run on the cohort engine too.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.api.protocols import Allocation
from repro.api.registry import ALLOCATORS, Strategy, StrategyError
from repro.core.baselines import (equal_bandwidth, fedl_lambda,
                                  tune_fedl_lambda)
from repro.core.sao import _Q, solve_sao
from repro.core.wireless import effective_arrays, masked_sum


@ALLOCATORS.register("sao")
@dataclass(frozen=True)
class SAOAllocator(Strategy):
    """Algorithm 5: per-device bandwidth + CPU frequency under per-device
    energy budgets. ``box_correct`` enables the beyond-paper KKT box fix."""

    box_correct: bool = False

    traceable = True

    def allocate(self, arr, B: float) -> Allocation:
        T, E, b, f = self.allocate_traced(arr, B, None)
        return Allocation(T=T, E=E, b=b, f=f)

    def allocate_traced(self, arr, B: float, mask):
        # fold interference BEFORE the energy accounting too — the rate the
        # solver allocated against is the degraded one. solve_sao folds
        # again at its own entry; that nesting is exactly-once ONLY because
        # effective_arrays pops the "inr" key (pinned by
        # tests/test_channel_dynamics.py)
        arr = effective_arrays(arr)
        s = solve_sao(arr, B, mask=mask, box_correct=self.box_correct)
        e = arr["G"] * jnp.square(s.f) + arr["H"] / _Q(s.b, arr["J"])
        if mask is not None:
            e = jnp.where(mask, e, 0.0)
        return s.T, jnp.sum(e), s.b, s.f

    @classmethod
    def from_string(cls, arg):
        if arg in (None, ""):
            return cls()
        if arg in ("box", "box_correct"):
            return cls(box_correct=True)
        raise StrategyError(f"sao:{arg}: the only ':arg' is 'box' "
                            "(KKT box correction)")


@ALLOCATORS.register("equal")
@dataclass(frozen=True)
class EqualBandwidthAllocator(Strategy):
    """Baseline 1: b_n = B/S, fastest feasible frequency per device."""

    traceable = True

    def allocate(self, arr, B: float) -> Allocation:
        r = equal_bandwidth(arr, B)
        return Allocation(T=r.T, E=jnp.sum(r.e), b=r.b, f=r.f)

    def allocate_traced(self, arr, B: float, mask):
        r = equal_bandwidth(arr, B, mask=mask)
        return r.T, jnp.sum(r.e), r.b, r.f


@ALLOCATORS.register("fedl")
@dataclass(frozen=True)
class FEDLAllocator(Strategy):
    """Baseline 2 — FEDL [27]: min Σe + λ·T without per-device energy
    constraints, at a fixed λ. Spelled ``fedl:<λ>`` in compact form."""

    lam: float = 1.0

    traceable = True

    def allocate(self, arr, B: float) -> Allocation:
        r = fedl_lambda(arr, B, self.lam)
        return Allocation(T=r.T, E=jnp.sum(r.e), b=r.b, f=r.f)

    def allocate_traced(self, arr, B: float, mask):
        r = fedl_lambda(arr, B, self.lam, mask=mask)
        return r.T, masked_sum(r.e, mask), r.b, r.f


@ALLOCATORS.register("fedl_auto")
@dataclass(frozen=True)
class FEDLAutoAllocator(Strategy):
    """FEDL with the §VI-A λ protocol ('the device with the highest energy
    cost just meets its budget') tuned PER ROUND inside the traced program
    — a ``lax.while_loop`` bisection over the grid solve, so the baseline
    sweeps run device-resident. ``fedl_auto:<iters>`` sets the bisection
    depth; ``n_grid`` the T-grid of each inner solve."""

    iters: int = 12
    n_grid: int = 60

    traceable = True

    def _solve(self, arr, B, mask):
        arr = effective_arrays(arr)
        lam = tune_fedl_lambda(arr, B, mask=mask, iters=self.iters,
                               n_grid=self.n_grid)
        return fedl_lambda(arr, B, lam, n_grid=self.n_grid, mask=mask)

    def allocate(self, arr, B: float) -> Allocation:
        r = self._solve(arr, B, None)
        return Allocation(T=r.T, E=jnp.sum(r.e), b=r.b, f=r.f)

    def allocate_traced(self, arr, B: float, mask):
        r = self._solve(arr, B, mask)
        return r.T, masked_sum(r.e, mask), r.b, r.f
