"""Registered device-selection strategies (paper §IV, Algorithms 3-4, and
the compared baselines). Thin adapters over ``repro.core.selection``; each
consumes only what it needs from the ``SelectionContext``.

Every built-in also implements the traced contract
(``repro.api.protocols.TracedSelector``): ``select_traced`` is a pure jnp
function over fixed-size padded index sets (ports in
``repro.strategies.traced``), which lets the driver move the whole round
loop onto the device (``lax.scan`` in ``repro.core.engine.run_rounds``).
Deterministic policies (divergence, icas) are bit-compatible with their
numpy versions; the stochastic ones draw from ``jax.random`` instead of the
host Generator and are parity-tested structurally.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.api.protocols import SelectionContext, TracedContext
from repro.api.registry import SELECTORS, Strategy, StrategyError
from repro.core.selection import (select_divergence, select_icas,
                                  select_kmeans_random, select_random,
                                  select_rra)
from repro.core.wireless import effective_arrays, fleet_arrays, rate_mbps
from repro.strategies.traced import (select_divergence_traced,
                                     select_icas_traced,
                                     select_kmeans_random_traced,
                                     select_random_traced, select_rra_traced,
                                     select_stochastic_sched_traced)


def _require_clusters(ctx: SelectionContext, name: str):
    if ctx.clusters is None:
        raise StrategyError(
            f"selector {name!r} needs K-means clusters; run the initial "
            "round (Algorithm 2) first")
    return ctx.clusters


@SELECTORS.register("random")
@dataclass(frozen=True)
class RandomSelector(Strategy):
    """FedAvg [31]: S uniform devices."""

    traceable = True
    needs_rng = True
    needs_divergence = False

    def select(self, ctx: SelectionContext) -> np.ndarray:
        return select_random(ctx.rng, ctx.num_devices, ctx.devices_per_round)

    def pad_size(self, ctx: TracedContext) -> int:
        return ctx.devices_per_round

    def select_traced(self, key, divergences, labels, arr, ctx: TracedContext):
        return select_random_traced(key, num_devices=ctx.num_devices,
                                    S=ctx.devices_per_round)


@SELECTORS.register("kmeans_random")
@dataclass(frozen=True)
class KMeansRandomSelector(Strategy):
    """Algorithm 3: s random devices from each cluster."""

    traceable = True
    needs_rng = True
    needs_divergence = False
    needs_clusters = True

    def select(self, ctx: SelectionContext) -> np.ndarray:
        return select_kmeans_random(ctx.rng,
                                    _require_clusters(ctx, self.registry_name),
                                    ctx.selected_per_cluster)

    def pad_size(self, ctx: TracedContext) -> int:
        return ctx.num_clusters * ctx.selected_per_cluster

    def select_traced(self, key, divergences, labels, arr, ctx: TracedContext):
        return select_kmeans_random_traced(
            key, labels, num_clusters=ctx.num_clusters,
            s=ctx.selected_per_cluster, num_devices=ctx.num_devices)


@SELECTORS.register("divergence")
@dataclass(frozen=True)
class DivergenceSelector(Strategy):
    """Algorithm 4 (ours): top-s weight divergence per cluster."""

    traceable = True
    needs_rng = False
    needs_divergence = True
    needs_clusters = True

    def select(self, ctx: SelectionContext) -> np.ndarray:
        return select_divergence(ctx.divergences(),
                                 _require_clusters(ctx, self.registry_name),
                                 ctx.selected_per_cluster)

    def pad_size(self, ctx: TracedContext) -> int:
        return ctx.num_clusters * ctx.selected_per_cluster

    def select_traced(self, key, divergences, labels, arr, ctx: TracedContext):
        return select_divergence_traced(
            divergences, labels, num_clusters=ctx.num_clusters,
            s=ctx.selected_per_cluster, num_devices=ctx.num_devices,
            avail=arr.get("avail") if isinstance(arr, dict) else None)


@SELECTORS.register("icas")
@dataclass(frozen=True)
class ICASSelector(Strategy):
    """ICAS [42]: importance × channel-rate blend, deterministic top-S."""

    beta: float = 0.5

    traceable = True
    needs_rng = False
    needs_divergence = True

    def select(self, ctx: SelectionContext) -> np.ndarray:
        arr = effective_arrays(fleet_arrays(ctx.fleet))
        rates = np.asarray(rate_mbps(ctx.bandwidth_mhz / ctx.num_devices,
                                     arr["J"]))
        return select_icas(ctx.divergences(), rates, ctx.devices_per_round,
                           beta=self.beta)

    def pad_size(self, ctx: TracedContext) -> int:
        return ctx.devices_per_round

    def select_traced(self, key, divergences, labels, arr, ctx: TracedContext):
        return select_icas_traced(
            divergences, arr, bandwidth_mhz=ctx.bandwidth_mhz,
            num_devices=ctx.num_devices, S=ctx.devices_per_round,
            beta=self.beta)


@SELECTORS.register("stochastic-sched")
@dataclass(frozen=True)
class StochasticSchedSelector(Strategy):
    """Churn-aware stochastic scheduling (Perazzone et al. [arXiv
    2201.07912] style): independent per-device participation probabilities
    proportional to energy headroom over per-round cost, normalized to an
    expected set size of ``devices_per_round``. The traced form reads the
    async engine's ``arr["avail"]`` churn mask, so a churned-out client's
    probability is exactly zero — the selector of choice for the
    buffered-asynchronous tick loop."""

    traceable = True
    needs_rng = True
    needs_divergence = False

    def select(self, ctx: SelectionContext) -> np.ndarray:
        arr = effective_arrays(fleet_arrays(ctx.fleet))
        S = ctx.devices_per_round
        cost = (np.asarray(arr["H"]
                           / rate_mbps(ctx.bandwidth_mhz / S, arr["J"]))
                + np.asarray(arr["G"]) * np.square(np.asarray(arr["f_max"])))
        ratio = np.asarray(arr["e_cons"]) / np.maximum(cost, 1e-12)
        p = np.clip(S * ratio / max(float(ratio.sum()), 1e-12), 0.0, 1.0)
        mask = ctx.rng.random(ctx.num_devices) < p
        if not mask.any():               # never empty (mirrors the port)
            mask[int(np.argmax(ratio))] = True
        return np.flatnonzero(mask)

    def pad_size(self, ctx: TracedContext) -> int:
        return ctx.num_devices          # the participating set size varies

    def select_traced(self, key, divergences, labels, arr, ctx: TracedContext):
        return select_stochastic_sched_traced(
            key, arr, bandwidth_mhz=ctx.bandwidth_mhz,
            num_devices=ctx.num_devices, S=ctx.devices_per_round)


@SELECTORS.register("rra")
@dataclass(frozen=True)
class RRASelector(Strategy):
    """RRA [39]: energy-efficiency participation thresholding; the selected
    set size varies per round (~``target_mean`` on average, §VI-C)."""

    target_mean: int = 45

    traceable = True
    needs_rng = True
    needs_divergence = False

    def select(self, ctx: SelectionContext) -> np.ndarray:
        arr = effective_arrays(fleet_arrays(ctx.fleet))
        e_eq = np.asarray(
            arr["H"] / rate_mbps(ctx.bandwidth_mhz / self.target_mean,
                                 arr["J"]))
        return select_rra(ctx.rng, e_eq, np.asarray(arr["e_cons"]),
                          target_mean=self.target_mean)

    def pad_size(self, ctx: TracedContext) -> int:
        return ctx.num_devices          # the participating set size varies

    def select_traced(self, key, divergences, labels, arr, ctx: TracedContext):
        return select_rra_traced(
            key, arr, bandwidth_mhz=ctx.bandwidth_mhz,
            num_devices=ctx.num_devices, target_mean=self.target_mean)
