"""Registered device-selection strategies (paper §IV, Algorithms 3-4, and
the compared baselines). Thin adapters over ``repro.core.selection``; each
consumes only what it needs from the ``SelectionContext``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.api.protocols import SelectionContext
from repro.api.registry import SELECTORS, Strategy, StrategyError
from repro.core.selection import (select_divergence, select_icas,
                                  select_kmeans_random, select_random,
                                  select_rra)
from repro.core.wireless import fleet_arrays, rate_mbps


def _require_clusters(ctx: SelectionContext, name: str):
    if ctx.clusters is None:
        raise StrategyError(
            f"selector {name!r} needs K-means clusters; run the initial "
            "round (Algorithm 2) first")
    return ctx.clusters


@SELECTORS.register("random")
@dataclass(frozen=True)
class RandomSelector(Strategy):
    """FedAvg [31]: S uniform devices."""

    def select(self, ctx: SelectionContext) -> np.ndarray:
        return select_random(ctx.rng, ctx.num_devices, ctx.devices_per_round)


@SELECTORS.register("kmeans_random")
@dataclass(frozen=True)
class KMeansRandomSelector(Strategy):
    """Algorithm 3: s random devices from each cluster."""

    def select(self, ctx: SelectionContext) -> np.ndarray:
        return select_kmeans_random(ctx.rng,
                                    _require_clusters(ctx, self.registry_name),
                                    ctx.selected_per_cluster)


@SELECTORS.register("divergence")
@dataclass(frozen=True)
class DivergenceSelector(Strategy):
    """Algorithm 4 (ours): top-s weight divergence per cluster."""

    def select(self, ctx: SelectionContext) -> np.ndarray:
        return select_divergence(ctx.divergences(),
                                 _require_clusters(ctx, self.registry_name),
                                 ctx.selected_per_cluster)


@SELECTORS.register("icas")
@dataclass(frozen=True)
class ICASSelector(Strategy):
    """ICAS [42]: importance × channel-rate blend, deterministic top-S."""

    beta: float = 0.5

    def select(self, ctx: SelectionContext) -> np.ndarray:
        arr = fleet_arrays(ctx.fleet)
        rates = np.asarray(rate_mbps(ctx.bandwidth_mhz / ctx.num_devices,
                                     arr["J"]))
        return select_icas(ctx.divergences(), rates, ctx.devices_per_round,
                           beta=self.beta)


@SELECTORS.register("rra")
@dataclass(frozen=True)
class RRASelector(Strategy):
    """RRA [39]: energy-efficiency participation thresholding; the selected
    set size varies per round (~``target_mean`` on average, §VI-C)."""

    target_mean: int = 45

    def select(self, ctx: SelectionContext) -> np.ndarray:
        arr = fleet_arrays(ctx.fleet)
        e_eq = np.asarray(
            arr["H"] / rate_mbps(ctx.bandwidth_mhz / self.target_mean,
                                 arr["J"]))
        return select_rra(ctx.rng, e_eq, np.asarray(arr["e_cons"]),
                          target_mean=self.target_mean)
