"""Synthetic non-iid next-token data for the federated LM workload.

Each "dialect" is an independent Markov token stream
(``repro.data.synthetic.make_token_stream`` with a decorrelated seed), cut
into ``[seq_len + 1]`` windows. Windows ride the ``Dataset.images`` slot and
the window's dialect id rides ``Dataset.labels`` — so the paper's non-iid
bias machinery (``partition_bias``: each client draws a σ-fraction from its
majority class) partitions clients by DIALECT exactly as it partitions the
CNN datasets by image class, and the K-means / divergence / selection layers
see the same statistical structure the paper studies.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import Dataset, make_token_stream

#: decorrelates per-dialect stream seeds from the dataset seed
DIALECT_SEED_STRIDE = 1009


def make_lm_dataset(num_samples: int, seq_len: int, vocab_size: int,
                    num_dialects: int = 10, seed: int = 0) -> Dataset:
    """``images``: [num_samples, seq_len+1] int32 token windows;
    ``labels``: [num_samples] dialect ids; ``num_classes = num_dialects``.

    Window order is shuffled (seeded) so a biased partition's per-client
    draws interleave dialects the way the image datasets interleave
    classes."""
    if num_samples <= 0:
        raise ValueError(f"num_samples must be positive, got {num_samples}")
    per = -(-num_samples // num_dialects)        # windows per dialect (ceil)
    width = seq_len + 1
    windows = np.empty((num_dialects * per, width), np.int32)
    dialects = np.empty((num_dialects * per,), np.int32)
    for d in range(num_dialects):
        stream = np.asarray(make_token_stream(
            vocab_size, per * width,
            seed=seed * DIALECT_SEED_STRIDE + d))
        windows[d * per:(d + 1) * per] = stream[:per * width].reshape(per,
                                                                      width)
        dialects[d * per:(d + 1) * per] = d
    rng = np.random.default_rng(seed)
    order = rng.permutation(num_dialects * per)[:num_samples]
    return Dataset(images=windows[order], labels=dialects[order],
                   num_classes=num_dialects)
