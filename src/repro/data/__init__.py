from repro.data.synthetic import Dataset, make_dataset, make_token_stream
from repro.data.lm_data import make_lm_dataset
from repro.data.partition import (FederatedData, LazyFederatedData,
                                  partition_bias, partition_bias_lazy,
                                  partition_dirichlet)
