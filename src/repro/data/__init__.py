from repro.data.synthetic import Dataset, make_dataset, make_token_stream
from repro.data.partition import FederatedData, partition_bias, partition_dirichlet
