"""Non-iid client partitioners — the paper's σ-bias scheme (§IV-A, §VI).

σ ∈ (0, 1): each client draws σ·D_n samples from its majority class and the
rest uniformly from the other classes.
σ = "H":    80% majority class + 20% a secondary class (two labels only).
Also a Dirichlet partitioner for broader non-iid sweeps (beyond paper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Union

import numpy as np

from repro.data.synthetic import Dataset


@dataclass
class FederatedData:
    """Fixed-size per-client arrays so client local updates can be vmapped."""
    images: np.ndarray        # [N_clients, D, H, W, C]
    labels: np.ndarray        # [N_clients, D]
    majority: np.ndarray      # [N_clients] ground-truth majority class
    sizes: np.ndarray         # [N_clients] nominal D_n (for eq. 4 weights)

    lazy = False

    @property
    def num_clients(self) -> int:
        return self.images.shape[0]


@dataclass
class LazyFederatedData:
    """Index-backed partition for population-scale fleets.

    Materializing ``[N, D, H, W, C]`` images at N=1e6 costs ~100× the
    dataset itself (every sample is drawn by many clients); this variant
    stores only per-client SAMPLE INDICES into the shared pool, so the
    partition is O(N·D) int32 and a cohort's image stack is gathered on
    demand (``pool_images[indices[idx]]`` — a device-side gather in the
    paged driver). Consumed by ``FLExperiment(store="paged")`` only: the
    dense/traced paths require the materialized stack.
    """
    pool_images: np.ndarray   # [T, H, W, C] the shared sample pool
    indices: np.ndarray       # [N_clients, D] int32 rows into the pool
    labels: np.ndarray        # [N_clients, D]
    majority: np.ndarray      # [N_clients] ground-truth majority class
    sizes: np.ndarray         # [N_clients] nominal D_n (for eq. 4 weights)

    lazy = True

    @property
    def num_clients(self) -> int:
        return self.indices.shape[0]

    @property
    def nbytes(self) -> int:
        return (self.pool_images.nbytes + self.indices.nbytes
                + self.labels.nbytes + self.majority.nbytes
                + self.sizes.nbytes)


def _bias_indices_loop(rng, by_class, K: int, num_clients: int,
                       samples_per_client: int, sigma,
                       majority: np.ndarray) -> np.ndarray:
    """The paper's per-client sample draw, one client at a time — THE rng
    stream existing partitions are pinned to (draw order: [secondary,]
    rest, major, shuffle)."""
    idx = np.empty((num_clients, samples_per_client), np.int64)
    for n in range(num_clients):
        m = majority[n]
        if sigma == "H":
            n_major = int(round(0.8 * samples_per_client))
            sec = rng.choice([k for k in range(K) if k != m])
            rest_pool = by_class[sec]
            rest = rng.choice(rest_pool, samples_per_client - n_major)
        else:
            n_major = int(round(float(sigma) * samples_per_client))
            others = np.concatenate([by_class[k] for k in range(K) if k != m])
            rest = rng.choice(others, samples_per_client - n_major)
        major = rng.choice(by_class[m], n_major)
        sel = np.concatenate([major, rest])
        rng.shuffle(sel)
        idx[n] = sel
    return idx


#: clients at/above which :func:`partition_bias_lazy` switches from the
#: per-client rng loop (bit-compatible with :func:`partition_bias`) to the
#: vectorized draw path — the loop costs minutes at 1e6 clients
VECTORIZED_PARTITION_MIN = 100_000


def _bias_indices_vectorized(rng, by_class, K: int, num_clients: int,
                             samples_per_client: int, sigma,
                             majority: np.ndarray) -> np.ndarray:
    """Whole-fleet sample draw in a handful of vectorized rng calls — the
    same σ-bias distribution as the loop but its OWN draw stream (still
    deterministic in ``seed``; a 1e6-client partition takes seconds, not
    minutes). With-replacement draws, like ``rng.choice`` above."""
    D = samples_per_client
    lens = np.array([len(c) for c in by_class])
    pool = np.zeros((K, lens.max()), np.int64)
    for k, c in enumerate(by_class):
        pool[k, :len(c)] = c
    n_major = int(round((0.8 if sigma == "H" else float(sigma)) * D))

    def draw(cls_per_client, count, cls_pool, cls_lens):
        u = rng.random((num_clients, count))
        col = (u * cls_lens[cls_per_client][:, None]).astype(np.int64)
        return cls_pool[cls_per_client[:, None], col]

    major = draw(majority, n_major, pool, lens)
    if sigma == "H":
        sec = rng.integers(0, K - 1, num_clients)
        sec = sec + (sec >= majority)              # skip the majority class
        rest = draw(sec, D - n_major, pool, lens)
    else:
        olens = lens.sum() - lens                  # |others| per class
        opool = np.zeros((K, int(olens.max())), np.int64)
        for m in range(K):
            opool[m, :olens[m]] = np.concatenate(
                [by_class[k] for k in range(K) if k != m])
        rest = draw(majority, D - n_major, opool, olens)
    return rng.permuted(np.concatenate([major, rest], axis=1), axis=1)


def partition_bias(ds: Dataset, num_clients: int, samples_per_client: int,
                   sigma: Union[float, str], seed: int = 0,
                   sizes: np.ndarray = None) -> FederatedData:
    """The paper's non-iid partitioner. Majority classes are assigned
    round-robin so every class is some client's majority (as in Fig. 4)."""
    rng = np.random.default_rng(seed)
    K = ds.num_classes
    by_class = [np.flatnonzero(ds.labels == k) for k in range(K)]
    majority = np.arange(num_clients) % K
    rng.shuffle(majority)
    idx = _bias_indices_loop(rng, by_class, K, num_clients,
                             samples_per_client, sigma, majority)
    if sizes is None:
        sizes = np.full(num_clients, samples_per_client, np.float64)
    return FederatedData(images=ds.images[idx],
                         labels=ds.labels[idx].astype(np.int32),
                         majority=majority,
                         sizes=np.asarray(sizes, np.float64))


def partition_bias_lazy(ds: Dataset, num_clients: int,
                        samples_per_client: int, sigma: Union[float, str],
                        seed: int = 0,
                        sizes: np.ndarray = None) -> LazyFederatedData:
    """σ-bias partition as per-client INDICES into the shared pool — the
    O(N·D)-int32 form population-scale paged experiments consume.

    Below :data:`VECTORIZED_PARTITION_MIN` clients the draws replay
    :func:`partition_bias`'s per-client rng stream exactly, so
    ``partition_bias_lazy(...).indices`` selects the same samples as the
    materialized partition of the same seed; at/above it the vectorized
    stream takes over (same distribution, still seed-deterministic)."""
    rng = np.random.default_rng(seed)
    K = ds.num_classes
    by_class = [np.flatnonzero(ds.labels == k) for k in range(K)]
    majority = np.arange(num_clients) % K
    rng.shuffle(majority)
    draw = (_bias_indices_loop if num_clients < VECTORIZED_PARTITION_MIN
            else _bias_indices_vectorized)
    idx = draw(rng, by_class, K, num_clients, samples_per_client, sigma,
               majority)
    if sizes is None:
        sizes = np.full(num_clients, samples_per_client, np.float64)
    return LazyFederatedData(pool_images=ds.images,
                             indices=idx.astype(np.int32),
                             labels=ds.labels[idx].astype(np.int32),
                             majority=majority,
                             sizes=np.asarray(sizes, np.float64))


def partition_dirichlet(ds: Dataset, num_clients: int, samples_per_client: int,
                        alpha: float, seed: int = 0) -> FederatedData:
    """Dirichlet(α) label-distribution partitioner (beyond-paper sweeps)."""
    rng = np.random.default_rng(seed)
    K = ds.num_classes
    by_class = [np.flatnonzero(ds.labels == k) for k in range(K)]
    imgs = np.empty((num_clients, samples_per_client) + ds.images.shape[1:],
                    ds.images.dtype)
    labs = np.empty((num_clients, samples_per_client), np.int32)
    majority = np.zeros(num_clients, np.int64)
    for n in range(num_clients):
        pvec = rng.dirichlet(np.full(K, alpha))
        counts = rng.multinomial(samples_per_client, pvec)
        sel = np.concatenate([
            rng.choice(by_class[k], c) for k, c in enumerate(counts) if c > 0])
        rng.shuffle(sel)
        imgs[n] = ds.images[sel]
        labs[n] = ds.labels[sel]
        majority[n] = int(np.argmax(counts))
    return FederatedData(images=imgs, labels=labs, majority=majority,
                         sizes=np.full(num_clients, samples_per_client, np.float64))
