"""Non-iid client partitioners — the paper's σ-bias scheme (§IV-A, §VI).

σ ∈ (0, 1): each client draws σ·D_n samples from its majority class and the
rest uniformly from the other classes.
σ = "H":    80% majority class + 20% a secondary class (two labels only).
Also a Dirichlet partitioner for broader non-iid sweeps (beyond paper).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Union

import numpy as np

from repro.data.synthetic import Dataset


@dataclass
class FederatedData:
    """Fixed-size per-client arrays so client local updates can be vmapped."""
    images: np.ndarray        # [N_clients, D, H, W, C]
    labels: np.ndarray        # [N_clients, D]
    majority: np.ndarray      # [N_clients] ground-truth majority class
    sizes: np.ndarray         # [N_clients] nominal D_n (for eq. 4 weights)

    @property
    def num_clients(self) -> int:
        return self.images.shape[0]


def partition_bias(ds: Dataset, num_clients: int, samples_per_client: int,
                   sigma: Union[float, str], seed: int = 0,
                   sizes: np.ndarray = None) -> FederatedData:
    """The paper's non-iid partitioner. Majority classes are assigned
    round-robin so every class is some client's majority (as in Fig. 4)."""
    rng = np.random.default_rng(seed)
    K = ds.num_classes
    by_class = [np.flatnonzero(ds.labels == k) for k in range(K)]
    majority = np.arange(num_clients) % K
    rng.shuffle(majority)

    imgs = np.empty((num_clients, samples_per_client) + ds.images.shape[1:],
                    ds.images.dtype)
    labs = np.empty((num_clients, samples_per_client), np.int32)
    for n in range(num_clients):
        m = majority[n]
        if sigma == "H":
            n_major = int(round(0.8 * samples_per_client))
            sec = rng.choice([k for k in range(K) if k != m])
            rest_pool = by_class[sec]
            rest = rng.choice(rest_pool, samples_per_client - n_major)
        else:
            n_major = int(round(float(sigma) * samples_per_client))
            others = np.concatenate([by_class[k] for k in range(K) if k != m])
            rest = rng.choice(others, samples_per_client - n_major)
        major = rng.choice(by_class[m], n_major)
        sel = np.concatenate([major, rest])
        rng.shuffle(sel)
        imgs[n] = ds.images[sel]
        labs[n] = ds.labels[sel]
    if sizes is None:
        sizes = np.full(num_clients, samples_per_client, np.float64)
    return FederatedData(images=imgs, labels=labs, majority=majority,
                         sizes=np.asarray(sizes, np.float64))


def partition_dirichlet(ds: Dataset, num_clients: int, samples_per_client: int,
                        alpha: float, seed: int = 0) -> FederatedData:
    """Dirichlet(α) label-distribution partitioner (beyond-paper sweeps)."""
    rng = np.random.default_rng(seed)
    K = ds.num_classes
    by_class = [np.flatnonzero(ds.labels == k) for k in range(K)]
    imgs = np.empty((num_clients, samples_per_client) + ds.images.shape[1:],
                    ds.images.dtype)
    labs = np.empty((num_clients, samples_per_client), np.int32)
    majority = np.zeros(num_clients, np.int64)
    for n in range(num_clients):
        pvec = rng.dirichlet(np.full(K, alpha))
        counts = rng.multinomial(samples_per_client, pvec)
        sel = np.concatenate([
            rng.choice(by_class[k], c) for k, c in enumerate(counts) if c > 0])
        rng.shuffle(sel)
        imgs[n] = ds.images[sel]
        labs[n] = ds.labels[sel]
        majority[n] = int(np.argmax(counts))
    return FederatedData(images=imgs, labels=labs, majority=majority,
                         sizes=np.full(num_clients, samples_per_client, np.float64))
