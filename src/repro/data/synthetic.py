"""Deterministic synthetic datasets with the shape/class structure of the
paper's benchmarks (MNIST / CIFAR-10 / FashionMNIST).

The container is offline, so we synthesise class-structured image data:
each class has a smooth random template; samples are template + per-sample
deformation + pixel noise. What matters for reproducing the paper's
*selection dynamics* is that (a) classes are separable by a small CNN and
(b) client weight vectors trained on different majority classes diverge —
both hold by construction (validated in tests/benchmarks).

Also provides a synthetic token stream for LM-scale FL experiments.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.configs.paper_cnn import CNNConfig, CNN_CONFIGS


@dataclass
class Dataset:
    images: np.ndarray       # [N, H, W, C] float32 in [0, 1]
    labels: np.ndarray       # [N] int32
    num_classes: int


def _class_templates(rng, num_classes, h, w, c):
    """Smooth low-frequency class templates (random fourier features)."""
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    yy, xx = yy / h, xx / w
    templates = np.zeros((num_classes, h, w, c), np.float32)
    for k in range(num_classes):
        img = np.zeros((h, w, c), np.float32)
        for _ in range(6):
            fy, fx = rng.uniform(0.5, 4.0, 2)
            ph = rng.uniform(0, 2 * np.pi, c)
            amp = rng.uniform(0.3, 1.0)
            img += amp * np.sin(2 * np.pi * (fy * yy + fx * xx))[..., None]
            img += amp * 0.3 * np.cos(ph)[None, None, :]
        templates[k] = img
    templates -= templates.min()
    templates /= max(templates.max(), 1e-6)
    return templates


def make_dataset(name: str, num_samples: int, seed: int = 0,
                 noise: float = 0.25) -> Dataset:
    """name in {mnist, cifar10, fashion} — shapes follow the paper (Table II)."""
    cfg = CNN_CONFIGS[name]
    h, w = cfg.input_hw
    c = cfg.input_channels
    # templates define the CLASSES — they depend only on the dataset name so
    # train/test splits (different seeds) share the same class structure.
    # crc32, NOT hash(): str hashing is salted per process, and a
    # checkpointed run must resume bit-identically in a fresh interpreter.
    tmpl_rng = np.random.default_rng(zlib.crc32(name.encode()))
    templates = _class_templates(tmpl_rng, cfg.num_classes, h, w, c)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, cfg.num_classes, num_samples).astype(np.int32)
    shift = rng.integers(-2, 3, (num_samples, 2))
    images = np.empty((num_samples, h, w, c), np.float32)
    base = templates[labels]
    for i in range(num_samples):
        img = np.roll(base[i], tuple(shift[i]), axis=(0, 1))
        images[i] = img
    images += rng.normal(0.0, noise, images.shape).astype(np.float32)
    images = np.clip(images, 0.0, 1.0)
    return Dataset(images=images, labels=labels, num_classes=cfg.num_classes)


def make_token_stream(vocab_size: int, num_tokens: int, seed: int = 0,
                      order: int = 2) -> np.ndarray:
    """Markov token stream — gives LM training a learnable structure."""
    rng = np.random.default_rng(seed)
    ctx = min(64, vocab_size)
    trans = rng.dirichlet(np.ones(ctx) * 0.1, size=ctx)
    toks = np.zeros(num_tokens, np.int64)
    s = 0
    for i in range(num_tokens):
        s = rng.choice(ctx, p=trans[s])
        toks[i] = s % vocab_size
    return toks.astype(np.int32)
