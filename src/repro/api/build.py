"""``build_experiment(spec)`` — the single factory from a declarative
``ExperimentSpec`` to a runnable ``FLExperiment``. Replaces the scattered
kwargs of the legacy ``FLExperiment.__init__`` / ``fl_sim.run`` call sites.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.api.registry import (AGGREGATORS, ALLOCATORS, COMPRESSORS,
                                SELECTORS)
from repro.api.spec import ExperimentSpec
from repro.configs.base import FLConfig
from repro.configs.paper_cnn import CNN_CONFIGS


def fl_config_from_spec(spec: ExperimentSpec) -> FLConfig:
    return FLConfig(num_devices=spec.clients,
                    devices_per_round=spec.devices_per_round,
                    local_iters=spec.local_iters,
                    num_clusters=spec.num_clusters,
                    selected_per_cluster=spec.selected_per_cluster,
                    learning_rate=spec.learning_rate,
                    sigma=spec.sigma,
                    target_accuracy=spec.target_accuracy,
                    max_rounds=spec.rounds,
                    selection=spec.selection["name"],
                    feature_layer=spec.feature_layer)


def build_experiment(spec: ExperimentSpec, *,
                     test_data: Optional[Tuple[np.ndarray, np.ndarray]] = None):
    """Materialize dataset, partition, fleet and driver from ``spec``.

    ``test_data`` optionally overrides the held-out evaluation set (used by
    benchmarks that probe on a train slice instead).
    """
    from repro.core.fedavg import FLExperiment       # driver (late: cycle)
    from repro.core.wireless import sample_fleet
    from repro.data import make_dataset, partition_bias

    if spec.model != "auto":
        raise ValueError(
            f"model={spec.model!r}: non-CNN architectures run through "
            "repro.launch.fl_round.lower_fl_round_from_spec, not "
            "build_experiment")
    cnn_cfg = CNN_CONFIGS[spec.dataset]

    ds = make_dataset(spec.dataset, spec.train_samples,
                      seed=spec.resolved_data_seed)
    if test_data is None:
        test = make_dataset(spec.dataset, spec.test_samples,
                            seed=spec.resolved_test_seed)
        test_images, test_labels = test.images, test.labels
    else:
        test_images, test_labels = test_data
    fed = partition_bias(ds, spec.clients, spec.samples_per_client,
                         spec.sigma, seed=spec.resolved_partition_seed)
    fleet = sample_fleet(spec.clients, seed=spec.resolved_fleet_seed)

    exp = FLExperiment(
        cnn_cfg, fed, test_images, test_labels, fleet,
        fl_config_from_spec(spec),
        bandwidth_mhz=spec.bandwidth_mhz,
        selection=SELECTORS.resolve(spec.selection),
        allocator=ALLOCATORS.resolve(spec.allocator),
        aggregator=AGGREGATORS.resolve(spec.aggregator),
        compression=COMPRESSORS.resolve(spec.compressor),
        seed=spec.seed,
        batch_size=spec.batch_size,
        fedprox_mu=spec.fedprox_mu)
    exp.spec = spec
    return exp


def build_cohort(spec: ExperimentSpec):
    """A ``CohortRunner`` for ``spec`` — seeds ``seed..seed+cohort-1`` run
    as one vmapped, device-sharded program (``repro.core.cohort``)."""
    from repro.core.cohort import CohortRunner       # late: cycle
    return CohortRunner(spec)
