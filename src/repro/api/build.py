"""``build_experiment(spec)`` — the single factory from a declarative
``ExperimentSpec`` to a runnable ``FLExperiment``. Replaces the scattered
kwargs of the legacy ``FLExperiment.__init__`` / ``fl_sim.run`` call sites.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.api.registry import (AGGREGATORS, ALLOCATORS, CHANNELS,
                                COMPRESSORS, SELECTORS)
from repro.api.scenario import CELL_SEED_STRIDE, build_fleet
from repro.api.spec import ExperimentSpec
from repro.configs.base import FLConfig
from repro.configs.paper_cnn import CNN_CONFIGS


#: clients at/above which a paged build keeps the data partition lazy
#: (index-backed); below it even paged experiments materialize the
#: per-client image stack so the host round loop stays simple
LAZY_PARTITION_MIN = 50_000


def fl_config_from_spec(spec: ExperimentSpec,
                        num_devices: Optional[int] = None) -> FLConfig:
    return FLConfig(num_devices=num_devices or spec.clients,
                    devices_per_round=spec.devices_per_round,
                    local_iters=spec.local_iters,
                    num_clusters=spec.num_clusters,
                    selected_per_cluster=spec.selected_per_cluster,
                    learning_rate=spec.learning_rate,
                    sigma=spec.sigma,
                    target_accuracy=spec.target_accuracy,
                    max_rounds=spec.rounds,
                    selection=spec.selection["name"],
                    feature_layer=spec.feature_layer)


# a multi-cell cohort asks for every cell of the same build (seed × C
# lanes) — cache the whole-fleet build so the O(C²·N) interference
# geometry runs once per seed, not once per lane. Fleets are never
# mutated in place (select/with_power/replace all copy), so sharing the
# object across experiments is safe.
_FLEET_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_FLEET_CACHE_MAX = 16


def _built_fleet(fs, seed: int, clients: Optional[int],
                 bandwidth_mhz: float):
    key = (fs.to_json(), seed, clients, bandwidth_mhz)
    fleet = _FLEET_CACHE.get(key)
    if fleet is None:
        fleet = _FLEET_CACHE[key] = build_fleet(
            fs, seed, clients=clients, bandwidth_mhz=bandwidth_mhz)
        while len(_FLEET_CACHE) > _FLEET_CACHE_MAX:
            _FLEET_CACHE.popitem(last=False)
    else:
        _FLEET_CACHE.move_to_end(key)
    return fleet


def fleet_for_cell(spec: ExperimentSpec, cell: int = 0):
    """The (sub-)fleet cell ``cell`` serves, plus the resolved channel.

    ``spec.fleet is None`` keeps the legacy ``sample_fleet`` path (bit-
    identical by construction); a ``FleetSpec`` goes through the scenario
    builder — whose default single static cell reproduces the same draws.
    """
    from repro.core.wireless import sample_fleet

    if spec.fleet is None:
        if cell:
            raise ValueError("cell > 0 needs a multi-cell FleetSpec "
                             "(ExperimentSpec.fleet)")
        return (sample_fleet(spec.clients, seed=spec.resolved_fleet_seed),
                CHANNELS.resolve("static"))
    fs = spec.fleet
    if not 0 <= cell < fs.num_cells:
        raise ValueError(f"cell {cell} out of range for a "
                         f"{fs.num_cells}-cell FleetSpec")
    full = _built_fleet(fs, spec.resolved_fleet_seed, spec.clients,
                        spec.bandwidth_mhz)
    fleet = full.cell_fleet(cell) if fs.num_cells > 1 else full
    return fleet, CHANNELS.resolve(fs.channel)


def build_experiment(spec: ExperimentSpec, *, cell: int = 0,
                     test_data: Optional[Tuple[np.ndarray, np.ndarray]] = None):
    """Materialize dataset, partition, fleet and driver from ``spec``.

    ``cell`` selects one cell of a multi-cell ``FleetSpec`` (each cell is
    its own FL system sharing spectrum with the others; cross-cell coupling
    enters through the fleet's interference term). Cells reuse the shared
    dataset but partition it with decorrelated per-cell streams.

    ``test_data`` optionally overrides the held-out evaluation set (used by
    benchmarks that probe on a train slice instead).
    """
    from repro.core.fedavg import FLExperiment       # driver (late: cycle)
    from repro.data import (make_dataset, partition_bias,
                            partition_bias_lazy)

    from repro.models.registry import model_def_for, workload_config

    if spec.model in ("auto", "cnn"):
        model_cfg = CNN_CONFIGS[spec.dataset]
    else:
        model_cfg = workload_config(spec.model)
    mdef = model_def_for(model_cfg)

    fleet, channel = fleet_for_cell(spec, cell)
    n = fleet.num_devices

    if mdef.make_dataset is not None:
        # self-synthesizing workloads (the LoRA LMs) build their own
        # datasets from the config; ``spec.dataset`` selects nothing
        ds = mdef.make_dataset(model_cfg, spec.train_samples,
                               seed=spec.resolved_data_seed)
    else:
        ds = make_dataset(spec.dataset, spec.train_samples,
                          seed=spec.resolved_data_seed)
    if test_data is None:
        test = (mdef.make_dataset(model_cfg, spec.test_samples,
                                  seed=spec.resolved_test_seed)
                if mdef.make_dataset is not None
                else make_dataset(spec.dataset, spec.test_samples,
                                  seed=spec.resolved_test_seed))
        test_images, test_labels = test.images, test.labels
    else:
        test_images, test_labels = test_data
    # population-scale paged fleets partition lazily: per-client sample
    # INDICES into the shared pool instead of a materialized
    # [N, D, H, W, C] stack (which at 1e6 clients would dwarf the model
    # plane the paged store exists to avoid)
    partition = (partition_bias_lazy
                 if spec.store == "paged" and n >= LAZY_PARTITION_MIN
                 else partition_bias)
    fed = partition(ds, n, spec.samples_per_client, spec.sigma,
                    seed=spec.resolved_partition_seed
                    + CELL_SEED_STRIDE * cell)

    exp = FLExperiment(
        model_cfg, fed, test_images, test_labels, fleet,
        fl_config_from_spec(spec, num_devices=n),
        bandwidth_mhz=spec.bandwidth_mhz,
        selection=SELECTORS.resolve(spec.selection),
        allocator=ALLOCATORS.resolve(spec.allocator),
        aggregator=AGGREGATORS.resolve(spec.aggregator),
        compression=COMPRESSORS.resolve(spec.compressor),
        channel=channel,
        seed=spec.seed,
        batch_size=spec.batch_size,
        fedprox_mu=spec.fedprox_mu,
        churn=(spec.churn_leave, spec.churn_join),
        store=spec.store,
        k_max=spec.k_max,
        chunk_size=spec.chunk_size,
        div_refresh_every=spec.div_refresh_every,
        cluster=spec.cluster,
        p_shards=spec.p_shards,
        faults=spec.faults,
        quarantine_after=spec.quarantine_after)
    exp.spec = spec
    exp.cell = cell
    return exp


def build_cohort(spec: ExperimentSpec):
    """A ``CohortRunner`` for ``spec`` — seeds ``seed..seed+cohort-1``
    (× the FleetSpec's cells) run as one vmapped, device-sharded program
    (``repro.core.cohort``)."""
    if (spec.faults is not None and spec.faults.active) \
            or spec.quarantine_after > 0:
        raise ValueError(
            "fault injection / quarantine is not wired into the vmapped "
            "cohort program yet — run the spec through build_experiment "
            "(single-lane) instead, or drop the faults/quarantine_after "
            "fields")
    from repro.core.cohort import CohortRunner       # late: cycle
    return CohortRunner(spec)
