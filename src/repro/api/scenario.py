"""Declarative physical-layer scenarios — the paper's §III-B system model
(eqs (5)–(11)) as a frozen, JSON-round-trippable spec instead of ad-hoc
host-side sampling.

    FleetSpec                       # topology: cells × device distributions
      └── CellSpec × C              # per-cell geometry, counts, power/energy
    ChannelModel registry           # @register_channel: static | rayleigh-
                                    # block | gauss-markov:<rho> | multicell-
                                    # interference | multicell-dynamic | yours
    build_fleet(spec, seed)         # → pytree-native Fleet (traces through
                                    #   engine.run_rounds / CohortRunner)

A ``FleetSpec`` is a field of ``ExperimentSpec`` — the physical scenario
round-trips through the same JSON artifact as the strategies, and the CLI
grows ``--fleet-spec`` / ``--cells`` / ``--channel``:

    spec = ExperimentSpec(clients=40,
                          fleet=FleetSpec(cells=(CellSpec(), CellSpec()),
                                          channel="multicell-interference"))
    build_cohort(spec).run()        # (seeds × cells) lanes, ONE lax.scan

Single-cell ``FleetSpec()`` with the ``static`` channel reproduces
:func:`repro.core.wireless.sample_fleet` bit-for-bit (pinned by
``tests/test_scenario.py``). Units: ``docs/UNITS.md``.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import (CHANNELS, Strategy, StrategyError,
                                register_channel)
from repro.core.wireless import (CELL_RADIUS_KM, DEFAULT_ALPHA, DEFAULT_B_MHZ,
                                 DEFAULT_CYCLES_RANGE, DEFAULT_E_CONS_RANGE,
                                 DEFAULT_F_MAX_GHZ, DEFAULT_F_MIN_GHZ,
                                 DEFAULT_LOCAL_ITERS, DEFAULT_P_DBM,
                                 DEFAULT_SAMPLES_RANGE, DEFAULT_Z_MBIT,
                                 NOISE_DBM_PER_HZ, PATHLOSS_DB,
                                 SHADOW_STD_DB, Fleet, dbm_to_watt)

FLEET_SPEC_VERSION = 1

# decorrelates per-cell streams (fleet draws here, data partitions in
# api.build) while cell 0 keeps the exact single-cell stream; any odd
# prime far above realistic cohort sizes works — consecutive cohort seeds
# must never land on another cell's stream (seed + 1 == seed' + stride)
CELL_SEED_STRIDE = 7919

__all__ = ["CellSpec", "FleetSpec", "build_fleet", "CHANNELS",
           "register_channel", "StaticChannel", "RayleighBlockChannel",
           "GaussMarkovChannel", "MulticellInterferenceChannel",
           "MulticellDynamicChannel", "multicell_fleet_spec",
           "population_fleet_spec"]


# ---------------------------------------------------------------------------
# channel models
# ---------------------------------------------------------------------------


def _largescale_gains(rng, d_km, shadow_db):
    """3GPP path loss + lognormal shadowing — THE large-scale draw every
    built-in shares, so the serving-link RNG stream is identical across
    channel models (the `static` bit-identity pin relies on this)."""
    pl_db = PATHLOSS_DB(d_km) + rng.normal(0.0, shadow_db, np.shape(d_km))
    return 10.0 ** (-pl_db / 10.0)


_SQRT_HALF = float(np.sqrt(0.5))


def _gm_init(key, arr):
    """CN(0,1) complex fading amplitude h_0 as a trailing-[2] real array
    (re, im), so E|h|² = 1 and the state pytree stays real-dtype."""
    J = arr["J"]
    return jax.random.normal(key, J.shape + (2,), J.dtype) * _SQRT_HALF


def _gm_step(rho, floor, key, h, arr):
    """One AR(1) step h_t = ρ·h_{t−1} + √(1−ρ²)·w_t (w ~ CN(0,1)); the
    round's power gain is |h_t|², unit-mean at every lag. Shared by
    ``gauss-markov`` and ``rayleigh-block`` (its ρ = 0 special case), which
    is what makes the ``gauss-markov:0 ≡ rayleigh-block`` pin bit-exact."""
    J = arr["J"]
    w = jax.random.normal(key, J.shape + (2,), J.dtype) * _SQRT_HALF
    h = rho * h + np.sqrt(max(1.0 - rho * rho, 0.0)) * w
    gain = jnp.sum(jnp.square(h), axis=-1)
    out = dict(arr)
    out["J"] = J * jnp.maximum(gain, floor)
    return h, out


@register_channel("static")
@dataclass(frozen=True)
class StaticChannel(Strategy):
    """The paper's §VI channel: 3GPP path loss + lognormal shadowing drawn
    once at fleet build time, constant over rounds. ``shadow_db = 0``
    disables shadowing (pure path loss)."""

    shadow_db: float = SHADOW_STD_DB

    traceable = True
    needs_rng = False
    stateful = False

    def sample_gains(self, rng, d_km):
        return _largescale_gains(rng, d_km, self.shadow_db)

    def apply_traced(self, key, arr):
        return arr


@register_channel("gauss-markov")
@dataclass(frozen=True)
class GaussMarkovChannel(Strategy):
    """First-order Gauss-Markov (Jakes-like) time-correlated fading: the
    complex amplitude evolves as h_t = ρ·h_{t−1} + √(1−ρ²)·w_t with
    w ~ CN(0,1) and h_0 ~ CN(0,1), so the per-round power coefficient
    |h_t|² is unit-mean exponential at every lag with round-to-round
    correlation ρ² — the fading STATE rides in the ``lax.scan`` carry
    (``RoundState.channel``), making selection-policy memory matter.

    ``rho = 0`` is memoryless block-Rayleigh (``rayleigh-block`` is exactly
    this special case); ``rho = 1`` freezes the first draw for the whole
    run. ``floor`` clamps deep fades so the SAO bisection brackets stay
    finite. Spelled ``gauss-markov:<rho>`` in compact form."""

    rho: float = 0.9
    floor: float = 1e-3
    shadow_db: float = SHADOW_STD_DB

    traceable = True
    needs_rng = True
    stateful = True

    def __post_init__(self):
        if not 0.0 <= self.rho <= 1.0:
            raise ValueError(f"gauss-markov rho must be in [0, 1]; "
                             f"got {self.rho}")

    def sample_gains(self, rng, d_km):
        return _largescale_gains(rng, d_km, self.shadow_db)

    def init_state(self, key, arr):
        return _gm_init(key, arr)

    def step_traced(self, key, state, arr):
        return _gm_step(self.rho, self.floor, key, state, arr)

    def apply_traced(self, key, arr):
        # memoryless fallback (a ρ=0 draw) for callers outside the stateful
        # engine path; the scanned pipeline uses init_state/step_traced
        return _gm_step(0.0, self.floor, key, 0.0, arr)[1]


@register_channel("rayleigh-block")
@dataclass(frozen=True)
class RayleighBlockChannel(GaussMarkovChannel):
    """Block Rayleigh fading: the large-scale gain of :class:`StaticChannel`
    times a unit-mean |CN(0,1)|² power coefficient redrawn EVERY round
    inside the scanned program — no host round-trips. Re-expressed as the
    ρ = 0 special case of :class:`GaussMarkovChannel` (same draws, pinned
    bit-identical), so the fading state machinery has exactly one
    implementation. ``floor`` clamps deep fades so the SAO bisection
    brackets stay finite. Spelled ``rayleigh-block:<floor>`` in compact
    form."""

    rho: float = dataclasses.field(default=0.0, init=False)
    floor: float = 1e-3
    shadow_db: float = SHADOW_STD_DB

    @classmethod
    def from_string(cls, arg):
        if arg in (None, ""):
            return cls()
        try:
            return cls(floor=float(arg))
        except ValueError:
            raise StrategyError(
                f"rayleigh-block:{arg}: expected a number for "
                "'floor'") from None


@register_channel("multicell-interference")
@dataclass(frozen=True)
class MulticellInterferenceChannel(Strategy):
    """Multi-cell uplink: per-cell path loss + shadowing to the serving BS
    (as ``static``), plus cross-cell interference at fleet build time —
    every cell reuses the full band B, so a BS hears the other cells'
    devices. The interference enters the FDMA rate (7) through the
    ``inr = I/N0`` fleet term (``J_eff = J/(1+inr)``,
    ``repro.core.wireless.effective_arrays``).

    ``load`` is the activity factor of interfering cells: the expected
    interference PSD at BS c is
    ``I_c = load · Σ_{m≠c} mean_{k∈m}(h_{k→c}·p_k) / (B·1e6)`` [W/Hz]
    (cross links use deterministic path loss — no extra shadowing draws, so
    the serving-link RNG stream matches ``static`` exactly).
    Spelled ``multicell-interference:<load>`` in compact form."""

    load: float = 1.0
    shadow_db: float = SHADOW_STD_DB

    traceable = True
    needs_rng = False
    stateful = False

    def sample_gains(self, rng, d_km):
        return _largescale_gains(rng, d_km, self.shadow_db)

    def apply_traced(self, key, arr):
        return arr

    def cross_cell_inr(self, pos_km, p_watt, cell_ids, centers_km,
                       bandwidth_mhz: float, N0: float) -> np.ndarray:
        """Per-device ``I/N0`` at each device's serving BS (all devices of
        one cell share it)."""
        cell_ids = np.asarray(cell_ids)
        num_cells = len(centers_km)
        inr = np.zeros(len(cell_ids))
        if num_cells < 2 or self.load <= 0.0:
            return inr
        for c in range(num_cells):
            psd = 0.0
            for m in range(num_cells):
                if m == c:
                    continue
                k = np.flatnonzero(cell_ids == m)
                d = np.hypot(pos_km[k, 0] - centers_km[c][0],
                             pos_km[k, 1] - centers_km[c][1])
                g = 10.0 ** (-PATHLOSS_DB(d) / 10.0)
                psd += float(np.mean(g * p_watt[k])) / (bandwidth_mhz * 1e6)
            inr[cell_ids == c] = self.load * psd / N0
        return inr


@register_channel("multicell-dynamic")
@dataclass(frozen=True)
class MulticellDynamicChannel(Strategy):
    """Multi-cell uplink with SELECTION-DRIVEN interference: instead of the
    build-time average-load PSD of ``multicell-interference``, each round's
    ``inr`` at BS c is the sum of the contributions of the devices the
    OTHER cells actually selected that round — computed inside the scanned
    round pipeline, so scheduling policies feel the interference their
    neighbors cause (and cause interference in turn).

    ``build_fleet`` precomputes the cross-gain matrix via
    :meth:`cross_gain_matrix` (deterministic path loss on cross links, like
    the static model, so serving-link RNG streams stay identical to
    ``static``); the engine folds the selected rows into each cell's rate
    before spectrum allocation. ``load`` scales every contribution (an
    activity/duty factor). With one cell the cross matrix is empty and the
    model is bit-identical to ``static``. Device selection itself sees the
    pre-interference gains — a cell cannot observe the other cells'
    simultaneous choices before they are made (causal scheduling).
    Spelled ``multicell-dynamic:<load>`` in compact form.

    ``rho`` (None → off) additionally runs :class:`GaussMarkovChannel`
    AR(1) correlated fading on each device's SERVING link — dynamic
    interference + time-correlated channels in ONE scanned program
    (``{"name": "multicell-dynamic", "params": {"rho": 0.9}}``). Cross
    links stay large-scale only: interference at a BS sums many devices,
    so per-link fading averages out there first.
    """

    load: float = 1.0
    shadow_db: float = SHADOW_STD_DB
    rho: Optional[float] = None       # serving-link Gauss-Markov fading
    floor: float = 1e-3

    traceable = True
    dynamic = True                    # per-round inr from actual selections

    def __post_init__(self):
        if self.rho is not None and not 0.0 <= self.rho <= 1.0:
            raise ValueError(f"multicell-dynamic rho must be in [0, 1]; "
                             f"got {self.rho}")

    @property
    def needs_rng(self):
        return self.rho is not None

    @property
    def stateful(self):
        return self.rho is not None

    def sample_gains(self, rng, d_km):
        return _largescale_gains(rng, d_km, self.shadow_db)

    def apply_traced(self, key, arr):
        return arr

    def init_state(self, key, arr):
        return _gm_init(key, arr)

    def step_traced(self, key, state, arr):
        return _gm_step(self.rho, self.floor, key, state, arr)

    def cross_gain_matrix(self, pos_km, p_watt, cell_ids, centers_km,
                          bandwidth_mhz: float, N0: float) -> np.ndarray:
        """``X[n, c]`` — the inr contribution device ``n`` adds at BS ``c``
        when it transmits: ``load · g_{n→c} · p_n / (B·1e6 · N0)``
        (dimensionless, same normalization as the static model's PSD). The
        own-cell column is zero, so a per-round reduction over the selected
        rows directly yields each BS's I/N0 from the *other* cells."""
        cell_ids = np.asarray(cell_ids)
        n = len(cell_ids)
        X = np.zeros((n, len(centers_km)))
        for c, (cx, cy) in enumerate(centers_km):
            d = np.hypot(pos_km[:, 0] - cx, pos_km[:, 1] - cy)
            g = 10.0 ** (-PATHLOSS_DB(d) / 10.0)
            X[:, c] = self.load * g * p_watt / (bandwidth_mhz * 1e6) / N0
        X[np.arange(n), cell_ids] = 0.0
        return X


# ---------------------------------------------------------------------------
# fleet specification
# ---------------------------------------------------------------------------


def _pair(x, name: str) -> Tuple[float, float]:
    try:
        lo, hi = x
    except (TypeError, ValueError):
        raise ValueError(f"{name} must be a (lo, hi) pair; got {x!r}") from None
    return (float(lo), float(hi))


@dataclass(frozen=True)
class CellSpec:
    """One cell's geometry and device-population distributions (§VI setup;
    every default reproduces :func:`repro.core.wireless.sample_fleet`).

    ``devices = None`` inherits ``ExperimentSpec.clients``;
    ``center_km = None`` takes the cell's slot in the ``FleetSpec`` auto
    layout (a line of cells ``isd_km`` apart).
    """

    devices: Optional[int] = None
    center_km: Optional[Tuple[float, float]] = None
    radius_km: float = CELL_RADIUS_KM
    p_dbm: float = DEFAULT_P_DBM
    z_mbit: float = DEFAULT_Z_MBIT
    e_cons_range: Tuple[float, float] = DEFAULT_E_CONS_RANGE
    cycles_range: Tuple[float, float] = DEFAULT_CYCLES_RANGE
    samples_range: Tuple[int, int] = DEFAULT_SAMPLES_RANGE
    f_min_ghz: float = DEFAULT_F_MIN_GHZ
    f_max_ghz: float = DEFAULT_F_MAX_GHZ
    alpha: float = DEFAULT_ALPHA

    def __post_init__(self):
        for name in ("e_cons_range", "cycles_range"):
            object.__setattr__(self, name, _pair(getattr(self, name), name))
        lo, hi = _pair(self.samples_range, "samples_range")
        object.__setattr__(self, "samples_range", (int(lo), int(hi)))
        if self.center_km is not None:
            object.__setattr__(self, "center_km",
                               _pair(self.center_km, "center_km"))

    def resolved_devices(self, default: Optional[int]) -> int:
        n = self.devices if self.devices is not None else default
        if n is None or n <= 0:
            raise ValueError(
                "CellSpec.devices is unset and no default device count was "
                "given (pass clients= to build_fleet / set it on the "
                "ExperimentSpec)")
        return int(n)


@dataclass(frozen=True)
class FleetSpec:
    """The whole physical scenario: cells, channel model, shared constants.

    Frozen and JSON-round-trippable, like ``ExperimentSpec`` (of which it
    is the ``fleet`` field). ``channel`` is a registry reference —
    ``"static"``, ``"rayleigh-block:0.01"``,
    ``{"name": "multicell-interference", "params": {"load": 0.5}}``, or any
    ``@register_channel`` model.
    """

    cells: Tuple[CellSpec, ...] = (CellSpec(),)
    channel: Union[str, Dict[str, Any]] = "static"
    isd_km: float = 2.0 * CELL_RADIUS_KM        # auto-layout inter-site dist
    local_iters: int = DEFAULT_LOCAL_ITERS      # the fleet's L (eq. 16)
    noise_dbm_per_hz: float = NOISE_DBM_PER_HZ
    version: int = FLEET_SPEC_VERSION

    def __post_init__(self):
        cells = tuple(c if isinstance(c, CellSpec) else CellSpec(**c)
                      for c in self.cells)
        if not cells:
            raise ValueError("FleetSpec needs at least one cell")
        object.__setattr__(self, "cells", cells)
        object.__setattr__(self, "channel",
                           CHANNELS.canonical(self.channel))

    @property
    def num_cells(self) -> int:
        return len(self.cells)

    def centers_km(self):
        """Resolved BS positions: explicit ``center_km`` wins, otherwise a
        line along x with ``isd_km`` spacing."""
        return [c.center_km if c.center_km is not None
                else (i * self.isd_km, 0.0)
                for i, c in enumerate(self.cells)]

    def replace(self, **kw) -> "FleetSpec":
        return dataclasses.replace(self, **kw)

    # ---- serialization (mirrors ExperimentSpec) ----------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FleetSpec":
        d = dict(d)
        version = d.pop("version", FLEET_SPEC_VERSION)
        if version > FLEET_SPEC_VERSION:
            raise ValueError(f"fleet spec version {version} is newer than "
                             f"supported {FLEET_SPEC_VERSION}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FleetSpec fields: {sorted(unknown)}")
        return cls(version=version, **d)

    @classmethod
    def from_json(cls, s: str) -> "FleetSpec":
        return cls.from_dict(json.loads(s))


def multicell_fleet_spec(num_cells: int, **kw) -> FleetSpec:
    """Convenience: ``num_cells`` default cells on the auto line layout,
    with the interference channel once there is more than one cell (the
    ``fl_sim --cells N`` shorthand)."""
    channel = kw.pop("channel",
                     "multicell-interference" if num_cells > 1 else "static")
    return FleetSpec(cells=tuple(CellSpec() for _ in range(num_cells)),
                     channel=channel, **kw)


def population_fleet_spec(num_clients: int, **kw) -> FleetSpec:
    """Convenience: one static cell serving ``num_clients`` devices — the
    population-scale scenario (``ExperimentSpec(store="paged", ...)``).
    All fleet draws are vectorized, so a 1e6-device build is O(N) numpy;
    pair with the ``micro`` CNN config and a lazy partition (automatic
    above ``repro.api.build.LAZY_PARTITION_MIN`` clients) to keep the
    whole experiment O(K·P + N)."""
    return FleetSpec(cells=(CellSpec(devices=int(num_clients)),), **kw)


# ---------------------------------------------------------------------------
# build_fleet: FleetSpec → pytree-native Fleet
# ---------------------------------------------------------------------------


def build_fleet(spec: FleetSpec, seed: int = 0, *,
                clients: Optional[int] = None,
                bandwidth_mhz: float = DEFAULT_B_MHZ) -> Fleet:
    """Materialize a :class:`~repro.core.wireless.Fleet` from ``spec``.

    Cell ``i`` draws from ``np.random.default_rng(seed + i·stride)`` in
    exactly :func:`sample_fleet`'s sequence (radius → shadowing → cycles →
    samples → energy budgets), so the default single-cell spec is
    bit-identical to ``sample_fleet(clients, seed)`` and consecutive
    cohort seeds never alias another cell's stream (``CELL_SEED_STRIDE``);
    multi-cell builds additionally draw a device angle (for cross-cell
    geometry) right after the radius. ``bandwidth_mhz`` is the per-cell
    reuse band the interference PSD normalizes over.
    """
    channel = CHANNELS.resolve(spec.channel)
    centers = spec.centers_km()
    multi = spec.num_cells > 1
    parts = []
    for i, cell in enumerate(spec.cells):
        n = cell.resolved_devices(clients)
        rng = np.random.default_rng(seed + i * CELL_SEED_STRIDE)
        r_km = cell.radius_km * np.sqrt(rng.uniform(0.01, 1.0, n))
        theta = rng.uniform(0.0, 2.0 * math.pi, n) if multi \
            else np.zeros(n)
        h = channel.sample_gains(rng, r_km)
        parts.append(dict(
            h=h,
            p=np.full(n, dbm_to_watt(cell.p_dbm)),
            z=np.full(n, cell.z_mbit),
            C=rng.uniform(*cell.cycles_range, n),
            D=rng.integers(cell.samples_range[0], cell.samples_range[1] + 1,
                           n).astype(np.float64),
            alpha=np.full(n, cell.alpha),
            f_min=np.full(n, cell.f_min_ghz),
            f_max=np.full(n, cell.f_max_ghz),
            e_cons=rng.uniform(*cell.e_cons_range, n),
            cell=np.full(n, i, np.int32),
            pos=np.stack([centers[i][0] + r_km * np.cos(theta),
                          centers[i][1] + r_km * np.sin(theta)], axis=1),
        ))

    cat = {k: np.concatenate([p[k] for p in parts])
           for k in parts[0]}
    pos = cat.pop("pos")
    N0 = dbm_to_watt(spec.noise_dbm_per_hz)
    inr = np.zeros(len(cat["h"]))
    xgain = None
    if hasattr(channel, "cross_gain_matrix"):
        # dynamic interference: precompute each device's per-BS inr
        # contribution; the per-round I/N0 is reduced from the actual
        # selections inside the scanned program (build-time inr stays 0)
        xgain = channel.cross_gain_matrix(pos, cat["p"], cat["cell"],
                                          centers, bandwidth_mhz, N0)
    elif hasattr(channel, "cross_cell_inr"):
        inr = channel.cross_cell_inr(pos, cat["p"], cat["cell"], centers,
                                     bandwidth_mhz, N0)
    return Fleet(L=spec.local_iters, N0=N0, inr=inr, xgain=xgain, **cat)
