"""Declarative experiment specification — one frozen, JSON-round-trippable
value that fully determines an FL experiment (paper Fig. 2 end to end).

    spec = ExperimentSpec(dataset="fashion", clients=30, sigma=0.8,
                          selection="divergence", allocator="sao")
    exp = build_experiment(spec)          # repro.api.build
    hist = exp.run()

Strategy fields accept a bare name (``"sao"``), the compact ``name:arg``
shorthand (``"fedl:2.0"``, ``"topk:0.05"``) or an explicit
``{"name", "params"}`` dict; they are normalized to the dict form at
construction so ``ExperimentSpec.from_json(spec.to_json()) == spec``.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Union

from repro.api.registry import get_registry
from repro.api.scenario import FleetSpec

SPEC_VERSION = 1

StrategyRef = Union[str, Dict[str, Any]]


def _canonical(kind: str, ref: Any) -> Dict[str, Any]:
    import repro.strategies  # noqa: F401  (populate registries)
    return get_registry(kind).canonical(ref)


@dataclass(frozen=True)
class ExperimentSpec:
    """Everything needed to rebuild one experiment, bit-for-bit."""

    # ---- data / partition (paper §VI setup) --------------------------
    dataset: str = "mnist"                 # mnist | cifar10 | fashion
    train_samples: int = 4000
    test_samples: int = 1000
    clients: int = 40                      # N
    samples_per_client: int = 128          # D_n
    sigma: Union[float, str] = 0.8         # non-iid bias; "H" = half-half

    # ---- model -------------------------------------------------------
    model: str = "auto"                    # "auto" | "cnn" → paper CNN for
                                           # dataset; else a registered
                                           # workload name ("tinyllama",
                                           # "mamba2-130m": LoRA LM rows)

    # ---- wireless fleet / physical scenario --------------------------
    bandwidth_mhz: float = 20.0            # B (per cell — reused across cells)
    fleet: Optional[Any] = None            # FleetSpec (or its dict form);
                                           # None → the paper's §VI single
                                           # cell via sample_fleet (legacy,
                                           # bit-identical to FleetSpec())

    # ---- FL hyper-parameters (FLConfig) ------------------------------
    rounds: int = 30
    devices_per_round: int = 10            # S
    selected_per_cluster: int = 1          # s
    local_iters: int = 20                  # L
    num_clusters: int = 10                 # c
    learning_rate: float = 0.05
    batch_size: int = 32
    target_accuracy: float = 0.0           # 0 → always run ``rounds``
    feature_layer: str = "auto"            # K-means feature (Alg. 2)
    fedprox_mu: float = 0.0                # >0 → FedProx client objective

    # ---- client parameter store (population-scale fleets) ------------
    store: str = "dense"                   # "dense": the [N, P] device plane
                                           # (bit-identical default);
                                           # "paged": active/cold split —
                                           # O(K·P) device state + host-paged
                                           # cold blocks (repro.core.store)
    k_max: Optional[int] = None            # active-plane rows (paged);
                                           # None → max(S, 256) capped at N
    chunk_size: Optional[int] = None       # cold-store block rows (paged);
                                           # None → ~64 MB blocks
    div_refresh_every: int = 0             # paged divergence refresh cadence:
                                           # 1 = every selection (exact dense
                                           # signal), 0 = lazy (drift-bounded)
    cluster: str = "full"                  # Alg.-2 K-means fit: "full" (one
                                           # [N, F] matrix) or "minibatch"
                                           # (streaming, O(chunk) memory)

    # ---- flat-plane sharding (model axis) ----------------------------
    p_shards: int = 0                      # >0: shard the [N, P] plane's P
                                           # axis over min(p_shards, devices)
                                           # (repro.sharding.specs); 0 = off

    # ---- client churn (buffered-asynchronous engine only) ------------
    churn_leave: float = 0.0               # per-tick P(available → gone)
    churn_join: float = 0.0                # per-tick P(gone → available)

    # ---- fault injection / robustness (repro.core.faults) ------------
    faults: Optional[Any] = None           # FaultSpec, its dict form, or the
                                           # compact "outage:0.1,corrupt:0.01"
                                           # string; None → fault-free
    quarantine_after: int = 0              # strikes (non-finite uploads)
                                           # before a client is excluded from
                                           # selection like avail=False; 0=off

    # ---- cohort (vmapped multi-seed execution) -----------------------
    cohort: int = 1                        # seeds seed..seed+cohort-1 run as
                                           # ONE compiled program (CohortRunner)

    # ---- seeds (None → derived from ``seed``) ------------------------
    seed: int = 0
    data_seed: Optional[int] = None        # default: seed
    test_seed: Optional[int] = None        # default: data_seed + 10_000
    partition_seed: Optional[int] = None   # default: seed + 1
    fleet_seed: Optional[int] = None       # default: seed

    # ---- pluggable strategies ----------------------------------------
    selection: StrategyRef = "divergence"
    allocator: StrategyRef = "sao"
    aggregator: StrategyRef = "fedavg"
    compressor: StrategyRef = "none"

    version: int = SPEC_VERSION

    def __post_init__(self):
        if self.store not in ("dense", "paged"):
            raise ValueError(f"store={self.store!r}: expected 'dense' or "
                             "'paged'")
        for name in ("k_max", "chunk_size"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive; got {v}")
        if self.div_refresh_every < 0:
            raise ValueError("div_refresh_every must be >= 0; got "
                             f"{self.div_refresh_every}")
        if self.cluster not in ("full", "minibatch"):
            raise ValueError(f"cluster={self.cluster!r}: expected 'full' "
                             "or 'minibatch'")
        if self.p_shards < 0:
            raise ValueError(f"p_shards must be >= 0; got {self.p_shards}")
        for name in ("churn_leave", "churn_join"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} is a per-tick probability; "
                                 f"expected 0 <= p <= 1, got {v}")
        if self.model not in ("auto", "cnn"):
            # importing the registry imports repro.models, whose __init__
            # registers the built-in LM workloads
            from repro.models.registry import workload_names
            if self.model not in workload_names():
                raise ValueError(
                    f"unknown model {self.model!r}; known: "
                    f"{('auto', 'cnn') + workload_names()}")
        if self.fleet is not None and not isinstance(self.fleet, FleetSpec):
            object.__setattr__(self, "fleet", FleetSpec.from_dict(self.fleet))
        if self.quarantine_after < 0:
            raise ValueError("quarantine_after must be >= 0; got "
                             f"{self.quarantine_after}")
        from repro.core.faults import FaultSpec
        object.__setattr__(self, "faults", FaultSpec.normalize(self.faults))
        object.__setattr__(self, "selection",
                           _canonical("selector", self.selection))
        object.__setattr__(self, "allocator",
                           _canonical("allocator", self.allocator))
        object.__setattr__(self, "aggregator",
                           _canonical("aggregator", self.aggregator))
        object.__setattr__(self, "compressor",
                           _canonical("compressor", self.compressor))

    # ---- derived -----------------------------------------------------
    @property
    def resolved_data_seed(self) -> int:
        return self.seed if self.data_seed is None else self.data_seed

    @property
    def resolved_test_seed(self) -> int:
        return (self.resolved_data_seed + 10_000
                if self.test_seed is None else self.test_seed)

    @property
    def resolved_partition_seed(self) -> int:
        return self.seed + 1 if self.partition_seed is None else self.partition_seed

    @property
    def resolved_fleet_seed(self) -> int:
        return self.seed if self.fleet_seed is None else self.fleet_seed

    @property
    def resolved_fleet_spec(self) -> FleetSpec:
        """The scenario, with ``None`` resolved to the paper's default
        single static cell."""
        return self.fleet if self.fleet is not None else FleetSpec()

    @property
    def num_cells(self) -> int:
        return 1 if self.fleet is None else self.fleet.num_cells

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    # ---- serialization -----------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        version = d.pop("version", SPEC_VERSION)
        if version > SPEC_VERSION:
            raise ValueError(f"spec version {version} is newer than "
                             f"supported {SPEC_VERSION}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ExperimentSpec fields: {sorted(unknown)}")
        return cls(version=version, **d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))
