"""Public experiment-construction API.

    from repro.api import (ExperimentSpec, build_experiment,
                           SELECTORS, ALLOCATORS, AGGREGATORS, COMPRESSORS)

Strategies resolve through per-stage registries (see ``repro.strategies``
for the built-ins); experiments are declared as a frozen, JSON-serializable
``ExperimentSpec`` and materialized by ``build_experiment``.
"""
from repro.api.registry import (AGGREGATORS, ALLOCATORS, CHANNELS,
                                COMPRESSORS, SELECTORS, Registry, Strategy,
                                StrategyError, get_registry,
                                register_channel)
from repro.api.protocols import (Allocation, Aggregator, Allocator,
                                 ChannelModel, Compressor, RoundState,
                                 SelectionContext, Selector,
                                 TracedAllocator, TracedContext,
                                 TracedSelector)
from repro.api.scenario import (CellSpec, FleetSpec, build_fleet,
                                multicell_fleet_spec)
from repro.api.spec import SPEC_VERSION, ExperimentSpec
from repro.api.build import (build_cohort, build_experiment,
                             fl_config_from_spec, fleet_for_cell)
import repro.strategies  # noqa: F401  (register built-in strategies)

__all__ = [
    "AGGREGATORS", "ALLOCATORS", "CHANNELS", "COMPRESSORS", "SELECTORS",
    "Registry", "Strategy", "StrategyError", "get_registry",
    "register_channel",
    "Allocation", "Aggregator", "Allocator", "ChannelModel",
    "Compressor", "RoundState", "SelectionContext", "Selector",
    "TracedAllocator", "TracedContext", "TracedSelector",
    "CellSpec", "FleetSpec", "build_fleet", "multicell_fleet_spec",
    "SPEC_VERSION", "ExperimentSpec",
    "build_cohort", "build_experiment", "fl_config_from_spec",
    "fleet_for_cell",
]
