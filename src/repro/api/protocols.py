"""Typed contracts between the round loop and its pluggable stages.

The driver (``repro.core.fedavg.FLExperiment``) talks to strategies only
through these protocols; the math lives in ``repro.core.*`` and the
registered adapters in ``repro.strategies.*``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (TYPE_CHECKING, Any, Callable, Dict, NamedTuple, Optional,
                    Protocol, Sequence, runtime_checkable)

import numpy as np

if TYPE_CHECKING:                      # import-cycle guard: api ↔ core
    from repro.core.wireless import DeviceFleet


@dataclass
class SelectionContext:
    """Everything a selection policy may consult for one round.

    ``divergences`` is lazy (a callable) so policies that don't need the
    ‖w_n − w_g‖ signal (e.g. ``random``) never pay for it.
    """
    rng: np.random.Generator
    num_devices: int
    devices_per_round: int            # S
    selected_per_cluster: int         # s (Alg. 3/4)
    bandwidth_mhz: float              # B
    fleet: "DeviceFleet"
    clusters: Optional[Sequence[np.ndarray]]
    divergences: Callable[[], np.ndarray]


class Allocation(NamedTuple):
    """Outcome of one round's spectrum allocation (eqs. 10-11)."""
    T: float                          # round delay T_k [s]
    E: float                          # round energy E_k [J]
    b: Optional[np.ndarray] = None    # per-device bandwidth [MHz]
    f: Optional[np.ndarray] = None    # per-device CPU frequency [GHz]


@runtime_checkable
class Selector(Protocol):
    """Device-selection policy (paper Algorithms 3/4 and baselines)."""

    def select(self, ctx: SelectionContext) -> np.ndarray: ...


@runtime_checkable
class Allocator(Protocol):
    """Spectrum allocation for a selected set. ``arr`` is the
    ``fleet_arrays`` dict of the selected devices; ``B`` the band [MHz]."""

    def allocate(self, arr: Dict[str, Any], B: float) -> Allocation: ...


@runtime_checkable
class Aggregator(Protocol):
    """Server-side model aggregation, eq. (4) and variants. May be
    stateful (e.g. server momentum); ``reset`` clears that state."""

    def aggregate(self, global_params: Any, stacked_params: Any,
                  weights: np.ndarray) -> Any: ...

    def reset(self) -> None: ...

    # True → plain D_n-weighted mean; lets the driver fuse aggregation
    # into the jitted round step shared across experiments.
    fuses_with_engine: bool


@runtime_checkable
class Compressor(Protocol):
    """Simulated lossy uplink compression of client updates."""

    identity: bool

    def compress(self, tree: Any) -> Any: ...

    def apply(self, stacked_new: Any, global_params: Any) -> Any:
        """Compress the stacked client *deltas* against the global model."""
        ...

    def payload_mbit(self, num_params: int,
                     num_leaves: int) -> Optional[float]:
        """Uplink payload z_n [Mbit], or None to keep the fleet's own z."""
        ...
