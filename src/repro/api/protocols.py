"""Typed contracts between the round loop and its pluggable stages.

The driver (``repro.core.fedavg.FLExperiment``) talks to strategies only
through these protocols; the math lives in ``repro.core.*`` and the
registered adapters in ``repro.strategies.*``.

Two parallel contracts exist for each stage:

* the host (numpy) protocols — ``Selector``/``Allocator``/... — drive the
  legacy one-Python-round-at-a-time loop;
* the traced variants — ``TracedSelector``/``TracedAllocator`` — are pure
  jnp functions over fixed-size padded index sets + participation masks,
  usable inside ``lax.scan``/``vmap`` (the device-resident round pipeline,
  ``repro.core.engine.run_rounds`` / ``repro.core.cohort.CohortRunner``).

A strategy advertises the traced contract with ``traceable = True``; the
driver dispatches to the scanned path only when every configured strategy
does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (TYPE_CHECKING, Any, Callable, Dict, NamedTuple, Optional,
                    Protocol, Sequence, Tuple, runtime_checkable)

import numpy as np

if TYPE_CHECKING:                      # import-cycle guard: api ↔ core
    from repro.core.wireless import Fleet


@dataclass
class SelectionContext:
    """Everything a selection policy may consult for one round.

    ``divergences`` is lazy (a callable) so policies that don't need the
    ‖w_n − w_g‖ signal (e.g. ``random``) never pay for it.
    """
    rng: np.random.Generator
    num_devices: int
    devices_per_round: int            # S
    selected_per_cluster: int         # s (Alg. 3/4)
    bandwidth_mhz: float              # B
    fleet: "Fleet"
    clusters: Optional[Sequence[np.ndarray]]
    divergences: Callable[[], np.ndarray]


class Allocation(NamedTuple):
    """Outcome of one round's spectrum allocation (eqs. 10-11).

    ``T``/``E`` may be device scalars (jnp) — the solve is jitted and the
    values stay on device until the host boundary (``FLHistory.append``)
    coerces them, so the driver never blocks between allocation and the
    training dispatch.
    """
    T: Any                            # round delay T_k [s] (float or jnp scalar)
    E: Any                            # round energy E_k [J] (float or jnp scalar)
    b: Optional[np.ndarray] = None    # per-device bandwidth [MHz]
    f: Optional[np.ndarray] = None    # per-device CPU frequency [GHz]


# ---------------------------------------------------------------------------
# traced round pipeline (device-resident; lax.scan / vmap friendly)
# ---------------------------------------------------------------------------


class RoundState(NamedTuple):
    """The carried pytree of the scanned round loop — everything one FL
    round reads and writes, device-resident.

    The traced pipeline keeps model weights on the FLAT PARAMETER PLANE:
    one model is a length-``P`` fp32 row (layout =
    ``repro.utils.trees.StackFlattenSpec``), so the carry's weight leaves
    are dense buffers, every per-round reduction (divergence, aggregation,
    K-means features, compression) is a single fused row op, and the whole
    carry can be donated (``donate_argnums``) so the ``[cohort, N, P]``
    buffer updates in place across dispatches. The host driver
    (``FLExperiment``) converts to/from pytrees at the trace boundary
    (``traced_state`` / ``load_traced_state``).

    Leaves:
      params        : flat [P] global model row (host boundary unflattens
                      it back to the model pytree)
      client_params : [N, P] flat client-weight buffer (row n = client n)
      opt_state     : server-optimizer state (the aggregator's
                      ``init_flat_state`` defines it — ``None`` for
                      stateless aggregation, a flat [P] row for FedAvgM)
      key           : jax PRNG key driving selection + local SGD
      labels        : [N] int32 K-means cluster labels (Alg. 2; zeros until
                      the initial round has run)
      channel       : channel-model state riding in the scan carry (e.g. the
                      Gauss-Markov complex fading amplitude; the model's
                      ``init_state`` defines it — ``None`` for memoryless
                      channels, populated INSIDE the traced program)
      sched         : the per-client statistics table
                      (``repro.core.store.ClientStats`` — age / in-flight
                      completion-time / availability / divergence columns
                      + the virtual clock, a pytree with device leaves)
                      when the buffered-asynchronous tick loop is driving
                      the scan (``repro.core.async_engine``); ``None``
                      for the synchronous round barrier. The same table
                      is the store's host-side source of truth — the scan
                      carries a device copy and ``load_traced_state``
                      folds it back.
    """
    params: Any
    client_params: Any
    opt_state: Any
    key: Any
    labels: Any
    channel: Any = None
    sched: Any = None


@dataclass(frozen=True)
class TracedContext:
    """Static (trace-time) round geometry shared by the traced strategies.

    Every field is a compile-time constant: it sizes the fixed-shape padded
    index sets, so it is part of the XLA program cache key.
    """
    num_devices: int                  # N
    devices_per_round: int            # S
    selected_per_cluster: int         # s (Alg. 3/4)
    num_clusters: int                 # c
    bandwidth_mhz: float              # B


@runtime_checkable
class TracedSelector(Protocol):
    """Traceable device selection: returns a FIXED-SIZE padded index set.

    ``select_traced(key, divergences, labels, arr, ctx)`` returns
    ``(idx, mask)`` where ``idx`` is int32 of length ``pad_size(ctx)``;
    invalid (padding) lanes hold the out-of-bounds sentinel
    ``ctx.num_devices`` and ``mask`` is False exactly there — JAX gathers
    clamp and scatters drop those lanes, so padding is self-masking.
    ``key`` is consumed only when ``needs_rng``; deterministic policies
    leave the PRNG stream untouched (bit-parity with the host loop).
    """

    traceable: bool
    needs_rng: bool                   # split a selection key off the stream?
    needs_divergence: bool            # compute ‖w_n − w_g‖ before selecting?

    def pad_size(self, ctx: TracedContext) -> int: ...

    def select_traced(self, key, divergences, labels,
                      arr: Dict[str, Any], ctx: TracedContext) -> Tuple[Any, Any]: ...


@runtime_checkable
class TracedAllocator(Protocol):
    """Traceable spectrum allocation over a padded selected set.

    ``arr`` holds the selected devices' constants (gathered, padded lanes
    duplicated + masked); returns jnp scalars/arrays ``(T, E, b, f)`` with
    padded lanes excluded from the max/sum reductions.
    """

    traceable: bool

    def allocate_traced(self, arr: Dict[str, Any], B: float,
                        mask: Any) -> Tuple[Any, Any, Any, Any]: ...


@runtime_checkable
class ChannelModel(Protocol):
    """Pluggable physical channel (registry: ``CHANNELS`` /
    ``@register_channel``).

    Hooks, by time scale:

    * ``sample_gains(rng, d_km)`` — host-side large-scale fading at fleet
      build time (path loss + shadowing from BS–device distance); consumed
      by ``repro.api.scenario.build_fleet``.
    * ``apply_traced(key, arr)`` — MEMORYLESS per-round small-scale fading
      INSIDE the scanned round pipeline: transform the round's
      ``fleet_arrays`` dict (e.g. redraw a Rayleigh block-fading multiplier
      on J). Pure jnp; the engine splits ``key`` off the round PRNG stream
      only when ``needs_rng`` — a model with ``needs_rng = False`` leaves
      the stream (and the compiled program) untouched, bit-identical to no
      channel hook at all.
    * ``init_state(key, arr)`` / ``step_traced(key, state, arr)`` —
      ROUND-COUPLED channel dynamics for models with ``stateful = True``:
      the state pytree returned by ``init_state`` rides in the
      ``RoundState.channel`` slot of the ``lax.scan`` carry, and every
      round the engine calls ``step_traced`` (instead of ``apply_traced``)
      to evolve it and produce that round's faded arrays — e.g. the
      Gauss-Markov AR(1) complex amplitude h_t = ρ·h_{t−1} + √(1−ρ²)·w_t.
      Models without the attribute (``stateful`` defaults False via
      ``getattr``) keep the memoryless contract, so pre-existing custom
      channels are untouched.

    Build-time cross-cell geometry is a fourth, optional hook: a channel
    exposing ``cross_gain_matrix(...)`` (see ``multicell-dynamic``) makes
    ``build_fleet`` precompute the per-device interference contribution at
    every BS, and the engine folds the *selected* devices' contributions
    into each cell's rate every round.
    """

    traceable: bool
    needs_rng: bool                   # split a per-round fading key?
    stateful: bool                    # carry channel state through the scan?

    def sample_gains(self, rng: np.random.Generator,
                     d_km: np.ndarray) -> np.ndarray: ...

    def apply_traced(self, key, arr: Dict[str, Any]) -> Dict[str, Any]: ...

    def init_state(self, key, arr: Dict[str, Any]) -> Any: ...

    def step_traced(self, key, state: Any,
                    arr: Dict[str, Any]) -> Tuple[Any, Dict[str, Any]]: ...


@runtime_checkable
class Selector(Protocol):
    """Device-selection policy (paper Algorithms 3/4 and baselines)."""

    def select(self, ctx: SelectionContext) -> np.ndarray: ...


@runtime_checkable
class Allocator(Protocol):
    """Spectrum allocation for a selected set. ``arr`` is the
    ``fleet_arrays`` dict of the selected devices; ``B`` the band [MHz]."""

    def allocate(self, arr: Dict[str, Any], B: float) -> Allocation: ...


@runtime_checkable
class Aggregator(Protocol):
    """Server-side model aggregation, eq. (4) and variants. May be
    stateful (e.g. server momentum); ``reset`` clears that state.

    Traceable aggregators additionally implement the FLAT contract the
    scanned pipeline drives: ``init_flat_state(global_vec)`` builds the
    ``RoundState.opt_state`` leaf (``None`` or a flat [P] row) and
    ``aggregate_flat(global_vec, rows, weights, opt_state)`` reduces the
    round's ``[S, P]`` client rows in one masked weighted row op
    (``repro.kernels.ops.flat_aggregate``); ``load_flat_state(opt, spec)``
    syncs a finished scan back into the host object.

    ASYNC contract (buffered aggregation, ``repro.core.async_engine``): an
    aggregator advertising ``async_capable = True`` additionally exposes
    ``buffer_size`` (M — the engine fires the server update once M
    in-flight client updates have landed) and ``staleness_weights(age)``
    (the per-update discount ``(1 + age)^(-alpha)`` folded into the
    aggregation weights). The engine routes the whole experiment through
    the virtual-time tick loop instead of the round barrier whenever the
    configured aggregator is async-capable; ``aggregate_flat`` itself is
    unchanged — the engine hands it the fired buffer's rows and the
    discounted weights, so ``fedbuff:M:0`` with a full buffer degenerates
    bit-identically to the synchronous ``fedavg`` round.

    FAULT contract: under fault injection (``ExperimentSpec.faults``) the
    engine zeroes the weight of every failed lane but still hands the
    full ``[S, P]`` slab to ``aggregate_flat`` — a zero-weight row may
    carry ANY payload, including NaN (a corrupted upload), so an
    aggregator must never let a zero-weight lane touch the fold
    (``ops.flat_aggregate`` masks payloads, the trimmed mean sorts them
    to +inf). An all-zero weight vector is handled by the DRIVER (the
    round is an explicit no-op); ``aggregate_flat`` is never asked to
    invent a fallback. Robust registry aggregators: ``trimmed:f``
    (coordinate-wise trimmed mean, unweighted), ``clipnorm:c``
    (delta-norm clipping, D_n weighting preserved)."""

    def aggregate(self, global_params: Any, stacked_params: Any,
                  weights: np.ndarray) -> Any: ...

    def reset(self) -> None: ...

    # True → plain D_n-weighted mean; lets the driver fuse aggregation
    # into the jitted round step shared across experiments.
    fuses_with_engine: bool


@runtime_checkable
class Compressor(Protocol):
    """Simulated lossy uplink compression of client updates."""

    identity: bool

    def compress(self, tree: Any) -> Any: ...

    def apply(self, stacked_new: Any, global_params: Any) -> Any:
        """Compress the stacked client *deltas* against the global model."""
        ...

    def apply_flat(self, rows: Any, global_vec: Any, spec: Any) -> Any:
        """Flat-plane form of ``apply``: rows is the round's ``[S, P]``
        slab of the client-weight buffer, ``global_vec`` the flat [P]
        global row and ``spec`` the ``StackFlattenSpec`` giving each
        leaf's column segment (per-leaf scales/thresholds stay exact)."""
        ...

    def payload_mbit(self, num_params: int,
                     num_leaves: int) -> Optional[float]:
        """Uplink payload z_n [Mbit], or None to keep the fleet's own z."""
        ...
