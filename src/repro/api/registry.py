"""Strategy registries — the pluggable heart of the experiment API.

Each swappable stage of the paper's round loop (Fig. 2) has its own
registry: device selection (Alg. 3/4), spectrum allocation (Alg. 5 vs the
§VI-A baselines), aggregation (eq. 4 and beyond-paper variants), and uplink
compression. A strategy is a small class registered under a short name:

    from repro.api import SELECTORS, register

    @SELECTORS.register("my_policy")
    @dataclass(frozen=True)
    class MySelector:
        temperature: float = 1.0
        def select(self, ctx):            # ctx: api.protocols.SelectionContext
            ...

Resolution accepts three spellings and normalizes them all:

    SELECTORS.resolve("my_policy")                      # bare name
    ALLOCATORS.resolve("fedl:2.0")                      # name:arg shorthand
    ALLOCATORS.resolve({"name": "fedl",
                        "params": {"lam": 2.0}})        # explicit dict
    SELECTORS.resolve(MySelector(temperature=0.5))      # an instance, as-is

The ``name:arg`` shorthand calls the class's ``from_string`` hook, which by
default feeds the argument to the class's single positional parameter —
enough for ``fedl:2.0`` and ``topk:0.05`` without per-class parsing code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Type


class StrategyError(Exception):
    """Registry lookup / registration failure."""


class Strategy:
    """Optional base for registered strategies (dataclasses recommended).

    Provides the serialization contract: ``params()`` returns the JSON-able
    constructor kwargs and ``spec()`` the canonical ``{"name", "params"}``
    dict stored inside an ``ExperimentSpec``.
    """

    registry_name: str = "?"          # set by Registry.register

    @classmethod
    def from_string(cls, arg: Optional[str]) -> "Strategy":
        """Build from the ``name:arg`` shorthand. Default: feed ``arg`` to
        the first dataclass field (numeric if it parses)."""
        if arg is None or arg == "":
            return cls()
        fields = dataclasses.fields(cls) if dataclasses.is_dataclass(cls) else ()
        if not fields:
            raise StrategyError(
                f"{cls.registry_name!r} takes no ':arg' parameter (got {arg!r})")
        f0 = fields[0]
        value: Any = arg
        if f0.type in ("float", "int", float, int):
            try:
                value = int(arg) if f0.type in ("int", int) else float(arg)
            except ValueError:
                raise StrategyError(
                    f"{cls.registry_name}:{arg}: expected a number for "
                    f"{f0.name!r}") from None
        return cls(**{f0.name: value})

    def params(self) -> Dict[str, Any]:
        if dataclasses.is_dataclass(self):
            return {f.name: getattr(self, f.name)
                    for f in dataclasses.fields(self) if f.init}
        return {}

    def spec(self) -> Dict[str, Any]:
        return {"name": self.registry_name, "params": self.params()}


class Registry:
    """Name → strategy-class mapping for one stage of the round loop."""

    def __init__(self, kind: str):
        self.kind = kind
        self._classes: Dict[str, Type] = {}

    # -- registration ---------------------------------------------------
    def register(self, name: str) -> Callable[[Type], Type]:
        if ":" in name:
            raise StrategyError(f"{self.kind} name {name!r} may not contain ':'")

        def deco(cls: Type) -> Type:
            if name in self._classes:
                raise StrategyError(
                    f"duplicate {self.kind} {name!r} "
                    f"(already registered to {self._classes[name].__qualname__})")
            self._classes[name] = cls
            cls.registry_name = name
            return cls

        return deco

    # -- lookup ---------------------------------------------------------
    def get(self, name: str) -> Type:
        try:
            return self._classes[name]
        except KeyError:
            raise StrategyError(
                f"unknown {self.kind} {name!r}; registered: {self.names()}"
            ) from None

    def names(self) -> List[str]:
        return sorted(self._classes)

    def __contains__(self, name: str) -> bool:
        return name in self._classes

    def resolve(self, spec: Any, **overrides: Any):
        """Normalize name / ``name:arg`` / ``{"name", "params"}`` / instance
        into a strategy instance. ``overrides`` are extra constructor kwargs
        applied on top of dict params (used by back-compat shims)."""
        if isinstance(spec, str):
            name, _, arg = spec.partition(":")
            cls = self.get(name)
            if hasattr(cls, "from_string"):
                inst = cls.from_string(arg or None)
            elif arg:
                raise StrategyError(
                    f"{self.kind} {name!r} has no from_string hook for "
                    f"the ':{arg}' shorthand")
            else:
                inst = cls()
            if overrides:
                inst = dataclasses.replace(inst, **overrides) \
                    if dataclasses.is_dataclass(inst) else cls(**overrides)
            return inst
        if isinstance(spec, dict):
            extra = set(spec) - {"name", "params"}
            if "name" not in spec or extra:
                raise StrategyError(
                    f"{self.kind} dict must have keys {{'name', 'params'}}; "
                    f"got {sorted(spec)}")
            cls = self.get(spec["name"])
            return cls(**{**spec.get("params", {}), **overrides})
        if isinstance(spec, type):
            raise StrategyError(
                f"got the {self.kind} class {spec.__name__}; pass an "
                f"instance ({spec.__name__}(...)) or its registered name")
        if hasattr(spec, "registry_name"):       # already an instance
            return spec
        raise StrategyError(
            f"cannot resolve {self.kind} from {type(spec).__name__}: {spec!r}")

    def canonical(self, spec: Any) -> Dict[str, Any]:
        """The normalized ``{"name", "params"}`` form (ExperimentSpec storage)."""
        inst = self.resolve(spec)
        return inst.spec()


SELECTORS = Registry("selector")
ALLOCATORS = Registry("allocator")
AGGREGATORS = Registry("aggregator")
COMPRESSORS = Registry("compressor")
CHANNELS = Registry("channel")

_BY_KIND = {r.kind: r for r in (SELECTORS, ALLOCATORS, AGGREGATORS,
                                COMPRESSORS, CHANNELS)}


def register_channel(name: str):
    """Register a :class:`~repro.api.protocols.ChannelModel` under ``name``
    (sugar for ``CHANNELS.register`` — the scenario-API entry point)."""
    return CHANNELS.register(name)


def get_registry(kind: str) -> Registry:
    try:
        return _BY_KIND[kind]
    except KeyError:
        raise StrategyError(
            f"unknown registry kind {kind!r}; known: {sorted(_BY_KIND)}") from None
