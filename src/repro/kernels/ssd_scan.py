"""Pallas TPU kernel: Mamba2 SSD chunked scan.

The TPU-idiomatic form of the selective scan (DESIGN.md §5): instead of the
GPU per-timestep selective-scan kernel, SSD factorizes each chunk into dense
MXU matmuls (intra-chunk quadratic attention-like block + chunk-state
outer products) with a tiny sequential state recurrence across chunks.

Grid: (B·H, S/Q) with the chunk axis minor/sequential; the [P, N] SSM state
lives in VMEM scratch across chunk steps.

Layouts: X [BH, S, P]; A (log-decay, = dt·a < 0) [BH, S]; B, C [BH, S, N]
(already head-expanded for grouped SSMs). Outputs: Y [BH, S, P] and the
final state [BH, P, N].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                Q: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)            # [Q, P]
    a = a_ref[0].astype(jnp.float32)            # [Q]
    b = b_ref[0].astype(jnp.float32)            # [Q, N]
    c = c_ref[0].astype(jnp.float32)            # [Q, N]

    a_cum = jnp.cumsum(a)                        # [Q]
    # intra-chunk decay matrix L[i, j] = exp(sum_{j<k<=i} a_k), i >= j
    seg = a_cum[:, None] - a_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q, Q]
    y_diag = jax.lax.dot_general(scores * L, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q, P]

    h = h_ref[...]                               # [P, N]
    # off-diagonal: carried state read out through C with in-chunk decay
    y_off = jax.lax.dot_general(c, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)   # [Q, P]
    y_off = y_off * jnp.exp(a_cum)[:, None]
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: h' = exp(A_chunk)·h + Σ_q exp(A_chunk − a_cum_q)·x_q⊗b_q
    decay_states = jnp.exp(a_cum[-1] - a_cum)    # [Q]
    h_new = h * jnp.exp(a_cum[-1]) + jax.lax.dot_general(
        x * decay_states[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [P, N]
    h_ref[...] = h_new

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a, b, c, *, chunk: int = 256, interpret: bool = True):
    """x: [BH, S, P]; a: [BH, S]; b, c: [BH, S, N].

    Returns (y: [BH, S, P], final_state: [BH, P, N] fp32). S must not be
    ragged; the wrapper pads with a=0, x=0 (identity steps).
    """
    BH, S, P = x.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad

    kernel = functools.partial(_ssd_kernel, Q=Q)
    y, h = pl.pallas_call(
        kernel,
        grid=(BH, Sp // Q),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, Q), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
    return y[:, :S], h
