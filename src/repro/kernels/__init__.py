"""Pallas TPU kernels for the framework's compute hot-spots, with jnp
oracles in ref.py and jit'd dispatch wrappers in ops.py.

  pairwise_l2     — K-means assignment / weight-divergence distance matrix
  flat_aggregate  — eq.-(4) aggregation GEMV over the [N, P] client plane
  flash_attention — blocked online-softmax attention (causal / SWA)
  ssd_scan        — Mamba2 SSD chunked scan (MXU-dense intra-chunk form)
"""
from repro.kernels import ops, ref
from repro.kernels.pairwise_l2 import pairwise_l2
from repro.kernels.flat_aggregate import flat_aggregate
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
