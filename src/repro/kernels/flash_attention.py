"""Pallas TPU kernel: flash attention (forward), causal + sliding window.

The compute hot-spot of every attention-bearing assigned architecture.
Online-softmax over KV tiles: the [Sq, Sk] score matrix never leaves VMEM,
and each KV tile is streamed through the MXU once. Block sizes default to
(128, 128) — MXU-aligned on both matmul dims.

Grid: (B·H, Sq/bq, Sk/bk) with the KV axis minor (sequential on TPU), so the
running max / sum / accumulator live in VMEM scratch across KV steps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window, sq: int, sk: int,
                  bq: int, bk: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [bk, D]
    v = v_ref[0].astype(jnp.float32)                  # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    # positions: queries right-aligned to keys (supports Sq < Sk decode)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk                                   # padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(s > NEG_INF * 0.5, jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, H, Sq, D]; k, v: [B, H, Sk, D] -> [B, H, Sq, D].

    GQA callers repeat KV heads up to H before the call (the wrapper in
    ``repro.kernels.ops`` does this).
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sqp, Skp = Sq + pad_q, Sk + pad_k
    qf = q.reshape(B * H, Sqp, D)
    kf = k.reshape(B * H, Skp, D)
    vf = v.reshape(B * H, Skp, D)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, sq=Sq, sk=Sk, bq=bq, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sqp // bq, Skp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),          # running max m
            pltpu.VMEM((bq,), jnp.float32),          # running sum l
            pltpu.VMEM((bq, D), jnp.float32),        # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sqp, D)[:, :, :Sq]
