"""Pallas TPU kernel: masked weighted row-reduction over the flat client
plane — FedAvg aggregation (eq. 4) as one GEMV.

The FL round's aggregation is ``g = Σ_n w_n · flat[n, :]`` over the
``[N, P]`` client-weight buffer (weights already masked + normalized by the
caller, ``repro.kernels.ops.flat_aggregate``). On TPU each (bn × bp) tile
of the plane is read into VMEM exactly once and contracted against its
weight slab on the MXU, accumulating fp32 partial sums in the output tile
across the N grid axis — the same single-read discipline as
``pairwise_l2`` (DESIGN.md §5). Block shapes default to MXU/VPU-aligned
(128, 512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flat_aggregate_kernel(w_ref, x_ref, out_ref):
    """Grid: (P/bp, N/bn); N is the minor (sequential) axis, so the output
    tile accumulates partial weighted sums across N blocks."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...].astype(jnp.float32)          # [1, bn]
    x = x_ref[...].astype(jnp.float32)          # [bn, bp]
    out_ref[...] += jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bn", "bp", "interpret"))
def flat_aggregate(flat: jnp.ndarray, weights: jnp.ndarray, *,
                   bn: int = 128, bp: int = 512,
                   interpret: bool = True) -> jnp.ndarray:
    """Weighted row sum. flat: [N, P]; weights: [N] -> [P] float32.

    Zero-padded lanes contribute zero weight, so padding N or P to block
    multiples never changes the sum. interpret=True executes the kernel
    body in Python on CPU (validation); on a real TPU pass interpret=False.
    """
    N, P = flat.shape
    bn = min(bn, max(8, N))
    bp = min(bp, max(128, P))
    pad_n = (-N) % bn
    pad_p = (-P) % bp
    if pad_n or pad_p:
        flat = jnp.pad(flat, ((0, pad_n), (0, pad_p)))
    if pad_n:
        weights = jnp.pad(weights, (0, pad_n))
    Np, Pp = flat.shape
    w2d = weights.astype(jnp.float32).reshape(1, Np)

    out = pl.pallas_call(
        _flat_aggregate_kernel,
        grid=(Pp // bp, Np // bn),
        in_specs=[
            pl.BlockSpec((1, bn), lambda j, k: (0, k)),
            pl.BlockSpec((bn, bp), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, Pp), jnp.float32),
        interpret=interpret,
    )(w2d, flat)
    return out[0, :P]
