"""Pallas TPU kernel: blocked pairwise squared-L2 distance.

The HBM-bandwidth hot spot of the paper's selection layer: K-means
assignment (Alg. 2/3, eq. 13) and the Fig.-4 distance-matrix study both
reduce to ‖x_n − c_m‖² over clients × centroids with feature dims up to
millions (all-weights features).

TPU adaptation (DESIGN.md §5): each (bn × bf) X-tile and (bm × bf) C-tile is
read into VMEM exactly once; the difference-square is accumulated in an fp32
VMEM tile across the F grid axis. This avoids the ‖x‖²+‖c‖²−2x·c expansion's
extra passes and its catastrophic cancellation in low precision. Block
shapes default to MXU/VPU-aligned (128, 512).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_l2_kernel(x_ref, c_ref, out_ref):
    """Grid: (N/bn, M/bm, F/bf); F is the minor (sequential) axis, so the
    output tile accumulates partial sums across F blocks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # [bn, bf]
    c = c_ref[...].astype(jnp.float32)          # [bm, bf]
    # sum_f (x_nf - c_mf)^2 for this F-slab, via the MXU-friendly expansion
    # INSIDE one slab (single read per operand, fp32 accumulate).
    xx = jnp.sum(x * x, axis=1, keepdims=True)              # [bn, 1]
    cc = jnp.sum(c * c, axis=1, keepdims=True).T            # [1, bm]
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out_ref[...] += xx + cc - 2.0 * xc


@functools.partial(jax.jit,
                   static_argnames=("bn", "bm", "bf", "interpret"))
def pairwise_l2(x: jnp.ndarray, c: jnp.ndarray, *, bn: int = 128,
                bm: int = 128, bf: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """Squared pairwise distances. x: [N, F]; c: [M, F] -> [N, M] float32.

    interpret=True executes the kernel body in Python on CPU (this
    container); on a real TPU pass interpret=False.
    """
    N, F = x.shape
    M = c.shape[0]
    bn = min(bn, max(8, N))
    bm = min(bm, max(8, M))
    bf = min(bf, max(128, F))
    pad_n = (-N) % bn
    pad_m = (-M) % bm
    pad_f = (-F) % bf
    if pad_n or pad_f:
        x = jnp.pad(x, ((0, pad_n), (0, pad_f)))
    if pad_m or pad_f:
        c = jnp.pad(c, ((0, pad_m), (0, pad_f)))
    Np, Fp = x.shape
    Mp = c.shape[0]

    out = pl.pallas_call(
        _pairwise_l2_kernel,
        grid=(Np // bn, Mp // bm, Fp // bf),
        in_specs=[
            pl.BlockSpec((bn, bf), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bf), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), jnp.float32),
        interpret=interpret,
    )(x, c)
    out = jnp.maximum(out, 0.0)   # clamp fp roundoff on the diagonal
    return out[:N, :M]
