"""Streaming chunked reductions over the cold half of the client store.

The flat ``[N, P]`` plane (PR 5) made every per-round reduction one fused
row op — but also made *peak memory* O(N·P). The paged client store
(``repro.core.store``) keeps only O(K_max·P + chunk·P) resident; these
drivers run the same fused row ops (``ops.client_divergence``,
``ops.pairwise_sq_dists``) a chunk at a time and stream the per-row results
out to host, so the reductions stay O(chunk·P) in memory at any point.

Every reduction here is ROW-INDEPENDENT (a per-row norm, a per-row distance
vector), so chunking changes neither the math nor the bits: the fp32 result
for row ``n`` is produced by the identical op on the identical row whether
it arrives in one ``[N, P]`` call or in ``ceil(N/chunk)`` block calls. The
paged≡dense parity pins in ``tests/test_paged_store.py`` rest on exactly
this property.

Inputs may be a single array (chunked here) or an iterable of
``[c_i, P]`` blocks (the paged store's ``iter_chunks`` yields assembled
blocks without ever materializing the plane). Per-chunk compute is jitted;
callers that page with a fixed ``chunk_size`` compile at most two shapes
(the full chunk and the last partial one).
"""
from __future__ import annotations

from typing import Iterable, Iterator, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

DEFAULT_CHUNK_BYTES = 64 << 20     # ~64 MB of fp32 rows per resident chunk

Blocks = Union[np.ndarray, jnp.ndarray, Iterable[np.ndarray]]


def default_chunk_size(row_size: int, *, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                       lo: int = 64, hi: int = 8192) -> int:
    """Rows per chunk so a resident fp32 block stays ~``chunk_bytes``."""
    rows = chunk_bytes // max(4 * int(row_size), 1)
    return int(min(hi, max(lo, rows)))


def iter_blocks(rows: Blocks, chunk_size: int) -> Iterator[np.ndarray]:
    """Yield ``[<=chunk_size, P]`` blocks from an array or pass blocks
    through from an iterable (re-chunking is the producer's business)."""
    if isinstance(rows, (np.ndarray, jnp.ndarray)):
        n = rows.shape[0]
        for start in range(0, n, chunk_size):
            yield rows[start:start + chunk_size]
    else:
        yield from rows


@jax.jit
def _div_chunk(block, gvec):
    return ops.client_divergence(block, gvec)


@jax.jit
def _pairwise_chunk(block, centroids):
    return ops.pairwise_sq_dists(block, centroids)


def chunked_client_divergence(rows: Blocks, gvec, *,
                              chunk_size: int | None = None) -> np.ndarray:
    """‖row_n − g‖₂ for every row, streamed chunk-at-a-time to host.

    Bitwise identical to ``ops.client_divergence(rows, gvec)`` on the
    concatenated input (row-independent reduction). Returns a host ``[N]``
    fp32 array; device residency never exceeds one chunk of rows.
    """
    gvec = jnp.asarray(gvec, jnp.float32)
    if chunk_size is None:
        chunk_size = default_chunk_size(gvec.shape[0])
    out = [np.asarray(_div_chunk(jnp.asarray(b, jnp.float32), gvec))
           for b in iter_blocks(rows, chunk_size)]
    if not out:
        return np.zeros((0,), np.float32)
    return np.concatenate(out)


def chunked_pairwise(rows: Blocks, centroids, *,
                     chunk_size: int | None = None) -> np.ndarray:
    """``[N, P] × [M, P] -> [N, M]`` squared L2, streamed over row chunks.

    A single chunk is exactly one jitted ``ops.pairwise_sq_dists`` call.
    Across chunks the reduction stays per (row, centroid) pair — chunking
    never mixes rows — but very long rows can tile the contraction
    differently per block shape, so agreement is to fp32 accumulation
    order, not bitwise. Peak device memory is one row chunk plus the
    centroid block.
    """
    centroids = jnp.asarray(centroids, jnp.float32)
    if chunk_size is None:
        chunk_size = default_chunk_size(centroids.shape[-1])
    out = [np.asarray(_pairwise_chunk(jnp.asarray(b, jnp.float32), centroids))
           for b in iter_blocks(rows, chunk_size)]
    if not out:
        return np.zeros((0, centroids.shape[0]), np.float32)
    return np.concatenate(out, axis=0)


@jax.jit
def _wsum_chunk(block, weights):
    w = weights.astype(jnp.float32)
    return block.astype(jnp.float32).T @ w, jnp.sum(w)


def streaming_weighted_mean(blocks: Iterable[Tuple[np.ndarray, np.ndarray]],
                            row_size: int) -> np.ndarray:
    """Eq.-(4) weighted mean over ``(rows, weights)`` blocks without ever
    holding more than one block: ``Σ w_n x_n / Σ w_n`` accumulated in fp32.

    NOT bitwise-identical to a single ``ops.flat_aggregate`` call (the
    summation splits at chunk boundaries and the division happens once at
    the end); the paged driver therefore uses this only for multi-wave
    initial rounds, where no dense pin exists — single-wave rounds call
    ``flat_aggregate`` directly and stay on the pinned numerics.
    """
    acc = np.zeros((row_size,), np.float32)
    wsum = 0.0
    for rows, weights in blocks:
        s, w = _wsum_chunk(jnp.asarray(rows, jnp.float32),
                           jnp.asarray(weights))
        acc += np.asarray(s)
        wsum += float(w)
    return acc / max(wsum, 1e-12)
