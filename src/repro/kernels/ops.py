"""Jit'd public wrappers around the Pallas kernels.

Model code calls these; ``use_pallas`` switches between the kernel (TPU
target; interpret mode on CPU) and the pure-jnp reference path. The default
follows the backend: kernels on TPU, references on CPU — interpret mode is
for validation, not speed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.pairwise_l2 import pairwise_l2 as _pairwise
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pairwise_sq_dists(x, c, *, use_pallas: bool | None = None):
    """[N, F] × [M, F] -> [N, M] squared L2 (K-means / Fig. 4 hot spot)."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return _pairwise(x, c, interpret=not _on_tpu())
    return ref.pairwise_l2_ref(x, c)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              use_pallas: bool | None = None):
    """GQA-aware attention. q: [B, S, H, D]; k, v: [B, S, K, D]."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    B, Sq, H, D = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_pallas:
        out = _flash(qt, kt, vt, causal=causal, window=window,
                     interpret=not _on_tpu())
    else:
        out = ref.flash_attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)


def ssd(x, a, b, c, *, chunk: int = 256, n_groups: int = 1,
        use_pallas: bool | None = None):
    """Mamba2 SSD. x: [B, S, H, P]; a: [B, S, H]; b, c: [B, S, G, N].

    Returns (y: [B, S, H, P], state: [B, H, P, N]).
    """
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    B, S, H, P = x.shape
    N = b.shape[-1]
    repg = H // b.shape[2]
    bh = jnp.repeat(b, repg, axis=2)
    ch = jnp.repeat(c, repg, axis=2)
    if use_pallas:
        xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
        af = a.transpose(0, 2, 1).reshape(B * H, S)
        bf = bh.transpose(0, 2, 1, 3).reshape(B * H, S, N)
        cf = ch.transpose(0, 2, 1, 3).reshape(B * H, S, N)
        y, h = _ssd(xf, af, bf, cf, chunk=chunk, interpret=not _on_tpu())
        return (y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
                h.reshape(B, H, P, N))
    return ref.ssd_ref(x, a, bh, ch)
