"""Jit'd public wrappers around the Pallas kernels.

Model code calls these; ``use_pallas`` switches between the kernel (TPU
target; interpret mode on CPU) and the pure-jnp reference path. The default
follows the backend: kernels on TPU, references on CPU — interpret mode is
for validation, not speed.

Dispatch policy (``_resolve_use_pallas``): an EXPLICIT ``use_pallas=True``
off-TPU lands the kernel in interpret mode, which on the round hot path is
orders of magnitude slower than the jnp reference (``flat_aggregate``:
3.3 s interpreted vs sub-ms jnp — see ROADMAP) — so it raises a
``RuntimeWarning``. Setting ``REPRO_FORCE_PALLAS=1`` is the escape hatch
for deliberate interpret-mode validation runs: it silences the warning and
also flips the ``use_pallas=None`` default to the kernel path everywhere.
"""
from __future__ import annotations

import os
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flat_aggregate import flat_aggregate as _flat_agg
from repro.kernels.pairwise_l2 import pairwise_l2 as _pairwise
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_pallas() -> bool:
    # read at call time (not import time) so tests/validation runs can
    # monkeypatch the environment per-case
    return os.environ.get("REPRO_FORCE_PALLAS", "").lower() not in (
        "", "0", "false", "no")


def _resolve_use_pallas(op: str, use_pallas: bool | None) -> bool:
    """Apply the dispatch policy for one op call (see module docstring)."""
    if use_pallas is None:
        return True if _force_pallas() else _on_tpu()
    if use_pallas and not _on_tpu() and not _force_pallas():
        warnings.warn(
            f"{op}: use_pallas=True off-TPU runs the Pallas kernel in "
            "interpret mode — a hot-path op becomes orders of magnitude "
            "slower than the jnp reference. Pass use_pallas=None to follow "
            "the backend, or set REPRO_FORCE_PALLAS=1 for a deliberate "
            "interpret-mode validation run.",
            RuntimeWarning, stacklevel=3)
    return use_pallas


def kernel_dispatch(use_pallas: bool | None = None) -> bool:
    """Would this call take the kernel route? The policy of
    ``_resolve_use_pallas`` WITHOUT the off-TPU warning — for callers
    (``models.transformer`` / ``models.layers``) that branch between an op
    here and their own jnp path, then pass the raw ``use_pallas`` down so
    the op's resolver still owns the single warning."""
    if use_pallas is None:
        return _force_pallas() or _on_tpu()
    return use_pallas


def pairwise_sq_dists(x, c, *, use_pallas: bool | None = None):
    """[N, F] × [M, F] -> [N, M] squared L2 (K-means / Fig. 4 hot spot).

    THE pairwise-distance implementation — K-means assignment
    (``repro.core.clustering``) and the Fig.-4 divergence matrix
    (``repro.core.divergence``) both route here. Off-TPU it is the
    streaming ‖x‖²+‖c‖²−2x·c expansion; both paths clamp at zero so no
    call site can see a negative squared distance from fp roundoff.
    """
    use_pallas = _resolve_use_pallas("pairwise_sq_dists", use_pallas)
    if use_pallas:
        return _pairwise(x, c, interpret=not _on_tpu())
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    xn = jnp.sum(jnp.square(x), axis=1, keepdims=True)
    cn = jnp.sum(jnp.square(c), axis=1)[None, :]
    return jnp.maximum(xn + cn - 2.0 * x @ c.T, 0.0)


def flat_aggregate(flat, weights, *, mask=None, normalize: bool = True,
                   use_pallas: bool | None = None):
    """Masked weighted row-reduction over the flat client plane:
    ``[N, P] × [N] -> [P]`` — FedAvg aggregation (eq. 4) as one fused op.

    ``mask`` zeroes padding lanes' weights; ``normalize`` divides by the
    (masked) weight sum, giving the eq.-(4) weighted mean. On TPU this is
    the ``flat_aggregate`` Pallas GEMV kernel; elsewhere the jnp reference
    whose summation order matches the pytree ``tree_weighted_mean_stacked``
    bit for bit in fp32.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = jnp.where(mask, w, 0.0)
    # Non-finite guard: a NaN/Inf row would poison the fold even at
    # weight 0 (0·NaN = NaN in the weighted reduction), so zero the
    # payload of every masked-out lane before either backend sees it.
    # Bitwise no-op for finite inputs: a 0-weight finite row contributed
    # exactly 0.0 to each partial sum already.
    flat = jnp.where((w > 0.0)[:, None], flat, jnp.zeros((), flat.dtype))
    if normalize:
        # the max() guard only bites when every lane is masked out (sum=0):
        # an empty round then aggregates to zeros instead of poisoning the
        # scan carry with 0/0 NaNs; real weight sums are untouched bitwise
        w = w / jnp.maximum(jnp.sum(w), 1e-12)
    use_pallas = _resolve_use_pallas("flat_aggregate", use_pallas)
    if use_pallas:
        return _flat_agg(flat, w, interpret=not _on_tpu())
    return ref.flat_aggregate_ref(flat, w)


def client_divergence(flat, gvec, *, use_pallas: bool | None = None):
    """[N] weight divergences ‖flat_n − g‖₂ of the flat client plane
    against the flat global row — §IV-C's selection signal as one fused
    row-norm reduction (the Pallas ``pairwise_l2`` kernel with the global
    model as a single centroid on TPU; a fused subtract-square-reduce
    elsewhere, numerically stronger than the expansion for near-identical
    rows)."""
    use_pallas = _resolve_use_pallas("client_divergence", use_pallas)
    if use_pallas:
        d2 = _pairwise(flat, gvec[None, :], interpret=not _on_tpu())[:, 0]
        return jnp.sqrt(d2)
    diff = flat.astype(jnp.float32) - gvec.astype(jnp.float32)[None, :]
    return jnp.sqrt(jnp.sum(jnp.square(diff), axis=1))


def chunked_client_divergence(rows, gvec, *, chunk_size: int | None = None):
    """Streaming form of :func:`client_divergence` for the paged client
    store: pages ``rows`` (an array or an iterable of ``[c, P]`` blocks,
    e.g. ``PagedStore.iter_chunks()``) through the fused row-norm reduction
    one chunk at a time. Bitwise identical per row (the reduction is
    row-independent); peak device memory is O(chunk·P). Returns a host
    ``[N]`` fp32 array."""
    from repro.kernels.chunked import chunked_client_divergence as _impl
    return _impl(rows, gvec, chunk_size=chunk_size)


def chunked_pairwise(rows, centroids, *, chunk_size: int | None = None):
    """Streaming form of :func:`pairwise_sq_dists` over row chunks —
    K-means assignment against a cold store without materializing the
    ``[N, P]`` plane. Bitwise identical per row; returns a host ``[N, M]``
    fp32 array."""
    from repro.kernels.chunked import chunked_pairwise as _impl
    return _impl(rows, centroids, chunk_size=chunk_size)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              use_pallas: bool | None = None):
    """GQA-aware attention. q: [B, S, H, D]; k, v: [B, S, K, D]."""
    use_pallas = _resolve_use_pallas("attention", use_pallas)
    B, Sq, H, D = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_pallas:
        out = _flash(qt, kt, vt, causal=causal, window=window,
                     interpret=not _on_tpu())
    else:
        out = ref.flash_attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)


def ssd(x, a, b, c, *, chunk: int = 256, n_groups: int = 1,
        use_pallas: bool | None = None):
    """Mamba2 SSD. x: [B, S, H, P]; a: [B, S, H]; b, c: [B, S, G, N].

    Returns (y: [B, S, H, P], state: [B, H, P, N]).
    """
    use_pallas = _resolve_use_pallas("ssd", use_pallas)
    B, S, H, P = x.shape
    N = b.shape[-1]
    repg = H // b.shape[2]
    bh = jnp.repeat(b, repg, axis=2)
    ch = jnp.repeat(c, repg, axis=2)
    if use_pallas:
        xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
        af = a.transpose(0, 2, 1).reshape(B * H, S)
        bf = bh.transpose(0, 2, 1, 3).reshape(B * H, S, N)
        cf = ch.transpose(0, 2, 1, 3).reshape(B * H, S, N)
        y, h = _ssd(xf, af, bf, cf, chunk=chunk, interpret=not _on_tpu())
        return (y.reshape(B, H, S, P).transpose(0, 2, 1, 3),
                h.reshape(B, H, P, N))
    return ref.ssd_ref(x, a, bh, ch)
