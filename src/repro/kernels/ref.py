"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately written in the most *naive* correct form — e.g. the
SSD oracle is the token-by-token recurrence, not the chunked algorithm — so
kernel tests compare two genuinely independent implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def pairwise_l2_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances. x: [N, F]; c: [M, F] -> [N, M] fp32.

    Naive O(N·M·F)-memory difference form — the kernel-test oracle only.
    Production call sites go through ``repro.kernels.ops.pairwise_sq_dists``
    (the streaming ‖x‖²+‖c‖²−2x·c expansion, clamped at zero).
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(jnp.square(diff), axis=-1)


def flat_aggregate_ref(flat: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Weighted row sum over the flat client plane: [N, P] × [N] -> [P].

    Spelled as an elementwise multiply + axis-0 reduce (NOT a dot) so the
    summation order matches ``tree_weighted_mean_stacked`` column for
    column — the flat FedAvg path stays bit-identical to the pytree path
    in fp32. Doubles as the production jnp path off-TPU.
    """
    w = weights.astype(jnp.float32)
    return jnp.sum(flat.astype(jnp.float32) * w[:, None], axis=0)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jnp.ndarray:
    """Plain softmax attention. q: [B, H, Sq, D]; k, v: [B, H, Sk, D]."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)   # right-aligned positions
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(X, A, Bm, Cm) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token SSD recurrence (the definitionally-correct oracle).

    X: [B, S, H, P] (pre-scaled by dt); A: [B, S, H] log-decay; Bm, Cm:
    [B, S, H, N] (already head-expanded). Returns (Y [B,S,H,P], h [B,H,P,N]).

      h_t = exp(A_t)·h_{t-1} + B_t ⊗ X_t ;   y_t = h_t · C_t
    """
    B, S, H, P = X.shape
    N = Bm.shape[-1]
    h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = h * jnp.exp(a_t)[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", x_t, b_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    xs = (X.transpose(1, 0, 2, 3).astype(jnp.float32),
          A.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2, 3).astype(jnp.float32),
          Cm.transpose(1, 0, 2, 3).astype(jnp.float32))
    h, ys = lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h
