"""Minimal metrics logging: in-memory history + CSV flush."""
from __future__ import annotations

import csv
import os
import time
from collections import defaultdict
from typing import Dict


class MetricsLogger:
    def __init__(self, csv_path: str = None):
        self.history = defaultdict(list)
        self.csv_path = csv_path
        self._t0 = time.time()

    def log(self, step: int, metrics: Dict):
        self.history["step"].append(step)
        self.history["wall_s"].append(time.time() - self._t0)
        for k, v in metrics.items():
            self.history[k].append(float(v))

    def flush(self):
        if not self.csv_path:
            return
        os.makedirs(os.path.dirname(self.csv_path) or ".", exist_ok=True)
        keys = list(self.history.keys())
        rows = zip(*[self.history[k] for k in keys])
        with open(self.csv_path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(keys)
            w.writerows(rows)

    def last(self, key: str):
        return self.history[key][-1] if self.history[key] else None
