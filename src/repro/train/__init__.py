from repro.train.optimizer import make_optimizer, cosine_schedule, clip_by_global_norm
from repro.train.train_step import make_train_step, make_loss_fn, cross_entropy
from repro.train.checkpoint import save_checkpoint, load_checkpoint, checkpoint_step
from repro.train.metrics import MetricsLogger
