"""Dependency-free pytree checkpointing: one .npz of leaves + a JSON
manifest holding the key paths (restores exact tree structure and dtypes).

Writes are ATOMIC: every file lands under a temporary name and is
``os.replace``d into place, and the manifest is written LAST — readers
treat its presence as the commit marker, so a writer killed mid-snapshot
leaves either the previous complete checkpoint or no manifest at all,
never a torn one. ``write_latest``/``latest_checkpoint`` maintain the
``LATEST`` pointer a directory of ``round_*`` snapshots resolves through
(with a newest-complete-snapshot fallback when the pointer itself is
stale)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            # numpy's savez can't serialize ml_dtypes (bfloat16) — store
            # as f32 (lossless widening); restore casts back via manifest.
            arr = arr.astype(np.float32)
        out[name] = arr
    return out


def _atomic_savez(path: str, **arrays) -> None:
    """``np.savez`` through a temp file + ``os.replace`` (same dir, so the
    rename is atomic on POSIX). A bare temp NAME would grow ``.npz`` under
    savez's suffix logic — hand it an open file object instead."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def _atomic_json(path: str, obj) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1)
    os.replace(tmp, path)


def save_checkpoint(path: str, tree: Any, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    leaves = _flatten_with_names(tree)
    _atomic_savez(os.path.join(path, "leaves.npz"), **leaves)
    manifest = {
        "step": step,
        "keys": sorted(leaves.keys()),
        "dtypes": {k: str(v.dtype) for k, v in leaves.items()},
        "shapes": {k: list(v.shape) for k, v in leaves.items()},
        "extra": extra or {},
    }
    # the manifest commits the checkpoint — written last, atomically
    _atomic_json(os.path.join(path, "manifest.json"), manifest)


def load_checkpoint(path: str, template: Any):
    """Restore into the structure of ``template`` (names must match)."""
    with np.load(os.path.join(path, "leaves.npz")) as data:
        loaded = {k: data[k] for k in data.files}
    names = list(_flatten_with_names(template).keys())
    flat, treedef = jax.tree_util.tree_flatten(template)
    assert len(names) == len(flat)
    new_leaves = []
    for name, leaf in zip(names, flat):
        arr = loaded[name]
        assert arr.shape == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


def checkpoint_extra(path: str) -> dict:
    """The ``extra`` dict a snapshot's manifest carries."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("extra", {})


def is_checkpoint(path: str) -> bool:
    """A directory is a complete snapshot iff its manifest committed."""
    return os.path.isfile(os.path.join(path, "manifest.json"))


def write_latest(directory: str, name: str) -> None:
    """Atomically flip ``directory/LATEST`` to point at snapshot ``name``."""
    tmp = os.path.join(directory, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(name + "\n")
    os.replace(tmp, os.path.join(directory, "LATEST"))


def latest_checkpoint(directory: str) -> str:
    """Resolve a checkpoint reference: ``directory`` may be a snapshot
    itself, or a parent of ``round_*`` snapshots — resolved through its
    ``LATEST`` pointer, falling back to the newest COMPLETE snapshot (one
    whose manifest committed) when the pointer is missing or stale."""
    if is_checkpoint(directory):
        return directory
    pointer = os.path.join(directory, "LATEST")
    if os.path.isfile(pointer):
        with open(pointer) as f:
            cand = os.path.join(directory, f.read().strip())
        if is_checkpoint(cand):
            return cand
    if os.path.isdir(directory):
        for name in sorted(os.listdir(directory), reverse=True):
            if name.startswith("round_") and not name.endswith(".tmp"):
                cand = os.path.join(directory, name)
                if is_checkpoint(cand):
                    return cand
    raise FileNotFoundError(
        f"no complete checkpoint found under {directory!r}")
