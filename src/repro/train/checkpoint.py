"""Dependency-free pytree checkpointing: one .npz of leaves + a JSON
manifest holding the key paths (restores exact tree structure and dtypes)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            # numpy's savez can't serialize ml_dtypes (bfloat16) — store
            # as f32 (lossless widening); restore casts back via manifest.
            arr = arr.astype(np.float32)
        out[name] = arr
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    leaves = _flatten_with_names(tree)
    np.savez(os.path.join(path, "leaves.npz"), **leaves)
    manifest = {
        "step": step,
        "keys": sorted(leaves.keys()),
        "dtypes": {k: str(v.dtype) for k, v in leaves.items()},
        "shapes": {k: list(v.shape) for k, v in leaves.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, template: Any):
    """Restore into the structure of ``template`` (names must match)."""
    with np.load(os.path.join(path, "leaves.npz")) as data:
        loaded = {k: data[k] for k in data.files}
    names = list(_flatten_with_names(template).keys())
    flat, treedef = jax.tree_util.tree_flatten(template)
    assert len(names) == len(flat)
    new_leaves = []
    for name, leaf in zip(names, flat):
        arr = loaded[name]
        assert arr.shape == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
