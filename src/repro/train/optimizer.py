"""Optimizers from scratch (no optax in the container): AdamW, SGD,
momentum-SGD, with cosine LR schedule and global-norm clipping."""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.utils.trees import tree_global_norm


class OptState(NamedTuple):
    step: jnp.ndarray
    m: object          # first moment (or momentum buffer); None-like for sgd
    v: object          # second moment; unused for sgd/momentum


def cosine_schedule(cfg: TrainConfig):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.learning_rate * (step + 1.0) / max(cfg.warmup_steps, 1)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * cfg.learning_rate * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def make_optimizer(cfg: TrainConfig) -> Tuple[Callable, Callable]:
    """Returns (init_fn(params) -> state, update_fn(grads, state, params) ->
    (new_params, new_state, stats))."""
    lr_fn = cosine_schedule(cfg)

    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def init(params):
        zeros = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=mdt), params)
        if cfg.optimizer == "adamw":
            return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())
        if cfg.optimizer == "momentum":
            return OptState(jnp.zeros((), jnp.int32), zeros(), None)
        return OptState(jnp.zeros((), jnp.int32), None, None)

    def update(grads, state: OptState, params):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_fn(state.step)
        step = state.step + 1

        if cfg.optimizer == "adamw":
            t = step.astype(jnp.float32)
            bc1 = 1.0 - cfg.beta1 ** t
            bc2 = 1.0 - cfg.beta2 ** t

            def upd(p, g, m, v):
                g32 = g.astype(jnp.float32)
                m32 = m.astype(jnp.float32)
                v32 = v.astype(jnp.float32)
                m32 = cfg.beta1 * m32 + (1.0 - cfg.beta1) * g32
                v32 = cfg.beta2 * v32 + (1.0 - cfg.beta2) * jnp.square(g32)
                u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
                m, v = m32.astype(mdt), v32.astype(mdt)
                if jnp.issubdtype(p.dtype, jnp.floating):
                    u = u + cfg.weight_decay * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

            out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
            new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                           is_leaf=lambda o: isinstance(o, tuple))
            new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                           is_leaf=lambda o: isinstance(o, tuple))
            new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                           is_leaf=lambda o: isinstance(o, tuple))
            return new_p, OptState(step, new_m, new_v), {"lr": lr, "gnorm": gnorm}

        if cfg.optimizer == "momentum":
            def upd(p, g, m):
                m = 0.9 * m + g.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
            out = jax.tree_util.tree_map(upd, params, grads, state.m)
            new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                           is_leaf=lambda o: isinstance(o, tuple))
            new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                           is_leaf=lambda o: isinstance(o, tuple))
            return new_p, OptState(step, new_m, None), {"lr": lr, "gnorm": gnorm}

        # plain SGD
        new_p = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, OptState(step, None, None), {"lr": lr, "gnorm": gnorm}

    return init, update
