"""Train-step factory: next-token cross entropy + optimizer update.

The same step is used by the single-host examples and by the multi-pod
dry-run (where it is jitted with in/out shardings over the production mesh).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.transformer import forward
from repro.train.optimizer import make_optimizer


def cross_entropy(logits, targets, mask=None, label_smoothing: float = 0.0):
    """logits: [B, S, V]; targets: [B, S] int. Mean NLL over valid tokens."""
    V = logits.shape[-1]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if label_smoothing > 0.0:
        smooth = -jnp.mean(logp, axis=-1)
        nll = (1.0 - label_smoothing) * nll + label_smoothing * smooth
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(model_cfg: ModelConfig, train_cfg: TrainConfig, *,
                 moe_impl: str = "dense", q_chunk: int = 512,
                 kv_chunk: int = 1024, unroll: int = 1):
    def loss_fn(params, batch: Dict[str, Any]):
        logits, aux = forward(model_cfg, params, batch, moe_impl=moe_impl,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              remat=train_cfg.remat, unroll=unroll)
        tokens = batch["tokens"]
        targets = batch.get("labels")
        if targets is None:
            logits_s = logits[:, :-1]
            targets = tokens[:, 1:]
            mask = batch.get("loss_mask")
            mask = mask[:, 1:] if mask is not None else None
        else:
            logits_s = logits
            mask = batch.get("loss_mask")
        ce = cross_entropy(logits_s, targets, mask,
                           train_cfg.label_smoothing)
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig, *,
                    moe_impl: str = "dense", q_chunk: int = 512,
                    kv_chunk: int = 1024, unroll: int = 1):
    """Returns (init_state_fn(params) -> opt_state, train_step fn).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    loss_fn = make_loss_fn(model_cfg, train_cfg, moe_impl=moe_impl,
                           q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll)
    opt_init, opt_update = make_optimizer(train_cfg)

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, stats = opt_update(grads, opt_state, params)
        metrics = {"loss": loss, **parts, **stats}
        return params, opt_state, metrics

    return opt_init, train_step
