"""Table I: the divergence→accuracy link that motivates Algorithm 4.

Fix the global model and the selections in all clusters but one; from the
probe cluster, try each member device in turn and measure the next-round
accuracy ON THAT CLUSTER'S MAJORITY CLASS. The paper's claim: the device
with the largest weight divergence yields the highest accuracy.
"""
from __future__ import annotations

import copy
import time

import numpy as np

from benchmarks.common import emit, fl_experiment


def run(quick: bool = False):
    dataset = "fashion"
    clients = 30
    exp = fl_experiment(dataset=dataset, clients=clients, test_samples=800,
                        test_seed=90_001, partition_seed=3,
                        selection="kmeans_random")
    fed = exp.fed
    # warm up: a few kmeans_random rounds (paper protocol)
    exp.run(rounds=2 if quick else 5)

    # probe cluster = the largest one
    probe = int(np.argmax([len(c) for c in exp.clusters]))
    members = exp.clusters[probe]
    majority = np.bincount(fed.majority[members]).argmax()
    others = [c for i, c in enumerate(exp.clusters) if i != probe and len(c)]
    rng = np.random.default_rng(0)
    fixed = np.array([rng.choice(c) for c in others])
    div = exp.divergences()

    t0 = time.time()
    snapshot = (exp.global_params, exp.client_params)
    results = []
    for dev in members:
        exp.global_params, exp.client_params = snapshot
        idx = np.concatenate([fixed, [dev]])
        new_params = exp.train_clients(idx)
        exp.aggregate(new_params, idx)
        _, per_class = exp.evaluate()
        results.append((float(div[dev]), float(per_class[majority])))
    us = (time.time() - t0) * 1e6 / max(len(members), 1)

    results_sorted = sorted(results)
    best_by_div = max(results)[1]              # accuracy of highest-divergence
    accs = [a for _, a in results]
    rank_of_best = int(np.argsort([a for _, a in results])[-1])
    emit("table1/cluster_size", us, str(len(members)))
    emit("table1/acc_of_max_divergence_device", us, f"{best_by_div:.3f}")
    emit("table1/max_acc_over_devices", us, f"{max(accs):.3f}")
    emit("table1/mean_acc_over_devices", us, f"{np.mean(accs):.3f}")
    # Spearman-ish check: correlation divergence vs accuracy
    if len(results) > 2:
        d = np.array([x for x, _ in results])
        a = np.array(accs)
        corr = np.corrcoef(np.argsort(np.argsort(d)),
                           np.argsort(np.argsort(a)))[0, 1]
        emit("table1/rank_correlation", us, f"{corr:.3f}")


if __name__ == "__main__":
    run()
