"""Fig. 5: SAO vs FEDL(λ) vs equal-bandwidth under one global iteration —
per-device energy feasibility, total energy, and completion time.

Paper protocol: S=10 devices, B=20 MHz, p=23 dBm, per-device energy budgets
randomly drawn. λ is swept: a small λ that satisfies every budget, the λ
matching SAO's total energy, and λ→∞ (delay-only).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.wireless import sample_fleet, fleet_arrays
from repro.core.sao import solve_sao, kkt_residuals
from repro.core.baselines import (equal_bandwidth, fedl_lambda,
                                  tune_fedl_lambda_for_constraints)

B = 20.0


def run(quick: bool = False):
    fleet = sample_fleet(100, seed=0)
    arr = fleet_arrays(fleet.select(np.arange(10)))

    sol, us = time_fn(lambda: solve_sao(arr, B).T.block_until_ready())
    sao = solve_sao(arr, B)
    r = kkt_residuals(sao, arr, B)
    E_sao = float(jnp.sum(r["e"]))
    emit("fig5/sao_T_ms", us, f"{float(sao.T)*1e3:.1f}")
    emit("fig5/sao_E_mJ", us, f"{E_sao*1e3:.1f}")
    emit("fig5/sao_all_feasible", us,
         str(bool(jnp.max(-r['energy_slack']) < 1e-4)))

    lam_feas = tune_fedl_lambda_for_constraints(arr, B)
    for lam, tag in [(lam_feas, "feasible"), (4.58, "matchE"), (1000.0, "inf")]:
        res, us2 = time_fn(lambda l=lam: fedl_lambda(arr, B, l).T
                           .block_until_ready())
        fedl = fedl_lambda(arr, B, lam)
        n_violate = int(jnp.sum(fedl.e > arr["e_cons"] + 1e-6))
        emit(f"fig5/fedl_{tag}_T_ms", us2, f"{float(fedl.T)*1e3:.1f}")
        emit(f"fig5/fedl_{tag}_E_mJ", us2, f"{float(jnp.sum(fedl.e))*1e3:.1f}")
        emit(f"fig5/fedl_{tag}_violations", us2, str(n_violate))

    eq, us3 = time_fn(lambda: equal_bandwidth(arr, B).T.block_until_ready())
    eqr = equal_bandwidth(arr, B)
    emit("fig5/equal_T_ms", us3, f"{float(eqr.T)*1e3:.1f}")
    emit("fig5/equal_E_mJ", us3, f"{float(jnp.sum(eqr.e))*1e3:.1f}")

    # beyond-paper: the KKT-box-corrected SAO (DESIGN.md §Perf-sched)
    sao_bc = solve_sao(arr, B, box_correct=True)
    r_bc = kkt_residuals(sao_bc, arr, B)
    emit("fig5/sao_boxfix_T_ms", us, f"{float(sao_bc.T)*1e3:.1f}")
    emit("fig5/sao_boxfix_all_feasible", us,
         str(bool(jnp.max(-r_bc['energy_slack']) < 1e-4)))

    # headline claims of the figure
    fedl_f = fedl_lambda(arr, B, lam_feas)
    assert float(sao.T) <= float(eqr.T) * 1.02, "SAO must beat equal-bandwidth"
    emit("fig5/sao_vs_fedl_feasible_speedup", us,
         f"{float(fedl_f.T)/float(sao.T):.3f}")
    emit("fig5/sao_boxfix_vs_fedl_feasible_speedup", us,
         f"{float(fedl_f.T)/float(sao_bc.T):.3f}")


if __name__ == "__main__":
    run()
