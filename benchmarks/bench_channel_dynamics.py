"""Channel-dynamics CI gate: the coupled multi-cell program stays ONE scan.

The dynamic-interference path (`multicell-dynamic` + `gauss-markov`) is the
first place the cells of a seed interact INSIDE the traced program — the
easiest thing for a refactor to silently break is the "one scanned program,
no per-round host round-trips" property (e.g. by reintroducing a host loop
over rounds or cells). This bench proves it structurally, not by timing:

  * the whole multi-round (seeds × cells) cohort must go through EXACTLY
    ONE compiled-callable dispatch (``engine.run_rounds`` is wrapped with a
    counter), and
  * that dispatch runs under ``jax.transfer_guard_device_to_host
    ("disallow")`` (``CohortRunner.run(transfer_guard=True)``) — any
    mid-program device→host sync raises instead of silently serializing;

plus the usual rounds/sec measurement for the perf trajectory. Writes
``results/BENCH_channel.json`` (uploaded as a CI artifact); ``--smoke`` is
the per-PR gate with a NON-ZERO EXIT on a structural failure.

    PYTHONPATH=src:. python benchmarks/bench_channel_dynamics.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import emit, fl_spec
from repro.api import build_cohort, multicell_fleet_spec


def _workload(rounds: int):
    # 2 coupled cells × 2 seeds, correlated fading + dynamic interference:
    # the full new scenario family in one program
    return fl_spec(clients=10, rounds=rounds, samples_per_client=8,
                   train_samples=400, test_samples=100, local_iters=1,
                   batch_size=4, devices_per_round=4, num_clusters=4,
                   cohort=2, test_seed=90_000,
                   fleet=multicell_fleet_spec(2, channel="multicell-dynamic"))


def run(rounds: int = 6, out: str | None = None):
    spec = _workload(rounds)
    runner = build_cohort(spec)

    # count compiled-callable dispatches: the whole cohort must be ONE
    import repro.core.cohort as cohort_mod
    import repro.core.engine as engine_mod
    calls = {"n": 0}
    real_run_rounds = engine_mod.run_rounds

    def counting_run_rounds(*a, **kw):
        fn = real_run_rounds(*a, **kw)

        def counted(*fa, **fkw):
            calls["n"] += 1
            return fn(*fa, **fkw)

        return counted

    cohort_mod.run_rounds = counting_run_rounds
    try:
        # warmup (build + compile), then the guarded, counted run
        runner.run(transfer_guard=True)
        calls["n"] = 0
        t0 = time.perf_counter()
        ch = runner.run(reuse_experiments=True, transfer_guard=True)
        jax.block_until_ready(ch.accuracy)
        dt = time.perf_counter() - t0
    finally:
        cohort_mod.run_rounds = real_run_rounds

    lanes = len(ch.seeds)
    single_program = calls["n"] == 1
    inr_dynamic = (ch.inr is not None
                   and bool((ch.inr.std(axis=1) > 0).any()))
    rps = lanes * (rounds + 1) / dt

    payload = {
        "benchmark": "channel_dynamics",
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "workload": {"cells": 2, "cohort": 2, "rounds": rounds,
                     "clients_per_cell": 10,
                     "channel": "multicell-dynamic"},
        "single_scanned_program": single_program,
        "dispatches": calls["n"],
        "no_host_round_trips": True,       # transfer guard would have raised
        "inr_selection_driven": inr_dynamic,
        "cohort_rounds_per_sec": round(rps, 3),
    }
    emit("channel/dynamic2cell_rps", 1e6 / rps, f"{rps:.2f}")
    emit("channel/dispatches", 0.0, str(calls["n"]))
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_channel.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke(out: str | None = None) -> bool:
    """Per-PR CI gate: structural properties of the dynamic path."""
    payload = run(rounds=4, out=out)
    ok = True
    for key in ("single_scanned_program", "inr_selection_driven"):
        verdict = "ok" if payload[key] else "FAIL"
        print(f"smoke {key}: {payload[key]} ... {verdict}")
        ok &= bool(payload[key])
    print(json.dumps(payload, indent=1))
    return ok


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="structural gate: one scanned program, no host "
                         "round-trips, selection-driven inr (non-zero exit "
                         "on failure; the tier-1 CI step)")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(rounds=args.rounds, out=args.out)
