"""Framework-level kernel microbenchmarks (interpret-mode wall times are NOT
TPU perf — the derived column is the correctness gap vs the jnp oracle; the
TPU roofline lives in EXPERIMENTS.md §Roofline).

``--smoke`` is the per-PR CI gate: the quick workload, a printed summary,
``results/BENCH_kernels.json``, and a NON-ZERO EXIT when any kernel's
interpret-mode output drifts past its oracle tolerance — so a kernel
regression fails the tier-1 workflow instead of hiding in an artifact.

    PYTHONPATH=src:. python benchmarks/bench_kernels.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import warnings

# every kernel here runs with an explicit use_pallas=True as a deliberate
# interpret-mode validation — silence the dispatch guard's off-TPU warning
warnings.filterwarnings("ignore", message=".*interpret mode.*",
                        category=RuntimeWarning)
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref
from repro.kernels.flat_aggregate import flat_aggregate
from repro.kernels.pairwise_l2 import pairwise_l2
from repro.kernels.flash_attention import flash_attention

# interpret-mode-vs-oracle drift ceilings (fp32 shapes; the smoke gate).
# pairwise_l2's ceiling leaves real headroom: fp32 cancellation in the
# ‖x‖²+‖c‖²−2x·c expansion vs the naive oracle measures ~1e-3 at F=2240
# and shifts with XLA's matmul reduction order across versions/CPUs.
TOLERANCES = {
    "pairwise_l2_100x10x2240": 5e-3,
    "flat_aggregate_100x113744": 1e-4,
    "flash_attn": 1e-4,
    "ssd_scan": 1e-4,
}


def run(quick: bool = False):
    entries = []
    k = jax.random.PRNGKey(0)

    # pairwise_l2 at the paper's real scale: 100 clients × w_fc2 (2240)
    x = jax.random.normal(k, (100, 2240))
    c = jax.random.normal(jax.random.PRNGKey(1), (10, 2240))
    out, us = time_fn(lambda: pairwise_l2(x, c).block_until_ready(),
                      repeats=3)
    err = float(jnp.max(jnp.abs(out - ref.pairwise_l2_ref(x, c))))
    emit("kernels/pairwise_l2_100x10x2240", us, f"maxerr={err:.2e}")
    entries.append({"name": "pairwise_l2_100x10x2240", "us": us,
                    "maxerr": err})

    # flat_aggregate at the FL round's real scale: the [N, P] client plane
    # of the paper CNN (P = 113744), 100-client eq.-(4) reduction
    flat = jax.random.normal(k, (100, 113744))
    w = jax.random.uniform(jax.random.PRNGKey(2), (100,))
    out, us = time_fn(lambda: flat_aggregate(flat, w).block_until_ready(),
                      repeats=3)
    err = float(jnp.max(jnp.abs(out - ref.flat_aggregate_ref(flat, w))))
    emit("kernels/flat_aggregate_100x113744", us, f"maxerr={err:.2e}")
    entries.append({"name": "flat_aggregate_100x113744", "us": us,
                    "maxerr": err})

    s = 128 if quick else 256
    q = jax.random.normal(k, (1, 4, s, 64))
    kk = jax.random.normal(jax.random.PRNGKey(2), (1, 4, s, 64))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 4, s, 64))
    out, us = time_fn(lambda: flash_attention(q, kk, v, bq=128, bk=128)
                      .block_until_ready(), repeats=2)
    err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, kk, v))))
    emit(f"kernels/flash_attn_s{s}", us, f"maxerr={err:.2e}")
    entries.append({"name": "flash_attn", "us": us, "maxerr": err})

    B, S, H, P, N = 1, 256, 4, 32, 16
    xs = jax.random.normal(k, (B, S, H, P)) * 0.5
    a = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4), (B, S, H)))
    bm = jax.random.normal(jax.random.PRNGKey(5), (B, S, 1, N)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(6), (B, S, 1, N)) * 0.3
    (y, h), us = time_fn(lambda: jax.block_until_ready(
        ops.ssd(xs, a, bm, cm, chunk=64, use_pallas=True)), repeats=2)
    y_r, _ = ops.ssd(xs, a, bm, cm, use_pallas=False)
    err = float(jnp.max(jnp.abs(y - y_r)))
    emit(f"kernels/ssd_scan_s{S}", us, f"maxerr={err:.2e}")
    entries.append({"name": "ssd_scan", "us": us, "maxerr": err})
    return entries


def smoke(out: str | None = None) -> bool:
    """Quick run + kernel-vs-oracle drift gate; writes BENCH_kernels.json."""
    entries = run(quick=True)
    ok = True
    for e in entries:
        tol = TOLERANCES[e["name"]]
        verdict = "ok" if e["maxerr"] <= tol else "KERNEL DRIFT"
        print(f"smoke {e['name']}: maxerr={e['maxerr']:.2e} "
              f"(tol {tol:.0e}) ... {verdict}")
        ok &= e["maxerr"] <= tol
    payload = {"benchmark": "kernels", "mode": "interpret",
               "backend": jax.default_backend(),
               "note": ("interpret-mode wall times validate correctness, "
                        "not TPU perf; maxerr is vs the naive jnp oracle"),
               "kernels": entries}
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_kernels.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return ok


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="quick run + kernel-drift gate (non-zero exit on "
                         "oracle mismatch; the tier-1 CI step)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(quick=args.quick)
