"""Framework-level kernel microbenchmarks (interpret-mode wall times are NOT
TPU perf — the derived column is the correctness gap vs the jnp oracle; the
TPU roofline lives in EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref
from repro.kernels.pairwise_l2 import pairwise_l2
from repro.kernels.flash_attention import flash_attention


def run(quick: bool = False):
    k = jax.random.PRNGKey(0)
    # pairwise_l2 at the paper's real scale: 100 clients × w_fc2 (2240)
    x = jax.random.normal(k, (100, 2240))
    c = jax.random.normal(jax.random.PRNGKey(1), (10, 2240))
    out, us = time_fn(lambda: pairwise_l2(x, c).block_until_ready(),
                      repeats=3)
    err = float(jnp.max(jnp.abs(out - ref.pairwise_l2_ref(x, c))))
    emit("kernels/pairwise_l2_100x10x2240", us, f"maxerr={err:.2e}")

    s = 128 if quick else 256
    q = jax.random.normal(k, (1, 4, s, 64))
    kk = jax.random.normal(jax.random.PRNGKey(2), (1, 4, s, 64))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 4, s, 64))
    out, us = time_fn(lambda: flash_attention(q, kk, v, bq=128, bk=128)
                      .block_until_ready(), repeats=2)
    err = float(jnp.max(jnp.abs(out - ref.flash_attention_ref(q, kk, v))))
    emit(f"kernels/flash_attn_s{s}", us, f"maxerr={err:.2e}")

    B, S, H, P, N = 1, 256, 4, 32, 16
    xs = jax.random.normal(k, (B, S, H, P)) * 0.5
    a = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(4), (B, S, H)))
    bm = jax.random.normal(jax.random.PRNGKey(5), (B, S, 1, N)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(6), (B, S, 1, N)) * 0.3
    (y, h), us = time_fn(lambda: jax.block_until_ready(
        ops.ssd(xs, a, bm, cm, chunk=64, use_pallas=True)), repeats=2)
    y_r, _ = ops.ssd(xs, a, bm, cm, use_pallas=False)
    err = float(jnp.max(jnp.abs(y - y_r)))
    emit(f"kernels/ssd_scan_s{S}", us, f"maxerr={err:.2e}")


if __name__ == "__main__":
    run()
