"""Fig. 10/11 + Table III: convergence of the four device-selection methods
on non-iid data; rounds-to-target; improvement scores vs FedAvg compared
with Favor's published scores.

The multi-seed trials for each (σ, method) cell run on the
``CohortRunner`` — the whole seed sweep is ONE compiled vmapped program
(initial round + K-means + all rounds), with rounds-to-target computed
host-side from the returned accuracy curves. Stochastic selectors
(kmeans_random / random) draw from ``jax.random`` on the cohort engine, so
their per-seed trajectories differ from the pre-cohort host-loop runs
(divergence / icas are deterministic and bit-identical).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fl_spec
from repro.api import build_cohort

# Favor's improvement scores over FedAvg (paper Table III)
FAVOR_SCORES = {("mnist", 0.5): 0.228, ("mnist", 0.8): 0.157,
                ("mnist", "H"): 0.0,
                ("fashion", 0.5): 0.150, ("fashion", 0.8): 0.209,
                ("fashion", "H"): 0.388,
                ("cifar10", 0.5): 0.181, ("cifar10", 0.8): 0.232,
                ("cifar10", "H"): 0.340}


def run_method(dataset, sigma, method, *, clients, rounds, local_iters,
               seeds, target):
    """All trials of one (σ, method) cell as a single cohort program.

    Returns (final accuracies, rounds-to-target) per seed. Rounds-to-target
    is the first history index at or above ``target`` (index k = round k;
    the initial all-device round sits at index 0), else ``rounds + 1``.
    The reported accuracy is the accuracy AT the stop round — matching the
    legacy early-stopping loop's final history entry — not after all
    ``rounds`` (the cohort always runs them; the curve is just truncated).
    """
    spec = fl_spec(dataset=dataset, sigma=sigma, clients=clients,
                   local_iters=local_iters, test_seed=90_000,
                   selection=method, rounds=rounds, seed=seeds[0])
    ch = build_cohort(spec).run(seeds=seeds, rounds=rounds)
    accs, r2t = [], []
    for i in range(len(seeds)):
        hist = ch.history(i)
        hit = [k for k, a in enumerate(hist.accuracy) if a >= target]
        stop = hit[0] if hit else len(hist.accuracy) - 1
        accs.append(hist.accuracy[stop])
        r2t.append(hit[0] if hit else rounds + 1)
    return accs, r2t


def run(quick: bool = False):
    dataset = "fashion"
    sigmas = [0.8] if quick else [0.5, 0.8, "H"]
    methods = ["divergence", "kmeans_random", "random", "icas"]
    clients = 30
    rounds = 10 if quick else 22
    trials = 1 if quick else 2
    target = 0.60 if dataset == "fashion" else 0.55
    seeds = [t * 17 for t in range(trials)]

    for sigma in sigmas:
        stag = str(sigma)
        per_method = {}
        for method in methods:
            t0 = time.time()
            accs, r2t = run_method(dataset, sigma, method, clients=clients,
                                   rounds=rounds, local_iters=20,
                                   seeds=seeds, target=target)
            us = (time.time() - t0) * 1e6 / trials
            per_method[method] = (float(np.median(r2t)),
                                  float(np.mean(accs)))
            emit(f"fig10/{dataset}_s{stag}_{method}_final_acc", us,
                 f"{np.mean(accs):.3f}")
            emit(f"fig11/{dataset}_s{stag}_{method}_rounds_to_{target}", us,
                 f"{np.median(r2t):.1f}")
        # Table III: improvement score = R_fedavg/R_ours - 1 ... paper
        # defines score = R_eval/R_fedavg - 1 (negative is better); report
        # the positive speed-up form used in the text.
        r_our = per_method["divergence"][0]
        r_fed = per_method["random"][0]
        score = r_fed / max(r_our, 1e-9) - 1.0
        favor = FAVOR_SCORES.get((dataset, sigma))
        emit(f"table3/{dataset}_s{stag}_improvement_vs_fedavg", 0.0,
             f"{score:.3f}")
        if favor is not None:
            emit(f"table3/{dataset}_s{stag}_favor_published", 0.0,
                 f"{favor:.3f}")


if __name__ == "__main__":
    run()
