"""Fig. 10/11 + Table III: convergence of the four device-selection methods
on non-iid data; rounds-to-target; improvement scores vs FedAvg compared
with Favor's published scores.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fl_experiment

# Favor's improvement scores over FedAvg (paper Table III)
FAVOR_SCORES = {("mnist", 0.5): 0.228, ("mnist", 0.8): 0.157,
                ("mnist", "H"): 0.0,
                ("fashion", 0.5): 0.150, ("fashion", 0.8): 0.209,
                ("fashion", "H"): 0.388,
                ("cifar10", 0.5): 0.181, ("cifar10", 0.8): 0.232,
                ("cifar10", "H"): 0.340}


def run_one(dataset, sigma, method, *, clients, rounds, local_iters, seed,
            target):
    exp = fl_experiment(dataset=dataset, sigma=sigma, clients=clients,
                        local_iters=local_iters, seed=seed,
                        test_seed=90_000, selection=method, rounds=rounds,
                        target_accuracy=target)
    hist = exp.run(rounds=rounds, target_accuracy=target)
    rounds_to = hist.rounds_to_target
    if rounds_to is None:
        # first round whose accuracy reaches the target, else cap
        hit = [i for i, a in enumerate(hist.accuracy) if a >= target]
        rounds_to = hit[0] if hit else rounds + 1
    return hist, rounds_to


def run(quick: bool = False):
    dataset = "fashion"
    sigmas = [0.8] if quick else [0.5, 0.8, "H"]
    methods = ["divergence", "kmeans_random", "random", "icas"]
    clients = 30
    rounds = 10 if quick else 22
    trials = 1 if quick else 2
    target = 0.60 if dataset == "fashion" else 0.55

    for sigma in sigmas:
        stag = str(sigma)
        per_method = {}
        for method in methods:
            accs, r2t = [], []
            t0 = time.time()
            for trial in range(trials):
                hist, rt = run_one(dataset, sigma, method, clients=clients,
                                   rounds=rounds, local_iters=20,
                                   seed=trial * 17, target=target)
                accs.append(hist.accuracy[-1])
                r2t.append(rt)
            us = (time.time() - t0) * 1e6 / trials
            per_method[method] = (float(np.median(r2t)),
                                  float(np.mean(accs)))
            emit(f"fig10/{dataset}_s{stag}_{method}_final_acc", us,
                 f"{np.mean(accs):.3f}")
            emit(f"fig11/{dataset}_s{stag}_{method}_rounds_to_{target}", us,
                 f"{np.median(r2t):.1f}")
        # Table III: improvement score = R_fedavg/R_ours - 1 ... paper
        # defines score = R_eval/R_fedavg - 1 (negative is better); report
        # the positive speed-up form used in the text.
        r_our = per_method["divergence"][0]
        r_fed = per_method["random"][0]
        score = r_fed / max(r_our, 1e-9) - 1.0
        favor = FAVOR_SCORES.get((dataset, sigma))
        emit(f"table3/{dataset}_s{stag}_improvement_vs_fedavg", 0.0,
             f"{score:.3f}")
        if favor is not None:
            emit(f"table3/{dataset}_s{stag}_favor_published", 0.0,
                 f"{favor:.3f}")


if __name__ == "__main__":
    run()
