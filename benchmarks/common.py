"""Shared benchmark utilities. Every benchmark prints CSV rows:
``name,us_per_call,derived`` where ``derived`` is the paper-facing quantity
(a delay in ms, an ARI, a round count, ...)."""
from __future__ import annotations

import time
from contextlib import contextmanager


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0
    box["us"] = box["s"] * 1e6


def time_fn(fn, *args, repeats: int = 5, warmup: int = 1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6
