"""Shared benchmark utilities. Every benchmark prints CSV rows:
``name,us_per_call,derived`` where ``derived`` is the paper-facing quantity
(a delay in ms, an ARI, a round count, ...).

FL benchmarks declare their setup as an ``ExperimentSpec`` via
:func:`fl_experiment`, replacing the dataset/partition/fleet/config blocks
that used to be duplicated across every figure module.
"""
from __future__ import annotations

import time
from contextlib import contextmanager


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0
    box["us"] = box["s"] * 1e6


def time_fn(fn, *args, repeats: int = 5, warmup: int = 1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6


# ---------------------------------------------------------------------------
# Spec-API experiment construction (shared across FL figure modules)
# ---------------------------------------------------------------------------

# Every FL benchmark uses the paper's §VI protocol numbers unless it
# overrides them explicitly.
BENCH_DEFAULTS = dict(dataset="fashion", train_samples=2500, test_samples=600,
                      samples_per_client=96, sigma=0.8, local_iters=20,
                      learning_rate=0.08, num_clusters=10, devices_per_round=10,
                      data_seed=7, seed=0)


def fl_spec(**overrides):
    """An ``ExperimentSpec`` with the benchmark-suite defaults applied."""
    from repro.api import ExperimentSpec

    return ExperimentSpec(**{**BENCH_DEFAULTS, **overrides})


def fl_experiment(*, test_data=None, **overrides):
    """Build the benchmark experiment for ``overrides``; returns the
    ``FLExperiment`` (its ``.fed`` / ``.spec`` carry partition + spec)."""
    from repro.api import build_experiment

    return build_experiment(fl_spec(**overrides), test_data=test_data)
