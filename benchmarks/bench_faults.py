"""Fault-tolerance CI gate: injection accounting, kill/--resume, and
robust aggregation under a byzantine cohort.

Three structural gates, none timing-based:

  * **accounting** — a scanned run under ``outage:0.2,corrupt:0.1`` must
    stay finite end to end and charge the O(N) fault counters at the
    configured rate (a binomial-tolerance window around rate·S·rounds);
    an injector that silently stops firing, or fires on padding lanes,
    moves the total out of the window.
  * **kill_resume** — the acceptance run: ``fl_sim`` on the hardest
    route (paged store + fedbuff + churn + ``outage:0.1``), SIGKILLed
    mid-run after its first checkpoint commits, then ``--resume``d in a
    FRESH interpreter. The stitched history must equal the uninterrupted
    run's bit for bit — which exercises atomic snapshots, the LATEST
    pointer, and cross-process dataset determinism all at once.
  * **byzantine** — 10% of the fleet negates-and-amplifies (×50). Plain
    eq. (4) must visibly degrade below its own fault-free run;
    ``trimmed:0.2`` must hold the final accuracy within 2 points of ITS
    fault-free run (same estimator — the trim bias is not the attack).

Writes ``results/BENCH_faults.json`` (uploaded as a CI artifact);
``--smoke`` is the per-PR gate with a NON-ZERO EXIT on failure.

    PYTHONPATH=src:. python benchmarks/bench_faults.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import emit, fl_spec
from repro.api import build_experiment

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# gate 1: fault accounting on the scanned route
# ---------------------------------------------------------------------------

ACC_ROUNDS = 8
ACC_RATE = 1.0 - (1.0 - 0.2) * (1.0 - 0.1)   # P(drop or corrupt) per lane


def _accounting() -> dict:
    spec = fl_spec(clients=10, rounds=ACC_ROUNDS, samples_per_client=16,
                   train_samples=400, test_samples=100, local_iters=2,
                   batch_size=8, devices_per_round=10,
                   selection="divergence",
                   faults="outage:0.2,corrupt:0.1", quarantine_after=3)
    exp = build_experiment(spec)
    hist = exp.run(rounds=ACC_ROUNDS)
    total = float(exp.stats.faults.sum())
    # expectation from the ACTUAL dispatch counts (the initial clustering
    # round is fault-free by design, so it is excluded)
    lanes = sum(len(np.asarray(s)) for s in hist.selected[1:])
    mean = ACC_RATE * lanes
    sd = (mean * (1.0 - ACC_RATE)) ** 0.5
    lo, hi = mean - 4 * sd, mean + 4 * sd
    finite = bool(np.all(np.isfinite(np.asarray(hist.accuracy))))
    return {
        "fault_events": total,
        "expected_mean": round(mean, 2),
        "window": [round(lo, 2), round(hi, 2)],
        "history_finite": finite,
        "in_window": bool(lo <= total <= hi),
        "accounting_ok": bool(finite and lo <= total <= hi),
    }


# ---------------------------------------------------------------------------
# gate 2: mid-run SIGKILL + --resume, bit-identical
# ---------------------------------------------------------------------------

_SIM = ["--dataset", "fashion", "--clients", "10", "--per-round", "4",
        "--rounds", "6", "--local-iters", "2", "--selection", "divergence",
        "--store", "paged", "--async-buffer", "2", "--churn", "0.05:0.1",
        "--faults", "outage:0.1", "--checkpoint-every", "2"]


def _sim(extra, out):
    env = {**os.environ, "PYTHONPATH": "src"}
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.fl_sim", *extra, "--out", out],
        cwd=ROOT, env=env, capture_output=True, text=True)


def _kill_resume(tmp: str) -> dict:
    full_out = os.path.join(tmp, "full.jsonl")
    res_out = os.path.join(tmp, "resumed.jsonl")
    ck_full = os.path.join(tmp, "ck_full")
    ck_kill = os.path.join(tmp, "ck_kill")

    r = _sim([*_SIM, "--checkpoint-dir", ck_full], full_out)
    if r.returncode != 0:
        return {"resume_ok": False, "error": r.stderr[-800:]}

    # the killed run: SIGKILL as soon as the first snapshot COMMITS
    env = {**os.environ, "PYTHONPATH": "src"}
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.fl_sim", *_SIM,
         "--checkpoint-dir", ck_kill],
        cwd=ROOT, env=env, stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL)
    deadline = time.time() + 300
    while (proc.poll() is None and time.time() < deadline
           and not os.path.exists(os.path.join(ck_kill, "LATEST"))):
        time.sleep(0.2)
    killed = proc.poll() is None
    if killed:
        proc.send_signal(signal.SIGKILL)
    proc.wait()

    r = _sim(["--resume", ck_kill], res_out)
    if r.returncode != 0:
        return {"resume_ok": False, "killed_mid_run": killed,
                "error": r.stderr[-800:]}

    with open(full_out) as f:
        full = json.loads(f.read().splitlines()[-1])
    with open(res_out) as f:
        res = json.loads(f.read().splitlines()[-1])
    bitwise = (full["accuracy"] == res["accuracy"]
               and full["total_T_s"] == res["total_T_s"]
               and full["total_E_J"] == res["total_E_J"])
    return {
        "killed_mid_run": killed,
        "accuracy_full": [round(a, 4) for a in full["accuracy"]],
        "accuracy_resumed": [round(a, 4) for a in res["accuracy"]],
        "bitwise_identical": bool(bitwise),
        "resume_ok": bool(bitwise),
    }


# ---------------------------------------------------------------------------
# gate 3: byzantine cohort vs trimmed-mean defense
# ---------------------------------------------------------------------------

BYZ_ROUNDS = 8
# seed:5 puts exactly ONE of the 10 clients (10%) in the adversarial set
BYZ = "byzantine:0.1,byz_scale:50,seed:5"
TOL_POINTS = 0.02                   # "within 2 points of fault-free"
DEGRADE_POINTS = 0.05


def _final_acc(hist) -> float:
    return float(np.mean(hist.accuracy[-3:]))


def _byzantine(rounds: int = BYZ_ROUNDS) -> dict:
    """Each aggregator against its OWN fault-free run: the trimmed mean
    trades convergence speed for robustness (it discards 2·⌊f·k⌋ updates
    per coordinate even when none are adversarial), so the attack's
    effect is isolated by holding the estimator fixed."""
    # the default 10 clusters select ~10 clients a round: ⌊0.2·k⌋ >= 1,
    # so the single adversary actually lands in the trimmed tail (with a
    # 4-client selection t would be 0 and NOTHING would be trimmed)
    base = dict(clients=10, rounds=rounds, devices_per_round=10,
                selection="divergence")

    def acc(**kw):
        return _final_acc(build_experiment(fl_spec(**base, **kw)).run(
            rounds=rounds))

    a_plain = acc()
    a_plain_byz = acc(faults=BYZ)
    a_trim = acc(aggregator="trimmed:0.2")
    a_trim_byz = acc(faults=BYZ, aggregator="trimmed:0.2")
    return {
        "acc_fedavg_fault_free": round(a_plain, 4),
        "acc_fedavg_byzantine": round(a_plain_byz, 4),
        "acc_trimmed_fault_free": round(a_trim, 4),
        "acc_trimmed_byzantine": round(a_trim_byz, 4),
        "plain_degrades": bool(a_plain_byz <= a_plain - DEGRADE_POINTS),
        "trimmed_within_tol": bool(a_trim_byz >= a_trim - TOL_POINTS),
        "byzantine_ok": bool(a_plain_byz <= a_plain - DEGRADE_POINTS
                             and a_trim_byz >= a_trim - TOL_POINTS),
    }


# ---------------------------------------------------------------------------


def run(out: str | None = None) -> dict:
    import jax

    t0 = time.perf_counter()
    acc = _accounting()
    emit("faults/accounting", 0.0,
         f"{acc['fault_events']:.0f} in {acc['window']}")
    with tempfile.TemporaryDirectory() as tmp:
        kr = _kill_resume(tmp)
    emit("faults/kill_resume", 0.0, str(kr.get("bitwise_identical")))
    byz = _byzantine()
    emit("faults/byzantine", 0.0,
         f"fedavg={byz['acc_fedavg_fault_free']}->"
         f"{byz['acc_fedavg_byzantine']} "
         f"trimmed={byz['acc_trimmed_fault_free']}->"
         f"{byz['acc_trimmed_byzantine']}")

    payload = {
        "benchmark": "faults",
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "accounting": acc,
        "kill_resume": kr,
        "byzantine": byz,
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    out = out or os.path.join(ROOT, "results", "BENCH_faults.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke(out: str | None = None) -> bool:
    payload = run(out=out)
    ok = True
    for section, key in (("accounting", "accounting_ok"),
                         ("kill_resume", "resume_ok"),
                         ("byzantine", "byzantine_ok")):
        val = payload[section].get(key, False)
        print(f"smoke {section}.{key}: {val} ... "
              f"{'ok' if val else 'FAIL'}")
        ok &= bool(val)
    print(json.dumps(payload, indent=1))
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(out=args.out)


if __name__ == "__main__":
    main()
