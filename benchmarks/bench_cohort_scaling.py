"""Cohort-engine scaling: rounds/sec for the three execution tiers —

  python_loop : the legacy round-at-a-time host driver (per-round host
                syncs for selection, allocation, accuracy), timed over
                sequential seeds — what an 8-seed sweep of ``run()`` calls
                cost before the device-resident pipeline
  scanned     : the same experiment as ONE lax.scan program
                (``engine.run_rounds``; what ``FLExperiment.run`` now
                dispatches to for traceable strategy bundles)
  cohort      : 8 seeds vmapped over the scanned program (``CohortRunner``;
                shard_map'd across local devices when more than one exists)
                — one dispatch, one transfer for the whole sweep

at N = 50 / 100 devices, on an overhead-sensitive round shape (small local
compute, shared evaluation set) — the regime the device-resident pipeline
targets. Every tier executes identical math; compile/build time excluded
via warmup. ``speedup_cohort8_vs_sequential_runs`` is cohort rounds/sec
over sequential legacy ``run()`` calls (8 sequential runs amortize nothing
beyond the shared XLA cache, so their rounds/sec equals the sequential
measurement).

NOTE the absolute ratio is hardware-bound: on a single compute device the
cohort can only amortize host overhead (its per-seed-round cost stays
within ~1.1x of the single-seed scan), while on an M-core host with real
parallel devices the sharded cohort scales toward min(M, 8)x on top.

Writes ``results/BENCH_cohort.json`` (the perf-trajectory artifact the CI
workflow uploads) plus the usual CSV rows.

``--smoke`` is the per-PR CI gate: the quick workload, a printed summary,
and a NON-ZERO EXIT when the scanned path has regressed below
``SMOKE_MIN_SPEEDUP`` × the python loop — so a pipeline slowdown fails the
tier-1 workflow instead of hiding in an artifact. NOTE the flat parameter
plane (PR 5) roughly doubled the PYTHON loop's rounds/sec (its per-round
tree ops collapsed to fused row ops and its stores donate in place), so
on a single CPU device the two tiers now run neck and neck (~0.85-1.9×
depending on load) — the floor sits below that band to catch only a
genuine scanned-path collapse; absolute scanned rps is tracked in
``BENCH_flat.json``'s gate instead.

    PYTHONPATH=src:. python benchmarks/bench_cohort_scaling.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import emit, fl_spec
from repro.api import build_cohort, build_experiment

COHORT = 8
SMOKE_MIN_SPEEDUP = 0.6        # scanned/python rounds-per-sec floor (gate;
                               # see module docstring — the flat plane sped
                               # the python loop up to near-parity on CPU)


def _workload(clients: int, rounds: int):
    return fl_spec(clients=clients, rounds=rounds, samples_per_client=8,
                   train_samples=400, test_samples=100, local_iters=1,
                   batch_size=4, devices_per_round=10, num_clusters=10,
                   test_seed=90_000)


def bench_python_loop(spec, rounds: int, n_seeds: int = 2):
    """Legacy-loop rounds/sec (seeds timed sequentially, compile excluded)."""
    warm = build_experiment(spec.replace(seed=1234))
    warm.traceable = lambda *a, **k: False
    warm.run(rounds=2)                       # compile train/eval/SAO
    exps = [build_experiment(spec.replace(seed=s)) for s in range(n_seeds)]
    for e in exps:
        e.traceable = lambda *a, **k: False
    t0 = time.perf_counter()
    for e in exps:
        e.run(rounds=rounds)
    dt = time.perf_counter() - t0
    return n_seeds * (rounds + 1) / dt


def bench_scanned(spec, rounds: int):
    """Single-seed scanned-program rounds/sec (compile excluded)."""
    build_experiment(spec.replace(seed=1234)).run(rounds=rounds)   # compile
    exp = build_experiment(spec)
    t0 = time.perf_counter()
    exp.run(rounds=rounds)
    dt = time.perf_counter() - t0
    return (rounds + 1) / dt


def bench_cohort(spec, rounds: int):
    """8-seed cohort rounds/sec (compile + build excluded, best of 2)."""
    runner = build_cohort(spec.replace(cohort=COHORT))
    runner.run(rounds=rounds)                # build + compile
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        runner.run(rounds=rounds, reuse_experiments=True)
        best = min(best, time.perf_counter() - t0)
    return COHORT * (rounds + 1) / best


def run(quick: bool = False, out: str | None = None):
    rounds = 8 if quick else 15
    sizes = [50] if quick else [50, 100]
    return _run(rounds, sizes, quick, out)


def _run(rounds, sizes, quick, out):
    configs = []
    for clients in sizes:
        spec = _workload(clients, rounds)
        rps_py = bench_python_loop(spec, rounds)
        rps_scan = bench_scanned(spec, rounds)
        rps_cohort = bench_cohort(spec, rounds)
        cfg = {"clients": clients, "rounds": rounds, "cohort": COHORT,
               "python_loop_rps": round(rps_py, 3),
               "scanned_rps": round(rps_scan, 3),
               "cohort8_rps": round(rps_cohort, 3),
               "speedup_scanned_vs_python": round(rps_scan / rps_py, 2),
               "speedup_cohort8_vs_sequential_runs":
                   round(rps_cohort / rps_py, 2)}
        configs.append(cfg)
        emit(f"cohort/N{clients}_python_loop_rps", 1e6 / rps_py,
             f"{rps_py:.2f}")
        emit(f"cohort/N{clients}_scanned_rps", 1e6 / rps_scan,
             f"{rps_scan:.2f}")
        emit(f"cohort/N{clients}_cohort{COHORT}_rps", 1e6 / rps_cohort,
             f"{rps_cohort:.2f}")
        emit(f"cohort/N{clients}_speedup_vs_sequential", 0.0,
             f"{rps_cohort / rps_py:.2f}")

    payload = {"benchmark": "cohort_scaling", "quick": quick,
               "cohort": COHORT,
               "environment": {"devices": len(jax.devices()),
                               "backend": jax.default_backend(),
                               "cpu_count": os.cpu_count()},
               "note": ("single-device hosts only amortize host overhead; "
                        "multi-device hosts additionally shard the cohort "
                        "axis (see CohortRunner)"),
               "configs": configs}
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_cohort.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke(out: str | None = None) -> bool:
    """The per-PR CI gate: quick workload + regression check. Returns
    True when the scanned pipeline still clears the speedup floor."""
    payload = _run(rounds=8, sizes=[50], quick=True, out=out)
    ok = True
    for cfg in payload["configs"]:
        ratio = cfg["speedup_scanned_vs_python"]
        verdict = "ok" if ratio >= SMOKE_MIN_SPEEDUP else "REGRESSION"
        print(f"smoke N{cfg['clients']}: scanned/python = {ratio:.2f}x "
              f"(floor {SMOKE_MIN_SPEEDUP}x) ... {verdict}")
        ok &= ratio >= SMOKE_MIN_SPEEDUP
    print(json.dumps(payload["configs"], indent=1))
    return ok


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="quick run + scanned-vs-python regression gate "
                         "(non-zero exit on regression; the tier-1 CI step)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(quick=args.quick, out=args.out)
