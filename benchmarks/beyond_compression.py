"""Beyond-paper benchmark: uplink compression × allocator.

Couples update compression (int8 / top-k) into the paper's spectrum
allocator via z_n, with both the paper-faithful Algorithm 5 and the
KKT-box-corrected variant — demonstrating the analytic finding that the
paper's energy-tight rule is z-blind once devices clip at f_max, and
measuring the accuracy cost of each scheme in a real FL run.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, fl_experiment
from repro.core import sample_fleet, fleet_arrays
from repro.core.sao import solve_sao
from repro.core.compression import payload_mbit

SCHEMES = ["none", "int8", "topk:0.05"]


def run(quick: bool = False):
    # --- latency: scheme × allocator on the Fig.-5 fleet ---
    fleet = sample_fleet(100, seed=0).select(np.arange(10))
    n_par = 113_744
    for scheme in SCHEMES:
        z = payload_mbit(n_par, scheme)
        f2 = dataclasses.replace(fleet, z=np.full_like(fleet.z, z))
        arr = fleet_arrays(f2)
        t_p = float(solve_sao(arr, 20.0).T)
        t_b = float(solve_sao(arr, 20.0, box_correct=True).T)
        emit(f"compression/z_mbit_{scheme}", 0.0, f"{z:.3f}")
        emit(f"compression/paperSAO_T_ms_{scheme}", 0.0, f"{t_p*1e3:.1f}")
        emit(f"compression/boxSAO_T_ms_{scheme}", 0.0, f"{t_b*1e3:.1f}")

    # --- accuracy cost: short FL runs per scheme ---
    rounds = 6 if quick else 12
    for scheme in SCHEMES:
        t0 = time.time()
        exp = fl_experiment(clients=20, train_samples=2000, test_samples=500,
                            test_seed=90_003, partition_seed=3,
                            compressor=scheme, selection="divergence",
                            allocator={"name": "sao",
                                       "params": {"box_correct": True}},
                            rounds=rounds)
        hist = exp.run(rounds=rounds)
        us = (time.time() - t0) * 1e6
        emit(f"compression/final_acc_{scheme}", us,
             f"{hist.accuracy[-1]:.3f}")
        emit(f"compression/total_T_s_{scheme}", us, f"{hist.total_T:.2f}")


if __name__ == "__main__":
    run()
