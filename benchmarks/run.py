"""Benchmark harness — one module per paper table/figure (DESIGN.md §9).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig5,fig8_9]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback

os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")

MODULES = [
    ("fig5", "benchmarks.fig5_sao_vs_fedl"),
    ("fig6_7", "benchmarks.fig6_7_delay_sweeps"),
    ("fig8_9", "benchmarks.fig8_9_kmeans"),
    ("fig10_11", "benchmarks.fig10_11_convergence"),
    ("table1", "benchmarks.table1_divergence_accuracy"),
    ("fig13", "benchmarks.fig13_interplay"),
    ("fig14", "benchmarks.fig14_power_opt"),
    ("kernels", "benchmarks.bench_kernels"),
    ("sao_scaling", "benchmarks.bench_sao_scaling"),
    ("compression", "benchmarks.beyond_compression"),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps/rounds (CI-friendly)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for key, module in MODULES:
        if only and key not in only:
            continue
        print(f"# --- {module} ---", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(module).run(quick=args.quick)
            print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(key)
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)
    print("# all benchmarks OK")


if __name__ == "__main__":
    main()
