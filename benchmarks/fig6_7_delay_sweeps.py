"""Fig. 6 (delay vs average transmit power) and Fig. 7 (delay vs energy
constraint): SAO vs Baseline 1 (equal bandwidth) vs Baseline 2 (FEDL, λ tuned
to just meet the tightest budget)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.wireless import sample_fleet, fleet_arrays, dbm_to_watt
from repro.core.sao import solve_sao
from repro.core.baselines import (equal_bandwidth, fedl_lambda,
                                  tune_fedl_lambda_for_constraints)

B = 20.0


def _methods(arr):
    sao = solve_sao(arr, B)
    eq = equal_bandwidth(arr, B)
    lam = tune_fedl_lambda_for_constraints(arr, B, iters=12)
    fedl = fedl_lambda(arr, B, lam)
    return {"sao": float(sao.T), "equal": float(eq.T), "fedl": float(fedl.T)}


def run(quick: bool = False):
    # --- Fig. 6: e_cons = 30 mJ fixed, p swept (paper: e=30mJ, p 10..23 dBm)
    powers = [12.0, 16.0, 20.0, 23.0] if quick else \
        [10.0, 12.0, 14.0, 16.0, 18.0, 20.0, 22.0, 23.0]
    base = sample_fleet(100, seed=0, e_cons_range=(40e-3, 40e-3))
    idx = np.arange(10)
    for p_dbm in powers:
        fleet = base.with_power(dbm_to_watt(p_dbm)).select(idx)
        arr = fleet_arrays(fleet)
        res, us = time_fn(lambda: _methods(arr), repeats=1, warmup=0)
        for m, T in res.items():
            emit(f"fig6/{m}_T_ms_at_{p_dbm:g}dBm", us, f"{T*1e3:.1f}")

    # --- Fig. 7: p = 23 dBm fixed, e_cons swept 30..50 mJ
    econs = [30e-3, 40e-3, 50e-3] if quick else \
        [30e-3, 35e-3, 40e-3, 45e-3, 50e-3]
    for e in econs:
        fleet = sample_fleet(100, seed=0, e_cons_range=(e, e)).select(idx)
        arr = fleet_arrays(fleet)
        res, us = time_fn(lambda: _methods(arr), repeats=1, warmup=0)
        for m, T in res.items():
            emit(f"fig7/{m}_T_ms_at_{e*1e3:g}mJ", us, f"{T*1e3:.1f}")
        # paper claim: SAO lowest at every point (when feasible)
        if res["sao"] <= min(res["equal"], res["fedl"]) * 1.02:
            emit(f"fig7/sao_lowest_at_{e*1e3:g}mJ", us, "True")


if __name__ == "__main__":
    run()
