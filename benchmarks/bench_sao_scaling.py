"""Scheduler-throughput benchmark: SAO solve latency vs selected-set size
(the paper's complexity claim: O(S²·log³(1/ε)) — ours vectorizes the inner
per-device bisections, so wall time grows sub-quadratically)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.wireless import sample_fleet, fleet_arrays
from repro.core.sao import solve_sao


def run(quick: bool = False):
    fleet = sample_fleet(200, seed=0)
    sizes = [10, 50] if quick else [5, 10, 25, 50, 100, 200]
    for S in sizes:
        arr = fleet_arrays(fleet.select(np.arange(S)))
        T, us = time_fn(lambda: float(solve_sao(arr, 20.0 * S / 10.0).T),
                        repeats=3, warmup=1)
        emit(f"sao_scaling/S{S}", us, f"T={T*1e3:.1f}ms")


if __name__ == "__main__":
    run()
