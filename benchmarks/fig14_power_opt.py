"""Fig. 14 / Appendix E: delay vs shared transmit power + Algorithm 6's
binary-search optimum."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.wireless import sample_fleet, fleet_arrays, dbm_to_watt
from repro.core.sao import solve_sao
from repro.core.power import optimal_transmit_power

B = 20.0


def run(quick: bool = False):
    # a tight-energy regime makes the delay-vs-power curve non-monotone
    fleet = sample_fleet(100, seed=0, e_cons_range=(35e-3, 35e-3)) \
        .select(np.arange(10))
    grid = [10, 14, 18, 21, 23] if quick else list(range(10, 24))
    best = (1e9, None)
    for p_dbm in grid:
        arr = fleet_arrays(fleet.with_power(dbm_to_watt(p_dbm)))
        T, us = time_fn(lambda: float(solve_sao(arr, B).T), repeats=1,
                        warmup=0)
        emit(f"fig14/grid_T_ms_at_{p_dbm}dBm", us, f"{T*1e3:.2f}")
        best = min(best, (T, p_dbm))

    res, us = time_fn(lambda: optimal_transmit_power(fleet, B), repeats=1,
                      warmup=0)
    emit("fig14/alg6_p_star_dbm", us, f"{res.p_star_dbm:.2f}")
    emit("fig14/alg6_T_star_ms", us, f"{res.T_star*1e3:.2f}")
    emit("fig14/grid_best_p_dbm", us, f"{best[1]}")
    emit("fig14/alg6_within_grid_best", us,
         str(res.T_star <= best[0] * 1.05))


if __name__ == "__main__":
    run()
