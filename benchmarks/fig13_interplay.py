"""Fig. 13: interplay between SAO and device selection — sweep S (selected
devices per round) and report accuracy, total delay T, total energy E."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fl_experiment


def run(quick: bool = False):
    dataset = "fashion"
    clients = 30
    rounds = 8 if quick else 20
    # S must be a multiple of the cluster count (one-per-cluster selection);
    # S == clients means no selection (the paper's S=100 point)
    sweep = [10, 30] if quick else [10, 20, 30]

    for S in sweep:
        t0 = time.time()
        exp = fl_experiment(dataset=dataset, clients=clients,
                            test_seed=90_002, partition_seed=3,
                            devices_per_round=S,
                            selected_per_cluster=max(S // 10, 1),
                            selection="divergence", rounds=rounds)
        hist = exp.run(rounds=rounds)
        us = (time.time() - t0) * 1e6
        emit(f"fig13/S{S}_final_acc", us, f"{hist.accuracy[-1]:.3f}")
        emit(f"fig13/S{S}_total_T_s", us, f"{hist.total_T:.2f}")
        emit(f"fig13/S{S}_total_E_J", us, f"{hist.total_E:.2f}")


if __name__ == "__main__":
    run()
