"""Fig. 13: interplay between SAO and device selection — sweep S (selected
devices per round) and report accuracy, total delay T, total energy E."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import FLConfig
from repro.configs.paper_cnn import CNN_CONFIGS
from repro.core import FLExperiment, sample_fleet
from repro.data import make_dataset, partition_bias


def run(quick: bool = False):
    dataset = "fashion"
    clients = 30
    rounds = 8 if quick else 20
    ds = make_dataset(dataset, 2500, seed=7)
    test = make_dataset(dataset, 600, seed=90_002)
    # S must be a multiple of the cluster count (one-per-cluster selection);
    # S == clients means no selection (the paper's S=100 point)
    sweep = [10, 30] if quick else [10, 20, 30]

    for S in sweep:
        t0 = time.time()
        fed = partition_bias(ds, clients, 96, 0.8, seed=3)
        fleet = sample_fleet(clients, seed=0)
        s_per_cluster = max(S // 10, 1)
        fl = FLConfig(num_devices=clients, devices_per_round=S,
                      local_iters=20, num_clusters=10,
                      selected_per_cluster=s_per_cluster, learning_rate=0.08)
        exp = FLExperiment(CNN_CONFIGS[dataset], fed, test.images,
                           test.labels, fleet, fl, seed=0)
        hist = exp.run("divergence", rounds=rounds)
        us = (time.time() - t0) * 1e6
        emit(f"fig13/S{S}_final_acc", us, f"{hist.accuracy[-1]:.3f}")
        emit(f"fig13/S{S}_total_T_s", us, f"{hist.total_T:.2f}")
        emit(f"fig13/S{S}_total_E_J", us, f"{hist.total_E:.2f}")


if __name__ == "__main__":
    run()
