"""Per-phase breakdown of the scanned FL round on the flat parameter plane,
plus end-to-end rounds/sec vs the recorded PR-4 scanned baseline.

Phases are timed as standalone jitted ops on the real experiment state
(the same ops the traced program composes):

  train      : vmapped local SGD of the selected clients
  eval       : test-set forward + accuracy
  divergence : ‖w_n − w_g‖ over the [N, P] plane (ops.client_divergence)
  aggregate  : eq.-(4) masked weighted row-reduction (ops.flat_aggregate)
  scatter    : donated row store into the [N, P] plane
  features   : K-means feature column slice (zero-copy)
  sao        : one Alg.-5 spectrum solve for the selected set

End-to-end rounds/sec runs the full scanned program (``FLExperiment.run``
on the traceable bundle) on the clients=100 workload of
``bench_cohort_scaling`` and compares against that benchmark's RECORDED
``results/BENCH_cohort.json`` scanned_rps — the PR-4 perf artifact. Writes
``results/BENCH_flat.json``.

``--smoke`` is the per-PR CI gate: a NON-ZERO EXIT when the flat-plane
pipeline drops below ``SMOKE_MIN_RATIO`` × the recorded baseline — so a
hot-path regression fails the tier-1 workflow instead of hiding in an
artifact. (The floor is deliberately below 1.0: the recorded baseline and
the CI runner differ in load; the tracked headline is ``speedup_vs_
recorded_baseline`` in the artifact.)

    PYTHONPATH=src:. python benchmarks/bench_round_breakdown.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fl_spec
from repro.api import build_experiment
from repro.core.sao import solve_sao
from repro.core.wireless import fleet_arrays
from repro.kernels import ops

CLIENTS = 100
ROUNDS = 15
SMOKE_MIN_RATIO = 0.9          # new rps / recorded PR-4 scanned rps (gate)
# PR-4's recorded scanned_rps for this exact workload (BENCH_cohort.json at
# the PR-4 commit) — the fallback when the artifact is missing or was
# overwritten by a --quick cohort run that dropped the clients=100 entry.
PR4_SCANNED_RPS_FALLBACK = 11.491


def _workload():
    """bench_cohort_scaling's clients=100 workload, verbatim."""
    return fl_spec(clients=CLIENTS, rounds=ROUNDS, samples_per_client=8,
                   train_samples=400, test_samples=100, local_iters=1,
                   batch_size=4, devices_per_round=10, num_clusters=10,
                   test_seed=90_000)


def _best_ms(fn, repeats: int = 10):
    fn()                                     # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def phase_timings(exp) -> dict:
    """Time each round phase as its standalone jitted op (best-of-N)."""
    spec_cols = exp.engine.flat_spec
    S = exp.fl.devices_per_round
    idx = jnp.arange(S)
    keys = jax.random.split(jax.random.PRNGKey(0), S)
    gvec = jnp.asarray(np.asarray(exp.client_params[0]))
    rows = exp.client_params[:S]
    w = exp._sizes[:S]
    arr = fleet_arrays(exp.fleet.select(np.arange(S)))

    train = exp.engine.train_clients
    ev = exp.engine.evaluate
    div = jax.jit(lambda f, g: ops.client_divergence(f, g))
    agg = jax.jit(lambda r, ww: ops.flat_aggregate(r, ww))
    feat = jax.jit(lambda f: f[:, spec_cols.columns("w_fc2")] * 1.0)
    # the production store path: DONATED in-place scatter — probe it on a
    # private copy of the plane (donation consumes the buffer each call,
    # so the copy threads through the timing loop)
    scatter = jax.jit(lambda buf, i, r: buf.at[i].set(r),
                      donate_argnums=(0,))
    scatter_buf = [jnp.array(exp.client_params)]

    def scatter_once():
        scatter_buf[0] = scatter(scatter_buf[0], idx, rows)
        scatter_buf[0].block_until_ready()

    out = {}
    out["train_ms"] = _best_ms(lambda: jax.block_until_ready(
        train(exp.global_params, exp._images[idx], exp._labels[idx], keys)))
    out["eval_ms"] = _best_ms(lambda: jax.block_until_ready(
        ev(exp.global_params, exp.test_images, exp.test_labels)))
    out["divergence_ms"] = _best_ms(lambda: div(
        exp.client_params, gvec).block_until_ready())
    out["aggregate_ms"] = _best_ms(lambda: agg(rows, w).block_until_ready())
    out["scatter_ms"] = _best_ms(scatter_once)
    out["features_ms"] = _best_ms(lambda: feat(
        exp.client_params).block_until_ready())
    out["sao_ms"] = _best_ms(lambda: solve_sao(arr, exp.B).T
                             .block_until_ready())
    return out


def scanned_rps(spec, repeats: int = 3) -> float:
    """End-to-end scanned-program rounds/sec (compile excluded, best-of-N)."""
    build_experiment(spec.replace(seed=1234)).run(rounds=ROUNDS)  # compile
    exp = build_experiment(spec)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        exp.run(rounds=ROUNDS)
        best = min(best, time.perf_counter() - t0)
    return (ROUNDS + 1) / best


def recorded_baseline() -> tuple[float, str]:
    """PR-4's scanned_rps for the clients=100 workload, from the recorded
    BENCH_cohort.json artifact (fallback: the pinned PR-4 number)."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_cohort.json")
    try:
        with open(path) as f:
            payload = json.load(f)
        # only trust a FULL-run artifact for this exact workload — a
        # --quick/--smoke cohort run overwrites the file with clients=50
        # rounds=8 numbers, and must not silently become the baseline
        # (makes the gate independent of CI step ordering)
        if payload.get("quick") is False:
            for cfg in payload.get("configs", []):
                if (cfg.get("clients") == CLIENTS
                        and cfg.get("rounds") == ROUNDS
                        and "scanned_rps" in cfg):
                    return (float(cfg["scanned_rps"]),
                            "results/BENCH_cohort.json")
    except (OSError, ValueError):
        pass
    return PR4_SCANNED_RPS_FALLBACK, "pinned PR-4 fallback"


def run(out: str | None = None):
    spec = _workload()
    exp = build_experiment(spec)
    exp.run(rounds=2)                        # warm state for phase probes
    phases = phase_timings(exp)
    rps = scanned_rps(spec)
    baseline, source = recorded_baseline()
    speedup = rps / baseline

    for name, ms in phases.items():
        emit(f"flat/{name}", ms * 1e3, f"{ms:.2f}ms")
    emit(f"flat/N{CLIENTS}_scanned_rps", 1e6 / rps, f"{rps:.2f}")
    emit(f"flat/N{CLIENTS}_speedup_vs_pr4_scanned", 0.0, f"{speedup:.2f}")

    payload = {
        "benchmark": "round_breakdown", "clients": CLIENTS, "rounds": ROUNDS,
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "phases_ms": {k: round(v, 3) for k, v in phases.items()},
        "rounds_per_sec": round(rps, 3),
        "baseline_scanned_rps": baseline,
        "baseline_source": source,
        "speedup_vs_recorded_baseline": round(speedup, 2),
        "note": ("phases are standalone jitted ops on real state; "
                 "aggregation and divergence are each ONE fused op over "
                 "the [N, P] flat plane (ops.flat_aggregate / "
                 "ops.client_divergence) — no per-leaf tree_map remains "
                 "in the traced round body"),
    }
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_flat.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke(out: str | None = None) -> bool:
    payload = run(out=out)
    ratio = payload["rounds_per_sec"] / payload["baseline_scanned_rps"]
    if ratio < SMOKE_MIN_RATIO:
        # absolute rps vs a recorded number is load-sensitive on shared
        # runners (±40% observed between minutes) — re-measure once with
        # more repeats before declaring a regression
        print(f"smoke N{CLIENTS}: {ratio:.2f}x below floor, re-measuring...")
        rps = scanned_rps(_workload(), repeats=6)
        payload["rounds_per_sec"] = round(max(rps, payload["rounds_per_sec"]),
                                          3)
        payload["speedup_vs_recorded_baseline"] = round(
            payload["rounds_per_sec"] / payload["baseline_scanned_rps"], 2)
        path = out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "BENCH_flat.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        ratio = payload["speedup_vs_recorded_baseline"]
    verdict = "ok" if ratio >= SMOKE_MIN_RATIO else "REGRESSION"
    print(f"smoke N{CLIENTS}: flat/scanned vs recorded PR-4 baseline = "
          f"{ratio:.2f}x (floor {SMOKE_MIN_RATIO}x) ... {verdict}")
    print(json.dumps(payload["phases_ms"], indent=1))
    return ratio >= SMOKE_MIN_RATIO


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="regression gate vs the recorded PR-4 scanned "
                         "baseline (non-zero exit; the tier-1 CI step)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(out=args.out)
