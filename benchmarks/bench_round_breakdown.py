"""Per-phase breakdown of the scanned FL round on the flat parameter plane,
plus end-to-end rounds/sec vs the recorded PR-4 scanned baseline.

Phases are timed as standalone jitted ops on the real experiment state
(the same ops the traced program composes):

  train      : vmapped local SGD of the selected clients
  eval       : test-set forward + accuracy
  divergence : ‖w_n − w_g‖ over the [N, P] plane (ops.client_divergence)
  aggregate  : eq.-(4) masked weighted row-reduction (ops.flat_aggregate)
  scatter    : donated row store into the [N, P] plane
  features   : K-means feature column slice (zero-copy)
  sao        : one Alg.-5 spectrum solve for the selected set

End-to-end rounds/sec runs the full scanned program (``FLExperiment.run``
on the traceable bundle) on the clients=100 workload of
``bench_cohort_scaling`` and compares against that benchmark's RECORDED
``results/BENCH_cohort.json`` scanned_rps — the PR-4 perf artifact. Writes
``results/BENCH_flat.json``.

``--smoke`` is the per-PR CI gate: a NON-ZERO EXIT when the flat-plane
pipeline drops below ``SMOKE_MIN_RATIO`` × the recorded baseline — so a
hot-path regression fails the tier-1 workflow instead of hiding in an
artifact. (The floor is deliberately below 1.0: the recorded baseline and
the CI runner differ in load; the tracked headline is ``speedup_vs_
recorded_baseline`` in the artifact.)

``--n-scaling`` sweeps the fleet size on the micro CNN workload and
writes ``results/BENCH_scale.json``: per-round wall time of the PAGED
active/cold store at N ∈ {1e3, 1e4, 1e5} (selection sweep timed
separately — it is the one O(N) step the design keeps), against the dense
plane's O(N·P) divergence sweep at N ∈ {1e3, 1e4}. With ``--smoke`` it
gates: paged rest-of-round at N=1e5 within ``SCALE_MAX_RATIO``× of
N=1e4 (flat in N), dense divergence growing ≥ ``DENSE_MIN_RATIO``× per
10× N (~linear — the cost the paged store removes). ``--million`` adds a
N=1e6 end-to-end paged run to the sweep.

    PYTHONPATH=src:. python benchmarks/bench_round_breakdown.py [--smoke]
    PYTHONPATH=src:. python benchmarks/bench_round_breakdown.py \
        --n-scaling [--smoke] [--million]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fl_spec
from repro.api import build_experiment
from repro.core.sao import solve_sao
from repro.core.wireless import fleet_arrays
from repro.kernels import ops

CLIENTS = 100
ROUNDS = 15
SMOKE_MIN_RATIO = 0.9          # new rps / recorded PR-4 scanned rps (gate)
# PR-4's recorded scanned_rps for this exact workload (BENCH_cohort.json at
# the PR-4 commit) — the fallback when the artifact is missing or was
# overwritten by a --quick cohort run that dropped the clients=100 entry.
PR4_SCANNED_RPS_FALLBACK = 11.491


def _workload():
    """bench_cohort_scaling's clients=100 workload, verbatim."""
    return fl_spec(clients=CLIENTS, rounds=ROUNDS, samples_per_client=8,
                   train_samples=400, test_samples=100, local_iters=1,
                   batch_size=4, devices_per_round=10, num_clusters=10,
                   test_seed=90_000)


def _best_ms(fn, repeats: int = 10):
    fn()                                     # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def phase_timings(exp) -> dict:
    """Time each round phase as its standalone jitted op (best-of-N)."""
    spec_cols = exp.engine.flat_spec
    S = exp.fl.devices_per_round
    idx = jnp.arange(S)
    keys = jax.random.split(jax.random.PRNGKey(0), S)
    gvec = jnp.asarray(np.asarray(exp.client_params[0]))
    rows = exp.client_params[:S]
    w = exp._sizes[:S]
    arr = fleet_arrays(exp.fleet.select(np.arange(S)))

    train = exp.engine.train_clients
    ev = exp.engine.evaluate
    div = jax.jit(lambda f, g: ops.client_divergence(f, g))
    agg = jax.jit(lambda r, ww: ops.flat_aggregate(r, ww))
    feat = jax.jit(lambda f: f[:, spec_cols.columns("w_fc2")] * 1.0)
    # the production store path: DONATED in-place scatter — probe it on a
    # private copy of the plane (donation consumes the buffer each call,
    # so the copy threads through the timing loop)
    scatter = jax.jit(lambda buf, i, r: buf.at[i].set(r),
                      donate_argnums=(0,))
    scatter_buf = [jnp.array(exp.client_params)]

    def scatter_once():
        scatter_buf[0] = scatter(scatter_buf[0], idx, rows)
        scatter_buf[0].block_until_ready()

    out = {}
    out["train_ms"] = _best_ms(lambda: jax.block_until_ready(
        train(exp.global_params, exp._images[idx], exp._labels[idx], keys)))
    out["eval_ms"] = _best_ms(lambda: jax.block_until_ready(
        ev(exp.global_params, exp.test_images, exp.test_labels)))
    out["divergence_ms"] = _best_ms(lambda: div(
        exp.client_params, gvec).block_until_ready())
    out["aggregate_ms"] = _best_ms(lambda: agg(rows, w).block_until_ready())
    out["scatter_ms"] = _best_ms(scatter_once)
    out["features_ms"] = _best_ms(lambda: feat(
        exp.client_params).block_until_ready())
    out["sao_ms"] = _best_ms(lambda: solve_sao(arr, exp.B).T
                             .block_until_ready())
    return out


def scanned_rps(spec, repeats: int = 3) -> float:
    """End-to-end scanned-program rounds/sec (compile excluded, best-of-N)."""
    build_experiment(spec.replace(seed=1234)).run(rounds=ROUNDS)  # compile
    exp = build_experiment(spec)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        exp.run(rounds=ROUNDS)
        best = min(best, time.perf_counter() - t0)
    return (ROUNDS + 1) / best


def recorded_baseline() -> tuple[float, str]:
    """PR-4's scanned_rps for the clients=100 workload, from the recorded
    BENCH_cohort.json artifact (fallback: the pinned PR-4 number)."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "BENCH_cohort.json")
    try:
        with open(path) as f:
            payload = json.load(f)
        # only trust a FULL-run artifact for this exact workload — a
        # --quick/--smoke cohort run overwrites the file with clients=50
        # rounds=8 numbers, and must not silently become the baseline
        # (makes the gate independent of CI step ordering)
        if payload.get("quick") is False:
            for cfg in payload.get("configs", []):
                if (cfg.get("clients") == CLIENTS
                        and cfg.get("rounds") == ROUNDS
                        and "scanned_rps" in cfg):
                    return (float(cfg["scanned_rps"]),
                            "results/BENCH_cohort.json")
    except (OSError, ValueError):
        pass
    return PR4_SCANNED_RPS_FALLBACK, "pinned PR-4 fallback"


def run(out: str | None = None):
    spec = _workload()
    exp = build_experiment(spec)
    exp.run(rounds=2)                        # warm state for phase probes
    phases = phase_timings(exp)
    rps = scanned_rps(spec)
    baseline, source = recorded_baseline()
    speedup = rps / baseline

    for name, ms in phases.items():
        emit(f"flat/{name}", ms * 1e3, f"{ms:.2f}ms")
    emit(f"flat/N{CLIENTS}_scanned_rps", 1e6 / rps, f"{rps:.2f}")
    emit(f"flat/N{CLIENTS}_speedup_vs_pr4_scanned", 0.0, f"{speedup:.2f}")

    payload = {
        "benchmark": "round_breakdown", "clients": CLIENTS, "rounds": ROUNDS,
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "phases_ms": {k: round(v, 3) for k, v in phases.items()},
        "rounds_per_sec": round(rps, 3),
        "baseline_scanned_rps": baseline,
        "baseline_source": source,
        "speedup_vs_recorded_baseline": round(speedup, 2),
        "note": ("phases are standalone jitted ops on real state; "
                 "aggregation and divergence are each ONE fused op over "
                 "the [N, P] flat plane (ops.flat_aggregate / "
                 "ops.client_divergence) — no per-leaf tree_map remains "
                 "in the traced round body"),
    }
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_flat.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


# ---------------------------------------------------------------------------
# --n-scaling: paged active/cold store vs the dense plane across fleet sizes
# ---------------------------------------------------------------------------

SCALE_PAGED_NS = (1_000, 10_000, 100_000)
SCALE_DENSE_NS = (1_000, 10_000)       # 1e5 dense = a 2.4 GB plane; skipped
SCALE_ROUNDS = 4                       # timed rounds per N (min taken)
SCALE_MAX_RATIO = 1.5                  # paged rest-of-round t(1e5)/t(1e4)
DENSE_MIN_RATIO = 3.0                  # dense divergence t(1e4)/t(1e3) floor


def _scale_spec(n: int, store: str):
    """The N-scaling workload: micro CNN (P ≈ 6k), cluster-free random
    selection (no all-device Alg.-2 round), tiny local work — so per-round
    time is dominated by the store machinery being measured."""
    return fl_spec(dataset="micro", clients=n, samples_per_client=8,
                   train_samples=512, test_samples=128, local_iters=1,
                   batch_size=4, devices_per_round=16, num_clusters=10,
                   selection="random", store=store, test_seed=91_000)


def _paged_point(n: int) -> dict:
    """One paged sweep point: per-round wall time with the O(N) selection
    sweep measured separately (it is the one deliberate O(N) step; the
    gate applies to the rest of the round)."""
    exp = build_experiment(_scale_spec(n, "paged"))
    exp.round("random")                          # compile + warm the store
    sel_ms = _best_ms(lambda: exp.select("random"), repeats=3)
    best = float("inf")
    for _ in range(SCALE_ROUNDS):
        t0 = time.perf_counter()
        exp.round("random")
        best = min(best, time.perf_counter() - t0)
    round_ms = best * 1e3
    return {"clients": n, "round_ms": round(round_ms, 3),
            "select_ms": round(sel_ms, 3),
            "rest_ms": round(max(round_ms - sel_ms, 0.0), 3),
            "store_mb": round(exp.store.nbytes / 2**20, 2),
            "lazy_data": bool(getattr(exp.fed, "lazy", False))}


def _dense_point(n: int) -> dict:
    """One dense probe point: the O(N·P) divergence sweep over the full
    plane — the per-round cost the paged store replaces with the O(N)
    stats table."""
    exp = build_experiment(_scale_spec(n, "dense"))
    gvec = jnp.asarray(np.asarray(exp.client_params[0]))
    div = jax.jit(lambda f, g: ops.client_divergence(f, g))
    div_ms = _best_ms(lambda: div(exp.client_params, gvec)
                      .block_until_ready(), repeats=5)
    return {"clients": n, "divergence_ms": round(div_ms, 3),
            "plane_mb": round(exp.store.nbytes / 2**20, 2)}


def run_n_scaling(out: str | None = None, million: bool = False) -> dict:
    paged_ns = SCALE_PAGED_NS + ((1_000_000,) if million else ())
    paged = []
    for n in paged_ns:
        p = _paged_point(n)
        paged.append(p)
        emit(f"scale/paged_N{n}_round", p["round_ms"] * 1e3,
             f"{p['round_ms']:.1f}ms (select {p['select_ms']:.1f}ms)")
    dense = []
    for n in SCALE_DENSE_NS:
        d = _dense_point(n)
        dense.append(d)
        emit(f"scale/dense_N{n}_divergence", d["divergence_ms"] * 1e3,
             f"{d['divergence_ms']:.2f}ms")

    by_n = {p["clients"]: p for p in paged}
    paged_ratio = (by_n[100_000]["rest_ms"]
                   / max(by_n[10_000]["rest_ms"], 1e-9))
    dense_ratio = (dense[-1]["divergence_ms"]
                   / max(dense[0]["divergence_ms"], 1e-9))
    payload = {
        "benchmark": "n_scaling",
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "paged": paged,
        "dense": dense,
        "paged_rest_ratio_1e5_over_1e4": round(paged_ratio, 2),
        "dense_divergence_ratio_1e4_over_1e3": round(dense_ratio, 2),
        "note": ("paged rest_ms = round_ms - select_ms: per-round cost "
                 "excluding the O(N) selection sweep, flat in N by "
                 "design (active [K, P] plane + O(N) stats table); dense "
                 "divergence_ms is the O(N*P) full-plane reduction the "
                 "paged store replaces"),
    }
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_scale.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke_n_scaling(out: str | None = None, million: bool = False) -> bool:
    payload = run_n_scaling(out=out, million=million)
    paged_ratio = payload["paged_rest_ratio_1e5_over_1e4"]
    if paged_ratio > SCALE_MAX_RATIO:
        # host-loop timings on shared runners are load-sensitive —
        # re-measure the two gated points once before failing
        print(f"scale smoke: paged ratio {paged_ratio:.2f} above ceiling, "
              "re-measuring...")
        pts = {n: _paged_point(n) for n in (10_000, 100_000)}
        paged_ratio = min(paged_ratio,
                          pts[100_000]["rest_ms"]
                          / max(pts[10_000]["rest_ms"], 1e-9))
        payload["paged_rest_ratio_1e5_over_1e4"] = round(paged_ratio, 2)
        path = out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "BENCH_scale.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
    dense_ratio = payload["dense_divergence_ratio_1e4_over_1e3"]
    ok_paged = paged_ratio <= SCALE_MAX_RATIO
    ok_dense = dense_ratio >= DENSE_MIN_RATIO
    print(f"scale smoke: paged rest-of-round 1e5/1e4 = {paged_ratio:.2f}x "
          f"(ceiling {SCALE_MAX_RATIO}x) ... "
          f"{'ok' if ok_paged else 'REGRESSION'}")
    print(f"scale smoke: dense divergence 1e4/1e3 = {dense_ratio:.2f}x "
          f"(floor {DENSE_MIN_RATIO}x, ~linear) ... "
          f"{'ok' if ok_dense else 'NOT LINEAR?'}")
    return ok_paged and ok_dense


def smoke(out: str | None = None) -> bool:
    payload = run(out=out)
    ratio = payload["rounds_per_sec"] / payload["baseline_scanned_rps"]
    if ratio < SMOKE_MIN_RATIO:
        # absolute rps vs a recorded number is load-sensitive on shared
        # runners (±40% observed between minutes) — re-measure once with
        # more repeats before declaring a regression
        print(f"smoke N{CLIENTS}: {ratio:.2f}x below floor, re-measuring...")
        rps = scanned_rps(_workload(), repeats=6)
        payload["rounds_per_sec"] = round(max(rps, payload["rounds_per_sec"]),
                                          3)
        payload["speedup_vs_recorded_baseline"] = round(
            payload["rounds_per_sec"] / payload["baseline_scanned_rps"], 2)
        path = out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "BENCH_flat.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        ratio = payload["speedup_vs_recorded_baseline"]
    verdict = "ok" if ratio >= SMOKE_MIN_RATIO else "REGRESSION"
    print(f"smoke N{CLIENTS}: flat/scanned vs recorded PR-4 baseline = "
          f"{ratio:.2f}x (floor {SMOKE_MIN_RATIO}x) ... {verdict}")
    print(json.dumps(payload["phases_ms"], indent=1))
    return ratio >= SMOKE_MIN_RATIO


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="regression gate vs the recorded PR-4 scanned "
                         "baseline (non-zero exit; the tier-1 CI step)")
    ap.add_argument("--n-scaling", action="store_true",
                    help="sweep fleet size: paged per-round time vs the "
                         "dense plane's O(N*P) sweep; writes "
                         "results/BENCH_scale.json")
    ap.add_argument("--million", action="store_true",
                    help="with --n-scaling: add a N=1e6 paged point")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.n_scaling:
        if args.smoke:
            sys.exit(0 if smoke_n_scaling(out=args.out,
                                          million=args.million) else 1)
        run_n_scaling(out=args.out, million=args.million)
        sys.exit(0)
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(out=args.out)
