"""Federated LM benchmark: the LoRA-adapter transformer workload on the
flat parameter plane, dispatched as ONE scanned program.

``--smoke`` is the per-PR CI gate. It:

  * runs the tinyllama smoke workload through ``CohortRunner`` with
    ``transfer_guard=True`` — the whole multi-round federated run is a
    SINGLE device dispatch of the same ``lax.scan`` traced program the CNN
    uses (any mid-run device→host sync raises instead of serializing);
  * asserts upload pricing scales with P_adapter, not P_base: the fleet's
    payload ``z`` must equal ``P_adapter * 32 / 1e6`` Mbit and sit far
    below a P_base-priced payload (the LoRA economics the subsystem
    exists for);
  * records tokens/sec and per-phase ms to ``results/BENCH_lm.json``.

    PYTHONPATH=src:. python benchmarks/bench_lm.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.api import ExperimentSpec, build_cohort, build_experiment
from repro.models.lm import adapter_num_params, base_params
from repro.utils.trees import tree_num_params

CLIENTS = 12
ROUNDS = 6
LOCAL_ITERS = 4
BATCH = 4
DIALECTS = 4


def _spec(model: str = "tinyllama") -> ExperimentSpec:
    return ExperimentSpec(
        model=model, clients=CLIENTS, train_samples=CLIENTS * 16,
        test_samples=48, samples_per_client=16, sigma=0.8, rounds=ROUNDS,
        devices_per_round=DIALECTS, num_clusters=DIALECTS,
        local_iters=LOCAL_ITERS, batch_size=BATCH, learning_rate=0.1,
        selection="divergence", allocator="sao", seed=0, test_seed=92_000)


def _best_ms(fn, repeats: int = 5):
    fn()                                     # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def phase_timings(exp) -> dict:
    """train / eval as the standalone jitted ops the traced program
    composes (the LM-specific phases; the plane ops are workload-agnostic
    and benchmarked by bench_round_breakdown)."""
    S = exp.fl.devices_per_round
    idx = np.arange(S)
    keys = jax.random.split(jax.random.PRNGKey(0), S)
    out = {}
    out["train_ms"] = _best_ms(lambda: jax.block_until_ready(
        exp.engine.train_clients(exp.global_params, exp._images[idx],
                                 exp._labels[idx], keys)))
    out["eval_ms"] = _best_ms(lambda: jax.block_until_ready(
        exp.engine.evaluate(exp.global_params, exp.test_images,
                            exp.test_labels)))
    return out


def run(out: str | None = None, model: str = "tinyllama") -> dict:
    spec = _spec(model)
    exp = build_experiment(spec)
    model_cfg = exp.model_cfg
    p_adapter = adapter_num_params(model_cfg)
    p_base = tree_num_params(base_params(model_cfg))
    seq_len = model_cfg.seq_len

    # ---- upload pricing: z rides P_adapter, never P_base --------------
    z = float(exp.fleet.z[0])
    z_adapter = p_adapter * 32 / 1e6
    z_base = p_base * 32 / 1e6
    assert np.allclose(exp.fleet.z, z_adapter), (
        f"fleet z={z} Mbit != P_adapter*32/1e6={z_adapter} Mbit")
    assert z < z_base / 10, (
        f"adapter payload {z} Mbit not well below base {z_base} Mbit")

    # ---- one transfer-guarded scanned dispatch ------------------------
    assert exp.traceable(), "LM strategy bundle must be fully traceable"
    runner = build_cohort(spec.replace(cohort=1))
    runner.run(transfer_guard=True)          # compile
    t0 = time.perf_counter()
    ch = runner.run(reuse_experiments=True, transfer_guard=True)
    wall = time.perf_counter() - t0
    # tokens processed by local training across the scanned run (the init
    # round trains ALL clients; each scan round trains the selected S)
    steps = (CLIENTS + ROUNDS * DIALECTS) * LOCAL_ITERS
    tokens = steps * BATCH * seq_len
    tok_per_sec = tokens / wall

    phases = phase_timings(exp)

    emit(f"lm/{model}_tokens_per_sec", 1e6 / max(tok_per_sec, 1e-9),
         f"{tok_per_sec:.0f}")
    for name, ms in phases.items():
        emit(f"lm/{model}_{name}", ms * 1e3, f"{ms:.2f}ms")
    emit(f"lm/{model}_z_mbit", 0.0, f"{z:.4f}")

    payload = {
        "benchmark": "federated_lm", "model": model, "clients": CLIENTS,
        "rounds": ROUNDS, "local_iters": LOCAL_ITERS, "batch": BATCH,
        "seq_len": seq_len,
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "p_adapter": int(p_adapter), "p_base": int(p_base),
        "upload_z_mbit": round(z, 6),
        "upload_z_base_mbit": round(z_base, 3),
        "scanned_wall_s": round(wall, 3),
        "tokens_per_sec": round(tok_per_sec, 1),
        "phases_ms": {k: round(v, 3) for k, v in phases.items()},
        "final_accuracy": float(np.asarray(ch.accuracy)[0, -1]),
        "note": ("whole run = ONE transfer-guarded dispatch of the same "
                 "scanned round program as the CNN; per-client state is a "
                 "[P_adapter] LoRA row, the frozen base never enters the "
                 "plane or the uplink"),
    }
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_lm.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke(out: str | None = None) -> bool:
    payload = run(out=out)
    ok = (payload["p_adapter"] * 20 < payload["p_base"]
          and payload["tokens_per_sec"] > 0
          and np.isfinite(payload["final_accuracy"]))
    print(f"lm smoke: P_adapter={payload['p_adapter']} vs "
          f"P_base={payload['p_base']} "
          f"(z={payload['upload_z_mbit']} Mbit, base would be "
          f"{payload['upload_z_base_mbit']} Mbit); "
          f"{payload['tokens_per_sec']:.0f} tok/s ... "
          f"{'ok' if ok else 'REGRESSION'}")
    return ok


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: transfer-guarded single-dispatch LM run "
                         "+ P_adapter upload-pricing assertions")
    ap.add_argument("--model", default="tinyllama",
                    choices=["tinyllama", "mamba2-130m"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(out=args.out, model=args.model)
