"""Fig. 8 (K-means training time per feature layer) and Fig. 9 (ARI per
feature layer × non-iid level σ).

Reproduces the paper's §IV-B finding: the last FC layer's weights (w_fc2)
give near-best ARI at a fraction of the all-weights training cost.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import BENCH_DEFAULTS, emit, fl_experiment, time_fn
from repro.core.clustering import (kmeans_fit,
                                   adjusted_rand_index)
from repro.data import make_dataset

LAYERS = ["w_c1", "b_c1", "w_c2", "b_c2", "w_fc1", "b_fc1", "w_fc2", "b_fc2",
          "all"]


def _trained_clients(dataset: str, sigma, *, clients: int, local_iters: int,
                     seed: int = 0):
    # eval set is a train slice here: clustering quality needs no held-out
    # data (same sample count + seed as the spec -> identical dataset)
    ds = make_dataset(dataset, BENCH_DEFAULTS["train_samples"], seed=seed)
    exp = fl_experiment(dataset=dataset, sigma=sigma, clients=clients,
                        local_iters=local_iters, seed=seed, data_seed=seed,
                        test_data=(ds.images[:100], ds.labels[:100]))
    idx = np.arange(clients)
    new_params = exp.train_clients(idx)
    exp.store_clients(new_params, idx)
    return exp, exp.fed


def run(quick: bool = False):
    clients = 30 if quick else 60
    sigmas = [0.8] if quick else [0.5, 0.8, "H"]
    dataset = "fashion"

    for sigma in sigmas:
        exp, fed = _trained_clients(dataset, sigma, clients=clients,
                                    local_iters=40, seed=0)
        stag = str(sigma)
        for layer in LAYERS:
            feats = exp.client_features(layer)
            key = jax.random.PRNGKey(0)

            def fit():
                c, l, i = kmeans_fit(key, feats, 10)
                return l.block_until_ready()

            labels, us = time_fn(fit, repeats=2, warmup=1)
            ari = adjusted_rand_index(np.asarray(labels), fed.majority)
            emit(f"fig8/kmeans_time_{layer}_dim{feats.shape[1]}", us,
                 f"{us/1e3:.2f}ms")
            emit(f"fig9/ari_{layer}_sigma{stag}", us, f"{ari:.3f}")

        # the paper's headline: w_fc2 ≈ best ARI, much cheaper than 'all'
        f_fc2 = exp.client_features("w_fc2")
        f_all = exp.client_features("all")
        emit(f"fig8/dim_reduction_sigma{stag}", 0.0,
             f"{f_all.shape[1]/f_fc2.shape[1]:.0f}x")


if __name__ == "__main__":
    run()
