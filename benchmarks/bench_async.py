"""Buffered-async CI gate: the tick loop stays ONE scanned program.

The async engine replaces the round barrier with a virtual-time tick loop
(``repro.core.async_engine``) — the easiest thing for a refactor to
silently break is the "rounds are events, yet still one compiled
``lax.scan``" property (e.g. by reintroducing a host loop over ticks or a
mid-tick device→host sync for the buffer decision). This bench proves it
structurally, not by timing:

  * the whole multi-tick cohort must go through EXACTLY ONE
    compiled-callable dispatch (``engine.run_rounds`` wrapped with a
    counter), and
  * that dispatch runs under ``jax.transfer_guard_device_to_host
    ("disallow")`` (``CohortRunner.run(transfer_guard=True)``) — any
    mid-program sync raises instead of silently serializing;
  * staleness sanity: with the buffer smaller than the padded selection
    (M < K) stragglers must age, so the mean fired-age trace is positive;

plus the usual ticks/sec measurement for the perf trajectory. Writes
``results/BENCH_async.json`` (uploaded as a CI artifact); ``--smoke`` is
the per-PR gate with a NON-ZERO EXIT on a structural failure.

``--n-scaling`` sweeps the fleet size over the PAGED buffered-async
composition (``FLExperiment._run_async_paged``) at N ∈ {1e3, 1e4, 1e5}
and writes ``results/BENCH_async_scale.json``: per-tick wall time with
the O(N) scheduler portion (the ``sched`` + ``plan`` jitted pieces —
churn, selection, completion pricing, the fire plan) timed separately,
so the gate applies to the rest of the tick (O(k_max·P) train +
O(M·P) fire + store staging), which must stay flat in N. With
``--smoke`` it gates rest-of-tick t(1e5)/t(1e4) ≤ ``SCALE_MAX_RATIO``;
``--million`` adds an end-to-end N=1e6 point — the issue's acceptance
run: a million-client fleet ticking in O(k_max·P + M·P) device memory.

    PYTHONPATH=src:. python benchmarks/bench_async.py [--smoke]
    PYTHONPATH=src:. python benchmarks/bench_async.py \
        --n-scaling [--smoke] [--million]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import emit, fl_spec
from repro.api import build_cohort, build_experiment


def _workload(rounds: int):
    # buffer M=3 < padded selection 6: every tick leaves stragglers in
    # flight (staleness must grow), with mild churn flipping the fleet
    return fl_spec(clients=10, rounds=rounds, samples_per_client=8,
                   train_samples=400, test_samples=100, local_iters=1,
                   batch_size=4, devices_per_round=6, num_clusters=4,
                   cohort=2, test_seed=91_000,
                   aggregator="fedbuff:3:0.5",
                   churn_leave=0.05, churn_join=0.2)


def run(rounds: int = 6, out: str | None = None):
    spec = _workload(rounds)
    runner = build_cohort(spec)

    # count compiled-callable dispatches: the whole cohort must be ONE
    import repro.core.cohort as cohort_mod
    import repro.core.engine as engine_mod
    calls = {"n": 0}
    real_run_rounds = engine_mod.run_rounds

    def counting_run_rounds(*a, **kw):
        fn = real_run_rounds(*a, **kw)

        def counted(*fa, **fkw):
            calls["n"] += 1
            return fn(*fa, **fkw)

        return counted

    cohort_mod.run_rounds = counting_run_rounds
    try:
        # warmup (build + compile), then the guarded, counted run
        runner.run(transfer_guard=True)
        calls["n"] = 0
        t0 = time.perf_counter()
        ch = runner.run(reuse_experiments=True, transfer_guard=True)
        jax.block_until_ready(ch.accuracy)
        dt = time.perf_counter() - t0
    finally:
        cohort_mod.run_rounds = real_run_rounds

    lanes = len(ch.seeds)
    single_program = calls["n"] == 1
    mean_staleness = float(ch.staleness.mean())
    staleness_positive = bool(ch.staleness.max() > 0)
    buffer_bounded = bool((ch.participation <= 3).all())
    rps = lanes * (rounds + 1) / dt

    payload = {
        "benchmark": "async_engine",
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "workload": {"cohort": 2, "rounds": rounds, "clients": 10,
                     "aggregator": "fedbuff:3:0.5",
                     "churn": [0.05, 0.2]},
        "single_scanned_program": single_program,
        "dispatches": calls["n"],
        "no_host_round_trips": True,       # transfer guard would have raised
        "staleness_positive": staleness_positive,
        "buffer_bounded": buffer_bounded,
        "mean_staleness": round(mean_staleness, 4),
        "mean_participation": round(float(ch.participation.mean()), 4),
        "mean_active": round(float(ch.active.mean()), 4),
        "cohort_ticks_per_sec": round(rps, 3),
    }
    emit("async/fedbuff_tps", 1e6 / rps, f"{rps:.2f}")
    emit("async/dispatches", 0.0, str(calls["n"]))
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_async.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke(out: str | None = None) -> bool:
    """Per-PR CI gate: structural properties of the buffered-async path."""
    payload = run(rounds=4, out=out)
    ok = True
    for key in ("single_scanned_program", "staleness_positive",
                "buffer_bounded"):
        verdict = "ok" if payload[key] else "FAIL"
        print(f"smoke {key}: {payload[key]} ... {verdict}")
        ok &= bool(payload[key])
    print(json.dumps(payload, indent=1))
    return ok


# ---------------------------------------------------------------------------
# --n-scaling: the paged buffered-async composition across fleet sizes
# ---------------------------------------------------------------------------

SCALE_NS = (1_000, 10_000, 100_000)
SCALE_TICKS = 4                        # timed ticks per N (min taken)
SCALE_MAX_RATIO = 1.5                  # rest-of-tick t(1e5)/t(1e4) ceiling


def _scale_spec(n: int):
    """bench_round_breakdown's N-scaling workload (micro CNN, cluster-free
    random selection, tiny local work) routed onto the paged async engine:
    fedbuff:4 with the pad-16 selection keeps stragglers in flight every
    tick, so the fire path (staging gather + O(M·P) fold) is exercised."""
    return fl_spec(dataset="micro", clients=n, samples_per_client=8,
                   train_samples=512, test_samples=128, local_iters=1,
                   batch_size=4, devices_per_round=16, num_clusters=10,
                   selection="random", store="paged",
                   aggregator="fedbuff:4", test_seed=91_000)


def _best_ms(fn, repeats: int):
    fn()                                     # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _async_point(n: int) -> dict:
    """One sweep point: per-tick wall time of the full host composition,
    with the O(N) scheduler portion (sched + plan) probed standalone on
    the SAME cached jitted pieces the driver dispatches."""
    from repro.core.async_engine import _paged_async_step_program
    from repro.core.wireless import fleet_arrays

    exp = build_experiment(_scale_spec(n))
    exp.run(rounds=1, include_initial_round=False)    # compile + warm

    # several ticks per timed run: the per-RUN O(N) carry snapshot and
    # fold-back amortize away, so the number is the steady-state tick
    ticks_per_run = 4

    def ticks_once():
        exp.run(rounds=ticks_per_run, include_initial_round=False)

    tick_ms = _best_ms(ticks_once, repeats=SCALE_TICKS) / ticks_per_run

    prog = _paged_async_step_program(
        exp.engine.cfg, exp.selector, exp.allocator,
        exp.aggregator.registry_name,
        tuple(sorted(exp.aggregator.params().items())),
        exp.compressor, exp.traced_context(), exp.fl.feature_layer,
        exp.channel, exp.churn)
    arr = dict(fleet_arrays(exp.fleet))
    arr.pop("xgain", None)
    state = prog.init_channel(exp.traced_state(), arr)
    sizes = exp._sizes

    def sched_plan_once():
        s, arr_f, idx, mask = prog.sched(state, arr)
        _, _, _, cand, *_ = prog.plan(s, arr_f, idx, mask, sizes)
        jax.block_until_ready(cand)

    sched_ms = _best_ms(sched_plan_once, repeats=3)
    return {"clients": n, "tick_ms": round(tick_ms, 3),
            "sched_ms": round(sched_ms, 3),
            "rest_ms": round(max(tick_ms - sched_ms, 0.0), 3),
            "k_max": exp.k_max, "buffer": prog.M,
            "store_mb": round(exp.store.nbytes / 2**20, 2),
            "lazy_data": bool(getattr(exp.fed, "lazy", False))}


def run_n_scaling(out: str | None = None, million: bool = False) -> dict:
    points = []
    for n in SCALE_NS + ((1_000_000,) if million else ()):
        p = _async_point(n)
        points.append(p)
        emit(f"async/paged_N{n}_tick", p["tick_ms"] * 1e3,
             f"{p['tick_ms']:.1f}ms (sched {p['sched_ms']:.1f}ms)")
    by_n = {p["clients"]: p for p in points}
    ratio = by_n[100_000]["rest_ms"] / max(by_n[10_000]["rest_ms"], 1e-9)
    payload = {
        "benchmark": "async_n_scaling",
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "paged_async": points,
        "rest_ratio_1e5_over_1e4": round(ratio, 2),
        "note": ("rest_ms = tick_ms - sched_ms: per-tick cost excluding "
                 "the O(N) scheduler (churn/select/completion-pricing/"
                 "fire-plan jitted pieces), flat in N by design — the "
                 "tick's device state is the [k_max, P] staging plane + "
                 "[M, P] fire candidates + O(N) stats columns, never an "
                 "[N, P] plane"),
    }
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_async_scale.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke_n_scaling(out: str | None = None, million: bool = False) -> bool:
    payload = run_n_scaling(out=out, million=million)
    ratio = payload["rest_ratio_1e5_over_1e4"]
    if ratio > SCALE_MAX_RATIO:
        # host-loop timings on shared runners are load-sensitive —
        # re-measure the two gated points once before failing
        print(f"async scale smoke: rest ratio {ratio:.2f} above ceiling, "
              "re-measuring...")
        pts = {n: _async_point(n) for n in (10_000, 100_000)}
        ratio = min(ratio, pts[100_000]["rest_ms"]
                    / max(pts[10_000]["rest_ms"], 1e-9))
        payload["rest_ratio_1e5_over_1e4"] = round(ratio, 2)
        path = out or os.path.join(os.path.dirname(__file__), "..",
                                   "results", "BENCH_async_scale.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
    ok = ratio <= SCALE_MAX_RATIO
    print(f"async scale smoke: paged rest-of-tick 1e5/1e4 = {ratio:.2f}x "
          f"(ceiling {SCALE_MAX_RATIO}x) ... "
          f"{'ok' if ok else 'REGRESSION'}")
    return ok


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="structural gate: one scanned program, no host "
                         "round-trips, positive staleness under M < K "
                         "(non-zero exit on failure; the tier-1 CI step)")
    ap.add_argument("--n-scaling", action="store_true",
                    help="sweep fleet size over the paged buffered-async "
                         "composition; writes results/BENCH_async_scale"
                         ".json (with --smoke: gate rest-of-tick flat "
                         "in N)")
    ap.add_argument("--million", action="store_true",
                    help="with --n-scaling: add an end-to-end N=1e6 point")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.n_scaling:
        if args.smoke:
            sys.exit(0 if smoke_n_scaling(out=args.out,
                                          million=args.million) else 1)
        run_n_scaling(out=args.out, million=args.million)
        sys.exit(0)
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(rounds=args.rounds, out=args.out)
