"""Buffered-async CI gate: the tick loop stays ONE scanned program.

The async engine replaces the round barrier with a virtual-time tick loop
(``repro.core.async_engine``) — the easiest thing for a refactor to
silently break is the "rounds are events, yet still one compiled
``lax.scan``" property (e.g. by reintroducing a host loop over ticks or a
mid-tick device→host sync for the buffer decision). This bench proves it
structurally, not by timing:

  * the whole multi-tick cohort must go through EXACTLY ONE
    compiled-callable dispatch (``engine.run_rounds`` wrapped with a
    counter), and
  * that dispatch runs under ``jax.transfer_guard_device_to_host
    ("disallow")`` (``CohortRunner.run(transfer_guard=True)``) — any
    mid-program sync raises instead of silently serializing;
  * staleness sanity: with the buffer smaller than the padded selection
    (M < K) stragglers must age, so the mean fired-age trace is positive;

plus the usual ticks/sec measurement for the perf trajectory. Writes
``results/BENCH_async.json`` (uploaded as a CI artifact); ``--smoke`` is
the per-PR gate with a NON-ZERO EXIT on a structural failure.

    PYTHONPATH=src:. python benchmarks/bench_async.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import emit, fl_spec
from repro.api import build_cohort


def _workload(rounds: int):
    # buffer M=3 < padded selection 6: every tick leaves stragglers in
    # flight (staleness must grow), with mild churn flipping the fleet
    return fl_spec(clients=10, rounds=rounds, samples_per_client=8,
                   train_samples=400, test_samples=100, local_iters=1,
                   batch_size=4, devices_per_round=6, num_clusters=4,
                   cohort=2, test_seed=91_000,
                   aggregator="fedbuff:3:0.5",
                   churn_leave=0.05, churn_join=0.2)


def run(rounds: int = 6, out: str | None = None):
    spec = _workload(rounds)
    runner = build_cohort(spec)

    # count compiled-callable dispatches: the whole cohort must be ONE
    import repro.core.cohort as cohort_mod
    import repro.core.engine as engine_mod
    calls = {"n": 0}
    real_run_rounds = engine_mod.run_rounds

    def counting_run_rounds(*a, **kw):
        fn = real_run_rounds(*a, **kw)

        def counted(*fa, **fkw):
            calls["n"] += 1
            return fn(*fa, **fkw)

        return counted

    cohort_mod.run_rounds = counting_run_rounds
    try:
        # warmup (build + compile), then the guarded, counted run
        runner.run(transfer_guard=True)
        calls["n"] = 0
        t0 = time.perf_counter()
        ch = runner.run(reuse_experiments=True, transfer_guard=True)
        jax.block_until_ready(ch.accuracy)
        dt = time.perf_counter() - t0
    finally:
        cohort_mod.run_rounds = real_run_rounds

    lanes = len(ch.seeds)
    single_program = calls["n"] == 1
    mean_staleness = float(ch.staleness.mean())
    staleness_positive = bool(ch.staleness.max() > 0)
    buffer_bounded = bool((ch.participation <= 3).all())
    rps = lanes * (rounds + 1) / dt

    payload = {
        "benchmark": "async_engine",
        "environment": {"devices": len(jax.devices()),
                        "backend": jax.default_backend(),
                        "cpu_count": os.cpu_count()},
        "workload": {"cohort": 2, "rounds": rounds, "clients": 10,
                     "aggregator": "fedbuff:3:0.5",
                     "churn": [0.05, 0.2]},
        "single_scanned_program": single_program,
        "dispatches": calls["n"],
        "no_host_round_trips": True,       # transfer guard would have raised
        "staleness_positive": staleness_positive,
        "buffer_bounded": buffer_bounded,
        "mean_staleness": round(mean_staleness, 4),
        "mean_participation": round(float(ch.participation.mean()), 4),
        "mean_active": round(float(ch.active.mean()), 4),
        "cohort_ticks_per_sec": round(rps, 3),
    }
    emit("async/fedbuff_tps", 1e6 / rps, f"{rps:.2f}")
    emit("async/dispatches", 0.0, str(calls["n"]))
    out = out or os.path.join(os.path.dirname(__file__), "..", "results",
                              "BENCH_async.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(out)}")
    return payload


def smoke(out: str | None = None) -> bool:
    """Per-PR CI gate: structural properties of the buffered-async path."""
    payload = run(rounds=4, out=out)
    ok = True
    for key in ("single_scanned_program", "staleness_positive",
                "buffer_bounded"):
        verdict = "ok" if payload[key] else "FAIL"
        print(f"smoke {key}: {payload[key]} ... {verdict}")
        ok &= bool(payload[key])
    print(json.dumps(payload, indent=1))
    return ok


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="structural gate: one scanned program, no host "
                         "round-trips, positive staleness under M < K "
                         "(non-zero exit on failure; the tier-1 CI step)")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke(out=args.out) else 1)
    run(rounds=args.rounds, out=args.out)
