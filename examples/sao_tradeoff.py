"""Resource-allocation study: delay vs power / energy-budget trade-offs and
the Appendix-E transmit-power optimizer (Figures 6, 7, 14 interactively).

Run:  PYTHONPATH=src python examples/sao_tradeoff.py
"""
import numpy as np

from repro.core import sample_fleet, fleet_arrays, solve_sao
from repro.core.baselines import equal_bandwidth
from repro.core.power import optimal_transmit_power
from repro.core.wireless import dbm_to_watt

B = 20.0


def main():
    fleet10 = sample_fleet(100, seed=0, e_cons_range=(35e-3, 35e-3)) \
        .select(np.arange(10))

    print("=== delay vs transmit power (e_cons = 35 mJ) ===")
    print(f"{'p[dBm]':>7s} {'SAO T[ms]':>10s} {'equal T[ms]':>11s}")
    for p in range(10, 24, 2):
        arr = fleet_arrays(fleet10.with_power(dbm_to_watt(p)))
        t_sao = float(solve_sao(arr, B).T) * 1e3
        t_eq = float(equal_bandwidth(arr, B).T) * 1e3
        print(f"{p:7d} {t_sao:10.1f} {t_eq:11.1f}")

    print("\n=== Algorithm 6: optimal shared transmit power ===")
    res = optimal_transmit_power(fleet10, B)
    print(f"p* = {res.p_star_dbm:.2f} dBm -> T* = {res.T_star*1e3:.1f} ms "
          f"({len(res.history)} probes)")

    print("\n=== delay vs per-device energy budget (p = 23 dBm) ===")
    print(f"{'e[mJ]':>6s} {'SAO T[ms]':>10s} {'paper-SAO':>10s} "
          f"{'box-fix':>8s}")
    for e in [30, 35, 40, 45, 50]:
        fl = sample_fleet(100, seed=0, e_cons_range=(e * 1e-3, e * 1e-3)) \
            .select(np.arange(10))
        arr = fleet_arrays(fl)
        t_p = float(solve_sao(arr, B).T) * 1e3
        t_b = float(solve_sao(arr, B, box_correct=True).T) * 1e3
        print(f"{e:6d} {min(t_p, t_b):10.1f} {t_p:10.1f} {t_b:8.1f}")


if __name__ == "__main__":
    main()
