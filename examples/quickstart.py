"""Quickstart: the paper's two contributions in ~60 seconds on CPU.

1. SAO (Algorithm 5): allocate bandwidth + CPU frequency for 10 selected
   devices under per-device energy budgets; check the Theorem-1 structure.
2. Weight-divergence device selection (Algorithms 2-4) on a miniature
   non-iid federated MNIST-like problem.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.configs.paper_cnn import CNN_CONFIGS
from repro.core import (FLExperiment, sample_fleet, fleet_arrays, solve_sao,
                        kkt_residuals, equal_bandwidth, adjusted_rand_index)
from repro.data import make_dataset, partition_bias


def demo_sao():
    print("=== 1. Spectrum Allocation Optimization (Alg. 5) ===")
    fleet = sample_fleet(100, seed=0)
    arr = fleet_arrays(fleet.select(np.arange(10)))
    B = 20.0  # MHz

    sol = solve_sao(arr, B)
    eq = equal_bandwidth(arr, B)
    r = kkt_residuals(sol, arr, B)
    print(f"SAO   T_k = {float(sol.T)*1e3:7.1f} ms  (band used: "
          f"{float(sol.ratio)*100:.1f}%)")
    print(f"equal T_k = {float(eq.T)*1e3:7.1f} ms")
    print(f"bandwidth b [MHz]: {np.round(np.asarray(sol.b), 2)}")
    print(f"cpu freq  f [GHz]: {np.round(np.asarray(sol.f), 2)}")
    print(f"per-device energy slack [mJ]: "
          f"{np.round(np.asarray(r['energy_slack'])*1e3, 2)}")

    sol_bc = solve_sao(arr, B, box_correct=True)
    print(f"beyond-paper box-corrected SAO: T_k = {float(sol_bc.T)*1e3:.1f} ms "
          f"({(1-float(sol_bc.T)/float(sol.T))*100:.1f}% faster)\n")


def demo_selection():
    print("=== 2. K-means clustering + weight-divergence selection ===")
    ds = make_dataset("fashion", 1500, seed=0)
    test = make_dataset("fashion", 400, seed=999)
    fed = partition_bias(ds, 20, 64, sigma=0.8, seed=1)
    fleet = sample_fleet(20, seed=0)
    fl = FLConfig(num_devices=20, devices_per_round=10, local_iters=20,
                  num_clusters=10, learning_rate=0.08, max_rounds=5)
    exp = FLExperiment(CNN_CONFIGS["fashion"], fed, test.images, test.labels,
                       fleet, fl, seed=0)
    hist = exp.run("divergence", rounds=5)
    ari = adjusted_rand_index(exp.cluster_labels, fed.majority)
    print(f"K-means clusters vs majority classes: ARI = {ari:.3f}")
    print(f"accuracy curve: {np.round(hist.accuracy, 3).tolist()}")
    print(f"per-round latency T_k [s]: {np.round(hist.T_k, 3).tolist()}")
    print(f"total energy E = {hist.total_E:.2f} J over {len(hist.T_k)} rounds")


if __name__ == "__main__":
    demo_sao()
    demo_selection()
