"""Quickstart: the paper's two contributions in ~60 seconds on CPU.

1. SAO (Algorithm 5): allocate bandwidth + CPU frequency for 10 selected
   devices under per-device energy budgets; check the Theorem-1 structure.
2. Weight-divergence device selection (Algorithms 2-4) on a miniature
   non-iid federated MNIST-like problem.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.api import ExperimentSpec, build_experiment
from repro.core import (sample_fleet, fleet_arrays, solve_sao,
                        kkt_residuals, equal_bandwidth, adjusted_rand_index)


def demo_sao():
    print("=== 1. Spectrum Allocation Optimization (Alg. 5) ===")
    fleet = sample_fleet(100, seed=0)
    arr = fleet_arrays(fleet.select(np.arange(10)))
    B = 20.0  # MHz

    sol = solve_sao(arr, B)
    eq = equal_bandwidth(arr, B)
    r = kkt_residuals(sol, arr, B)
    print(f"SAO   T_k = {float(sol.T)*1e3:7.1f} ms  (band used: "
          f"{float(sol.ratio)*100:.1f}%)")
    print(f"equal T_k = {float(eq.T)*1e3:7.1f} ms")
    print(f"bandwidth b [MHz]: {np.round(np.asarray(sol.b), 2)}")
    print(f"cpu freq  f [GHz]: {np.round(np.asarray(sol.f), 2)}")
    print(f"per-device energy slack [mJ]: "
          f"{np.round(np.asarray(r['energy_slack'])*1e3, 2)}")

    sol_bc = solve_sao(arr, B, box_correct=True)
    print(f"beyond-paper box-corrected SAO: T_k = {float(sol_bc.T)*1e3:.1f} ms "
          f"({(1-float(sol_bc.T)/float(sol.T))*100:.1f}% faster)\n")


def demo_selection():
    print("=== 2. K-means clustering + weight-divergence selection ===")
    # one declarative spec = the whole experiment (JSON-serializable;
    # strategies are registry names — see repro.api / repro.strategies)
    spec = ExperimentSpec(dataset="fashion", clients=20, sigma=0.8,
                          train_samples=1500, test_samples=400,
                          samples_per_client=64, local_iters=20,
                          learning_rate=0.08, rounds=5,
                          selection="divergence", allocator="sao",
                          data_seed=0, test_seed=999, partition_seed=1,
                          fleet_seed=0, seed=0)
    exp = build_experiment(spec)
    hist = exp.run(rounds=5)
    ari = adjusted_rand_index(exp.cluster_labels, exp.fed.majority)
    print(f"K-means clusters vs majority classes: ARI = {ari:.3f}")
    print(f"accuracy curve: {np.round(hist.accuracy, 3).tolist()}")
    print(f"per-round latency T_k [s]: {np.round(hist.T_k, 3).tolist()}")
    print(f"total energy E = {hist.total_E:.2f} J over {len(hist.T_k)} rounds")


if __name__ == "__main__":
    demo_sao()
    demo_selection()
