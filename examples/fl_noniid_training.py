"""End-to-end driver (deliverable b): the paper's full FL framework (Fig. 2)
— K-means clustering, weight-divergence selection, SAO allocation, FedAvg —
trained to a target accuracy on a non-iid federated dataset, with the
time/energy ledger (eqs. 10-11).

Compares all selection policies head-to-head. A full run is a few hundred
aggregate local-update steps per policy.

Run:  PYTHONPATH=src python examples/fl_noniid_training.py [--rounds 25]
"""
import argparse
import time

import numpy as np

from repro.api import ExperimentSpec, build_experiment
from repro.core import adjusted_rand_index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="fashion",
                    choices=["mnist", "cifar10", "fashion"])
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--sigma", default="0.8")
    ap.add_argument("--target-acc", type=float, default=0.6)
    ap.add_argument("--methods", default="divergence,kmeans_random,random")
    args = ap.parse_args()
    sigma = args.sigma if args.sigma == "H" else float(args.sigma)

    # one declarative spec; the per-method runs are replace()d variants and
    # share the engine's compiled round functions
    base = ExperimentSpec(dataset=args.dataset, clients=args.clients,
                          sigma=sigma, train_samples=3000, test_samples=800,
                          samples_per_client=96, local_iters=20,
                          learning_rate=0.08, rounds=args.rounds,
                          target_accuracy=args.target_acc, allocator="sao",
                          data_seed=7, test_seed=90_000, partition_seed=1,
                          fleet_seed=0, seed=0)

    print(f"dataset={args.dataset} clients={args.clients} sigma={sigma} "
          f"target={args.target_acc}")
    print(f"{'method':15s} {'final_acc':>9s} {'rounds→tgt':>10s} "
          f"{'T_total[s]':>10s} {'E_total[J]':>10s} {'ARI':>6s} {'wall[s]':>8s}")

    for method in args.methods.split(","):
        t0 = time.time()
        exp = build_experiment(base.replace(selection=method))
        hist = exp.run(rounds=args.rounds, target_accuracy=args.target_acc)
        ari = adjusted_rand_index(exp.cluster_labels, exp.fed.majority)
        r2t = hist.rounds_to_target if hist.rounds_to_target else f">{args.rounds}"
        print(f"{method:15s} {hist.accuracy[-1]:9.3f} {str(r2t):>10s} "
              f"{hist.total_T:10.2f} {hist.total_E:10.2f} {ari:6.3f} "
              f"{time.time()-t0:8.1f}")


if __name__ == "__main__":
    main()
