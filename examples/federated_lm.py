"""Federated language modeling on the flat parameter plane.

The paper's full pipeline — K-means clustering, weight-divergence selection,
SAO spectrum allocation, FedAvg — on a TRANSFORMER workload: each client's
trainable state is a LoRA adapter row over a frozen tinyllama-style base
(``repro.models.lm``), clients hold token windows from non-iid Markov
"dialects" (the LM analogue of majority image classes), and the whole run
executes as the SAME single scanned program the CNN uses. Upload payloads
are priced at P_adapter (the adapter row), never the frozen base.

Run:  PYTHONPATH=src python examples/federated_lm.py [--rounds 8]
      PYTHONPATH=src python examples/federated_lm.py --dry-run
"""
import argparse
import time

import numpy as np

from repro.api import ExperimentSpec, build_experiment
from repro.core import adjusted_rand_index
from repro.models.lm import adapter_num_params


def build_spec(args) -> ExperimentSpec:
    return ExperimentSpec(
        model=args.model, clients=args.clients,
        train_samples=args.clients * args.windows_per_client,
        test_samples=args.test_windows,
        samples_per_client=args.windows_per_client, sigma=0.8,
        rounds=args.rounds, devices_per_round=args.dialects,
        num_clusters=args.dialects, local_iters=args.local_steps,
        learning_rate=args.lr, batch_size=args.batch,
        selection="divergence", allocator="sao", seed=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tinyllama",
                    choices=["tinyllama", "mamba2-130m"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--dialects", type=int, default=4,
                    help="clusters AND devices/round (1 per cluster)")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--windows-per-client", type=int, default=16)
    ap.add_argument("--test-windows", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--dry-run", action="store_true",
                    help="1 tiny round: smoke the build + traced dispatch")
    args = ap.parse_args()
    if args.dry_run:
        args.rounds, args.clients, args.dialects = 1, 6, 2
        args.local_steps, args.windows_per_client = 2, 8
        args.test_windows, args.batch = 16, 4

    spec = build_spec(args)
    exp = build_experiment(spec)
    model_cfg = exp.model_cfg
    p_adapter = adapter_num_params(model_cfg)
    print(f"model={args.model}  P_adapter={p_adapter}  "
          f"plane=[{args.clients}, {p_adapter}]  "
          f"upload z={exp.fleet.z[0]:.4f} Mbit (= P_adapter*32/1e6)")
    print(f"traceable bundle: {exp.traceable()} "
          f"(one lax.scan program, {args.rounds} rounds)")

    t0 = time.time()
    hist = exp.run(rounds=args.rounds)
    wall = time.time() - t0
    ari = adjusted_rand_index(exp.cluster_labels,
                              np.asarray(exp.fed.majority))
    print(f"{'round':>5s} {'next-tok acc':>12s} {'T_k[s]':>8s} {'E_k[J]':>8s}")
    for r, (a, T, E) in enumerate(zip(hist.accuracy, hist.T_k, hist.E_k)):
        print(f"{r:5d} {a:12.4f} {T:8.3f} {E:8.3f}")
    print(f"dialect-cluster ARI={ari:.3f}  total T={hist.total_T:.2f}s "
          f"E={hist.total_E:.2f}J  wall={wall:.1f}s")
    if args.dry_run:
        print("dry-run ok")


if __name__ == "__main__":
    main()
