"""Beyond-paper example: the paper's selection technique on TRANSFORMER
clients (federated language modeling).

20 clients hold token streams from different Markov "dialects" (the LM
analogue of majority classes); each round the server computes weight
divergences, clusters clients on the lm_head layer (the w_fc2 analogue,
§IV-B), selects the top-divergence client per cluster, and FedAvg-aggregates
— exactly Algorithms 2-4 but with a GQA transformer instead of the CNN.

Run:  PYTHONPATH=src python examples/federated_lm.py [--rounds 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import TrainConfig
from repro.core.clustering import kmeans_fit, clusters_from_labels, \
    adjusted_rand_index
from repro.core.divergence import weight_divergence
from repro.core.selection import select_divergence, select_random
from repro.data.synthetic import make_token_stream
from repro.models import init_model
from repro.train.train_step import make_train_step
from repro.utils.trees import tree_weighted_mean_stacked


def make_dialect_streams(vocab, n_dialects, n_clients, tokens_per_client,
                         seed=0):
    """Each dialect = its own Markov chain; clients are assigned round-robin."""
    streams, dialect = [], []
    for n in range(n_clients):
        d = n % n_dialects
        streams.append(make_token_stream(vocab, tokens_per_client,
                                         seed=seed * 1000 + d))
        dialect.append(d)
    return np.stack(streams), np.array(dialect)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--dialects", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config("tinyllama-1.1b")
    tc = TrainConfig(learning_rate=1e-2, total_steps=1000, warmup_steps=1,
                     optimizer="sgd", grad_clip=1.0)
    streams, dialect = make_dialect_streams(
        cfg.vocab_size, args.dialects, args.clients, 8000)

    global_params = init_model(cfg, jax.random.PRNGKey(0))
    opt_init, train_step = make_train_step(cfg, tc, q_chunk=32, kv_chunk=32)

    def local_update(params, stream, key):
        opt = opt_init(params)
        # simple python loop (tiny scale) for clarity
        for s in range(args.local_steps):
            key, k = jax.random.split(key)
            i = np.asarray(jax.random.randint(k, (args.batch,), 0,
                                              stream.shape[0] - args.seq - 1))
            toks = jnp.asarray(np.stack([np.asarray(stream)[j:j + args.seq]
                                         for j in i]))
            params, opt, m = jitted_step(params, opt, {"tokens": toks})
        return params, float(m["loss"])

    # NOTE: no donation — global_params is reused by every selected client
    jitted_step = jax.jit(train_step)
    client_params = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (args.clients,) + l.shape).copy(),
        global_params)
    rng = np.random.default_rng(0)

    print(f"{'round':>5s} {'policy':>10s} {'mean loss':>9s} {'ARI':>6s}")
    for r in range(args.rounds):
        # selection: round 0 = everyone (Alg. 2 protocol), then divergence
        if r == 0:
            idx = np.arange(args.clients)
            clusters = None
        else:
            feats = client_params.get("lm_head",
                                      client_params["embed"])
            feats = feats.reshape(args.clients, -1)
            _, labels, _ = kmeans_fit(jax.random.PRNGKey(r), feats,
                                      args.dialects)
            clusters = clusters_from_labels(np.asarray(labels),
                                            args.dialects)
            div = np.asarray(weight_divergence(client_params, global_params))
            idx = select_divergence(div, clusters, s=1)
        losses = []
        updated = []
        for n in idx:
            key = jax.random.PRNGKey(1000 * r + int(n))
            p_n, loss = local_update(global_params, jnp.asarray(streams[n]),
                                     key)
            updated.append(p_n)
            losses.append(loss)
        stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *updated)
        client_params = jax.tree_util.tree_map(
            lambda all_, new: all_.at[jnp.asarray(idx)].set(new),
            client_params, stacked)
        global_params = tree_weighted_mean_stacked(
            stacked, np.ones(len(idx)))
        ari = (adjusted_rand_index(np.asarray(labels), dialect)
               if clusters is not None else float("nan"))
        print(f"{r:5d} {'all' if r == 0 else 'divergence':>10s} "
              f"{np.mean(losses):9.3f} {ari:6.3f}")


if __name__ == "__main__":
    main()
