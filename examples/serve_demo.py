"""Batched serving demo: generate from reduced variants of three assigned
families (dense GQA, Mamba2/SSD, encoder-decoder) through the ServeEngine —
prefill + cached decode, greedy and sampled.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_model
from repro.serve import ServeEngine


def demo(arch: str, batch: int = 4, prompt_len: int = 8, gen: int = 16):
    cfg = get_smoke_config(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=prompt_len + gen + 1)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["src_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (batch, 16, cfg.d_model)) * 0.1
    t0 = time.time()
    out = eng.generate(prompts, num_tokens=gen, **kw)
    dt = time.time() - t0
    print(f"{arch:22s} batch={batch} generated {gen} tokens "
          f"({batch*gen/dt:.1f} tok/s on CPU)")
    print(f"  first row: {out[0].tolist()}")
    # sampled variant
    out2 = eng.generate(prompts, num_tokens=gen, sampler="temperature",
                        key=jax.random.PRNGKey(3), temp=1.0, **kw)
    diverse = (out != out2).mean()
    print(f"  temperature sampling differs on {diverse*100:.0f}% of tokens")


if __name__ == "__main__":
    for arch in ["tinyllama-1.1b", "mamba2-130m", "seamless-m4t-medium"]:
        demo(arch)
