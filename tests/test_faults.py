"""Fault-tolerant runtime: channel-grounded fault injection, quarantine +
robust aggregation, and checkpoint/resume.

The parity pins are the contract that makes fault injection trustworthy:
the SAME faults must hit the SAME clients on every driver route (traced
scan ≡ host loop, dense async ≡ paged async), because the masks are drawn
from the engine's own PRNG stream right after the train split. Checkpoint
/resume is pinned bit-identical — a resumed run and the uninterrupted run
must be indistinguishable, which is also why ``make_dataset`` may not
depend on the per-process ``hash()`` salt (regression below).
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, FleetSpec, build_cohort, build_experiment
from repro.core.faults import FaultSpec, draw_fault_masks
from repro.kernels import ops
from repro.utils.trees import tree_flatten_vector

TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=3, devices_per_round=4, num_clusters=4,
            learning_rate=0.05, selection="divergence")

PAGED = dict(store="paged", k_max=8, div_refresh_every=1)


def _gvec(exp):
    return np.asarray(tree_flatten_vector(exp.global_params))


# ---------------------------------------------------------------------------
# satellite (a): the 0·NaN guard in the flat fold
# ---------------------------------------------------------------------------


def test_flat_aggregate_zero_weight_nan_guard():
    """A zero-weight lane carrying NaN/Inf must not poison the fold —
    0 * NaN is NaN in IEEE, so the kernel has to mask the payload, not
    just the weight."""
    rows = jnp.asarray([[1.0, 2.0], [jnp.nan, jnp.inf], [3.0, 6.0]])
    w = jnp.asarray([1.0, 0.0, 3.0])
    out = np.asarray(ops.flat_aggregate(rows, w))
    ref = np.asarray(ops.flat_aggregate(rows[::2], w[::2]))
    assert np.all(np.isfinite(out))
    assert np.array_equal(out, ref)


# ---------------------------------------------------------------------------
# FaultSpec parsing / validation
# ---------------------------------------------------------------------------


def test_fault_spec_parse_roundtrip():
    fs = FaultSpec.from_string("outage:0.1,corrupt:0.05,byzantine:0.2,"
                               "byz_scale:3,deadline:0.4")
    assert fs.outage == 0.1 and fs.corrupt == 0.05
    assert fs.byzantine == 0.2 and fs.byz_scale == 3.0
    assert fs.deadline == 0.4
    assert fs.active
    assert FaultSpec.normalize(fs.to_dict()) == fs
    assert FaultSpec.normalize(None) is None
    assert not FaultSpec().active


@pytest.mark.parametrize("bad", ["nonsense:0.5", "outage:1.5", "outage:-0.1",
                                 "byz_scale:-1", "deadline:-2", "outage"])
def test_fault_spec_rejects(bad):
    with pytest.raises(ValueError):
        FaultSpec.from_string(bad)


def test_fault_masks_shapes_and_rates():
    import jax
    fs = FaultSpec.from_string("outage:1.0,corrupt:0.0")
    drop, corrupt = draw_fault_masks(jax.random.PRNGKey(0), fs, (32,))
    assert bool(jnp.all(drop)) and not bool(jnp.any(corrupt))


def test_chan_outage_needs_stateful_channel():
    spec = ExperimentSpec(**TINY, faults="chan_outage:0.2")
    with pytest.raises(ValueError, match="stateful"):
        build_experiment(spec)
    # a fading channel grounds the outage in its own gain state
    ok = ExperimentSpec(**TINY, faults="chan_outage:0.2",
                        fleet=FleetSpec(channel="gauss-markov"))
    exp = build_experiment(ok)
    exp.run(rounds=2)
    assert np.all(exp.stats.faults >= 0)


def test_build_cohort_rejects_faults():
    spec = ExperimentSpec(**TINY, cohort=2, faults="outage:0.1")
    with pytest.raises(ValueError, match="cohort"):
        build_cohort(spec)


# ---------------------------------------------------------------------------
# robust aggregators
# ---------------------------------------------------------------------------


def test_robust_aggregator_parsing_and_validation():
    from repro.api.registry import AGGREGATORS, StrategyError
    tm = AGGREGATORS.resolve("trimmed:0.2")
    assert tm.f == 0.2 and tm.traceable and not tm.fuses_with_engine
    cn = AGGREGATORS.resolve("clipnorm:1.5")
    assert cn.c == 1.5
    with pytest.raises(StrategyError):
        AGGREGATORS.resolve("trimmed:0.5")
    with pytest.raises(StrategyError):
        AGGREGATORS.resolve("clipnorm:0")


def test_trimmed_mean_drops_outlier_lanes():
    from repro.api.registry import AGGREGATORS
    tm = AGGREGATORS.resolve("trimmed:0.25")
    g = jnp.zeros(3)
    rows = jnp.asarray([[1.0, 1.0, 1.0], [2.0, 2.0, 2.0],
                        [3.0, 3.0, 3.0], [1e6, -1e6, 1e6],
                        [np.nan, np.nan, np.nan]])    # padding lane
    w = jnp.asarray([1.0, 1.0, 1.0, 1.0, 0.0])
    out, _ = tm.aggregate_flat(g, rows, w, None)
    # k=4, t=1, COORDINATE-wise: the 1e6 outlier tops columns 0/2 and
    # bottoms column 1, so the survivors are (2,3) / (1,2) / (2,3)
    assert np.allclose(np.asarray(out), [2.5, 1.5, 2.5])


def test_clipnorm_degenerates_to_fedavg():
    from repro.api.registry import AGGREGATORS
    cn = AGGREGATORS.resolve("clipnorm:1e9")
    g = jnp.asarray([1.0, -1.0, 0.5])
    rows = jnp.asarray([[2.0, 0.0, 1.0], [0.0, -2.0, 0.0]])
    w = jnp.asarray([1.0, 3.0])
    out, _ = cn.aggregate_flat(g, rows, w, None)
    assert np.array_equal(np.asarray(out),
                          np.asarray(ops.flat_aggregate(rows, w)))


def test_clipnorm_bounds_single_client_pull():
    from repro.api.registry import AGGREGATORS
    cn = AGGREGATORS.resolve("clipnorm:1.0")
    g = jnp.zeros(4)
    rows = jnp.asarray([[1e4, 0.0, 0.0, 0.0]])
    out, _ = cn.aggregate_flat(g, rows, jnp.ones(1), None)
    assert np.linalg.norm(np.asarray(out)) <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# route parity under faults
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_traced_host_parity_under_faults():
    """Traced scan and host loop draw the SAME fault masks: one key split
    after the train split, both routes. Accuracy and the O(N) fault
    counters must agree bitwise."""
    kw = dict(TINY, faults="outage:0.3,corrupt:0.2", quarantine_after=2)
    e_t = build_experiment(ExperimentSpec(**kw))
    e_h = build_experiment(ExperimentSpec(**kw))
    h_t = e_t.run(rounds=TINY["rounds"])
    # an unreachable target routes run() onto the legacy host loop
    h_h = e_h.run(rounds=TINY["rounds"], target_accuracy=2.0)
    assert h_t.accuracy == h_h.accuracy
    assert np.array_equal(e_t.stats.faults, e_h.stats.faults)
    assert np.array_equal(e_t.stats.strikes, e_h.stats.strikes)
    assert np.array_equal(_gvec(e_t), _gvec(e_h))


@pytest.mark.slow
def test_async_dense_paged_parity_under_faults_and_churn():
    """The hardest route pin: fedbuff + churn + outages + corruption on
    the dense scanned tick vs the paged host composition."""
    kw = dict(TINY, aggregator="fedbuff:2:0.5",
              faults="outage:0.2,corrupt:0.1", quarantine_after=2,
              churn_leave=0.05, churn_join=0.1)
    e_d = build_experiment(ExperimentSpec(**kw))
    e_p = build_experiment(ExperimentSpec(**kw, **PAGED))
    h_d = e_d.run(rounds=TINY["rounds"])
    h_p = e_p.run(rounds=TINY["rounds"])
    assert h_d.accuracy == h_p.accuracy
    assert np.array_equal(_gvec(e_d), _gvec(e_p))
    for col in ("faults", "strikes", "t_done", "avail"):
        assert np.array_equal(getattr(e_d.stats, col),
                              getattr(e_p.stats, col)), col


def test_all_failed_round_is_a_noop():
    """outage:1.0 — every upload lost, every round. The global row must
    stay frozen and finite (the explicit empty-fire degradation), never
    divide by zero."""
    from repro.core.clustering import clusters_from_labels
    exp = build_experiment(ExperimentSpec(**TINY, faults="outage:1.0"))
    # preset a trivial partition so the driver never forces the Alg.-2
    # initial round (which trains all clients fault-free by design)
    labels = np.zeros(exp.fed.num_clients, np.int32)
    exp.cluster_labels = labels
    exp.clusters = clusters_from_labels(labels, exp.fl.num_clusters)
    g0 = _gvec(exp)
    hist = exp.run(rounds=2, include_initial_round=False,
                   target_accuracy=2.0)
    assert np.array_equal(_gvec(exp), g0)
    assert np.all(np.isfinite(np.asarray(hist.accuracy)))


@pytest.mark.slow
def test_quarantine_excludes_repeat_offenders():
    """Non-finite uploads accumulate strikes; once a client crosses
    ``quarantine_after`` it must vanish from selection."""
    kw = dict(TINY, faults="corrupt:0.6", quarantine_after=2)
    exp = build_experiment(ExperimentSpec(**kw))
    exp.run(rounds=6, target_accuracy=2.0)
    quarantined = np.flatnonzero(exp.stats.strikes >= 2)
    assert quarantined.size                   # 0.6 corruption: certain
    hist = exp.run(rounds=3, include_initial_round=False,
                   target_accuracy=2.0)
    for sel in hist.selected:
        assert not np.intersect1d(np.asarray(sel), quarantined).size


@pytest.mark.slow
def test_byzantine_bounded_by_trimmed_mean():
    """A negate-and-amplify byzantine cohort wrecks the plain eq. (4)
    fold but lands in the trimmed tails: the robust global row must stay
    far closer to the fault-free trajectory."""
    clean = build_experiment(ExperimentSpec(**TINY))
    plain = build_experiment(ExperimentSpec(
        **TINY, faults="byzantine:0.25,byz_scale:50"))
    robust = build_experiment(ExperimentSpec(
        **TINY, faults="byzantine:0.25,byz_scale:50", aggregator="trimmed:0.3"))
    clean.run(rounds=TINY["rounds"])
    plain.run(rounds=TINY["rounds"])
    robust.run(rounds=TINY["rounds"])
    d_plain = np.linalg.norm(_gvec(plain) - _gvec(clean))
    d_robust = np.linalg.norm(_gvec(robust) - _gvec(clean))
    assert np.isfinite(d_robust)
    assert d_robust < d_plain / 10.0


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


def _resume_pair(tmp_path, kw, rounds=4, cut=2):
    """Run ``rounds`` uninterrupted; run ``cut`` + checkpoint; rebuild a
    FRESH experiment, restore, run the rest. Returns both (exp, hist)."""
    spec = ExperimentSpec(**kw)
    full = build_experiment(spec)
    h_full = full.run(rounds=rounds)

    part = build_experiment(spec)
    part.run(rounds=cut, checkpoint_every=cut, checkpoint_dir=str(tmp_path),
             checkpoint_spec=spec.to_dict())

    res = build_experiment(spec)
    rnd, hist = res.load_checkpoint(str(tmp_path),
                                    expected_spec=spec.to_dict())
    assert rnd == cut
    h_res = res.run(rounds=rounds - cut, include_initial_round=False,
                    checkpoint_offset=rnd, history=hist)
    return (full, h_full), (res, h_res)


@pytest.mark.slow
def test_checkpoint_resume_bit_identical_paged_async(tmp_path):
    """Kill-and-resume on the hardest route (paged + fedbuff + churn +
    faults + quarantine) reproduces the uninterrupted run bit for bit —
    global row, history, and every stats column including the fault and
    strike counters."""
    kw = dict(TINY, **PAGED, aggregator="fedbuff:2:0.5",
              faults="outage:0.2,corrupt:0.3", quarantine_after=2,
              churn_leave=0.05, churn_join=0.1)
    (full, h_full), (res, h_res) = _resume_pair(tmp_path, kw)
    assert h_full.accuracy == h_res.accuracy
    assert h_full.T_k == h_res.T_k and h_full.E_k == h_res.E_k
    assert np.array_equal(_gvec(full), _gvec(res))
    for col in ("divergence", "drift", "age", "t_done", "avail", "faults",
                "strikes", "t_now"):
        assert np.array_equal(getattr(full.stats, col),
                              getattr(res.stats, col)), col


@pytest.mark.slow
def test_checkpoint_resume_dense_sync(tmp_path):
    kw = dict(TINY, faults="outage:0.3", quarantine_after=1)
    (full, h_full), (res, h_res) = _resume_pair(tmp_path, kw)
    assert h_full.accuracy == h_res.accuracy
    assert np.array_equal(_gvec(full), _gvec(res))
    assert np.array_equal(full.stats.strikes, res.stats.strikes)


def test_checkpoint_rejects_spec_mismatch(tmp_path):
    spec = ExperimentSpec(**TINY)
    exp = build_experiment(spec)
    exp.run(rounds=2, checkpoint_every=2, checkpoint_dir=str(tmp_path),
            checkpoint_spec=spec.to_dict())
    other = ExperimentSpec(**dict(TINY, learning_rate=0.01))
    fresh = build_experiment(other)
    with pytest.raises(ValueError, match="learning_rate"):
        fresh.load_checkpoint(str(tmp_path), expected_spec=other.to_dict())


def test_dense_async_checkpoint_unsupported():
    exp = build_experiment(ExperimentSpec(**TINY, aggregator="fedbuff:2"))
    with pytest.raises(ValueError, match="paged"):
        exp.run(rounds=2, checkpoint_every=1, checkpoint_dir="/tmp/nope")


# ---------------------------------------------------------------------------
# satellite (b): train/checkpoint.py hardening
# ---------------------------------------------------------------------------


def test_checkpoint_bf16_roundtrip(tmp_path):
    from repro.train import checkpoint as ckpt
    tree = {"w": jnp.arange(7, dtype=jnp.bfloat16) / 3,
            "b": np.arange(4, dtype=np.float32)}
    path = str(tmp_path / "snap")
    ckpt.save_checkpoint(path, tree, step=5)
    out = ckpt.load_checkpoint(path, tree)
    assert out["w"].dtype == jnp.bfloat16
    # bf16 -> f32 widening is lossless, so the round trip is bitwise
    assert np.array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert np.array_equal(out["b"], tree["b"])
    assert ckpt.checkpoint_step(path) == 5


def test_checkpoint_manifest_commits_last(tmp_path):
    """A snapshot without a manifest is torn, not committed — readers
    must skip it and fall back to the newest complete one."""
    from repro.train import checkpoint as ckpt
    good = str(tmp_path / "round_000002")
    ckpt.save_checkpoint(good, {"x": np.ones(3)}, step=2)
    torn = str(tmp_path / "round_000004")
    os.makedirs(torn)
    np.savez(os.path.join(torn, "leaves.npz"), x=np.zeros(3))
    assert ckpt.is_checkpoint(good) and not ckpt.is_checkpoint(torn)
    # a stale LATEST pointer at the torn snapshot is also skipped
    ckpt.write_latest(str(tmp_path), "round_000004")
    assert ckpt.latest_checkpoint(str(tmp_path)) == good
    with pytest.raises(FileNotFoundError):
        ckpt.latest_checkpoint(str(tmp_path / "empty"))


def test_checkpoint_no_tmp_litter(tmp_path):
    from repro.train import checkpoint as ckpt
    path = str(tmp_path / "snap")
    ckpt.save_checkpoint(path, {"x": np.ones(2)}, step=1)
    assert not [f for f in os.listdir(path) if f.endswith(".tmp")]


# ---------------------------------------------------------------------------
# cross-process determinism regression
# ---------------------------------------------------------------------------


def test_dataset_deterministic_across_hash_seeds():
    """make_dataset's class templates were seeded from ``hash(name)``,
    which is salted per interpreter — a resumed run in a fresh process
    trained on DIFFERENT data, breaking bit-identical --resume. Pin the
    stable digest by drawing the dataset under two hash salts."""
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = ("import sys; sys.path.insert(0, 'src'); "
            "from repro.data import make_dataset; "
            "d = make_dataset('mnist', 8, seed=3); "
            "print(repr((d.images.tobytes().hex()[:64], "
            "int(d.labels.sum()))))")
    outs = set()
    for salt in ("0", "1234"):
        r = subprocess.run([sys.executable, "-c", code], cwd=root,
                           env={**os.environ, "PYTHONHASHSEED": salt},
                           capture_output=True, text=True, check=True)
        outs.add(r.stdout.strip())
    assert len(outs) == 1, "dataset differs across interpreter hash salts"
