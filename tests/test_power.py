"""Algorithm 6 (transmit-power optimization) tests."""
import numpy as np

from repro.core.power import optimal_transmit_power
from repro.core.wireless import sample_fleet, fleet_arrays, dbm_to_watt
from repro.core.sao import solve_sao


def test_alg6_beats_or_matches_endpoints():
    fleet = sample_fleet(100, seed=0, e_cons_range=(35e-3, 35e-3)) \
        .select(np.arange(10))
    res = optimal_transmit_power(fleet, 20.0, p_min_dbm=10, p_max_dbm=23)
    t_lo = float(solve_sao(fleet_arrays(fleet.with_power(dbm_to_watt(10))),
                           20.0).T)
    t_hi = float(solve_sao(fleet_arrays(fleet.with_power(dbm_to_watt(23))),
                           20.0).T)
    assert res.T_star <= min(t_lo, t_hi) * 1.05
    assert 10.0 <= res.p_star_dbm <= 23.01
    assert len(res.history) >= 2


def test_alg6_near_grid_optimum():
    fleet = sample_fleet(100, seed=0, e_cons_range=(35e-3, 35e-3)) \
        .select(np.arange(10))
    grid = {p: float(solve_sao(
        fleet_arrays(fleet.with_power(dbm_to_watt(p))), 20.0).T)
        for p in range(10, 24)}
    best_T = min(grid.values())
    res = optimal_transmit_power(fleet, 20.0)
    assert res.T_star <= best_T * 1.05
