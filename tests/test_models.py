"""Model-zoo correctness: decode == forward for every family, SWA ring
buffers, encoder-decoder memory, loss masking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_model, forward, init_cache, decode_step
from repro.models.transformer import encode_memory

KEY = jax.random.PRNGKey(0)
T = 12


def _decode_all(cfg, p, toks, cache):
    outs = []
    step = jax.jit(lambda tok, c: decode_step(cfg, p, {"tokens": tok}, c))
    for t in range(toks.shape[1]):
        lg, cache = step(toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    return jnp.stack(outs, 1)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-1.5b", "qwen2-72b",
                                  "minitron-8b", "mixtral-8x22b",
                                  "granite-moe-3b-a800m", "mamba2-130m",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    p = init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size)
    ref, _ = forward(cfg, p, {"tokens": toks}, q_chunk=8, kv_chunk=8)
    dec = _decode_all(cfg, p, toks, init_cache(cfg, 2, T))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_encdec_decode_matches_forward():
    cfg = get_smoke_config("seamless-m4t-medium")
    p = init_model(cfg, KEY)
    src = jax.random.normal(jax.random.PRNGKey(3), (2, 24, cfg.d_model)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size)
    ref, _ = forward(cfg, p, {"tokens": toks, "src_embeds": src},
                     q_chunk=8, kv_chunk=8)
    cache = init_cache(cfg, 2, T)
    ck, cv = encode_memory(cfg, p, {"src_embeds": src}, q_chunk=8, kv_chunk=8)
    cache["cross_k"], cache["cross_v"] = ck, cv
    dec = _decode_all(cfg, p, toks, cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_vlm_image_tokens_change_logits():
    cfg = get_smoke_config("phi-3-vision-4.2b")
    p = init_model(cfg, KEY)
    toks = jnp.ones((1, 32), jnp.int32)
    img0 = jnp.zeros((1, cfg.num_image_tokens, cfg.d_model))
    img1 = jnp.ones((1, cfg.num_image_tokens, cfg.d_model)) * 0.3
    l0, _ = forward(cfg, p, {"tokens": toks, "image_embeds": img0},
                    q_chunk=8, kv_chunk=8)
    l1, _ = forward(cfg, p, {"tokens": toks, "image_embeds": img1},
                    q_chunk=8, kv_chunk=8)
    assert float(jnp.max(jnp.abs(l0 - l1))) > 1e-3


@pytest.mark.parametrize("window", [4, 8])
def test_swa_ring_buffer_decode(window):
    """Ring-buffer cache of size `window` matches full forward with SWA."""
    cfg = get_smoke_config("mixtral-8x22b").replace(sliding_window=window)
    p = init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, T), 0, cfg.vocab_size)
    ref, _ = forward(cfg, p, {"tokens": toks}, q_chunk=8, kv_chunk=8)
    cache = init_cache(cfg, 2, T, window=window)
    assert cache["attn"]["k"].shape[2] == window      # ring, not full length
    dec = _decode_all(cfg, p, toks, cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_unroll_equivalence():
    """unroll=full must be numerically identical to the scanned stack."""
    cfg = get_smoke_config("tinyllama-1.1b")
    p = init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    a, _ = forward(cfg, p, {"tokens": toks}, q_chunk=8, kv_chunk=8, unroll=1)
    b, _ = forward(cfg, p, {"tokens": toks}, q_chunk=16, kv_chunk=16, unroll=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_attention_chunking_invariance():
    from repro.models.layers import blockwise_attention
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 50, 4, 16))
    k = jax.random.normal(k2, (2, 50, 2, 16))
    v = jax.random.normal(k3, (2, 50, 2, 16))
    a = blockwise_attention(q, k, v, q_chunk=8, kv_chunk=16)
    b = blockwise_attention(q, k, v, q_chunk=50, kv_chunk=50)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_moe_dispatch_close_to_dense():
    """Dispatch MoE ≈ dense MoE when capacity is ample."""
    from repro.models import layers as L
    from repro.configs.base import MoEConfig
    moe = MoEConfig(num_experts=4, top_k=2, d_ff=32)
    p = L.init_moe(KEY, 16, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 10, 16))
    yd, _ = L.moe_apply_dense(p, x, moe)
    yp, _ = L.moe_apply_dispatch(p, x, moe, capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yp),
                               rtol=1e-4, atol=1e-4)
