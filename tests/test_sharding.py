"""Partition-rule unit tests (divisibility-aware fallbacks)."""
import jax
import numpy as np
import pytest

from repro.sharding.specs import param_spec, batch_axes

jax.config.update("jax_platforms", "cpu")


class FakeMesh:
    """Just enough Mesh interface for the rule functions."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def leaf(*shape):
    return jax.ShapeDtypeStruct(shape, jax.numpy.bfloat16)


def test_embed_vocab_sharded():
    assert param_spec(["embed"], leaf(152064, 8192), MESH1) \
        == jax.sharding.PartitionSpec("model", None)


def test_lm_head_vocab_sharded():
    assert param_spec(["lm_head"], leaf(8192, 152064), MESH1) \
        == jax.sharding.PartitionSpec(None, "model")


def test_attention_projections():
    # [L, D, H*hd] fused projection dim sharded (works even when head
    # count isn't divisible — granite's 24 heads × 64 = 1536 % 16 == 0)
    spec = param_spec(["blocks", "attn", "wq"], leaf(32, 1536, 1536), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, None, "model")
    spec = param_spec(["blocks", "attn", "wo"], leaf(32, 1536, 1536), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, "model", None)


def test_moe_expert_sharding_divisible():
    # jamba: 16 experts % 16 == 0 -> expert-sharded
    spec = param_spec(["groups", "pos1", "moe", "w_gate"],
                      leaf(9, 16, 8192, 24576), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, "model", None, None)


def test_moe_expert_sharding_fallback():
    # granite: 40 experts % 16 != 0 -> falls back to the FFN dim... which
    # is 512 % 16 == 0
    spec = param_spec(["blocks", "moe", "w_gate"],
                      leaf(32, 40, 1536, 512), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, None, None, "model")


def test_router_replicated():
    spec = param_spec(["blocks", "moe", "router"], leaf(32, 1536, 40), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, None, None)


def test_norms_replicated():
    spec = param_spec(["blocks", "ln1"], leaf(32, 8192), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_fallback_largest_divisible():
    # unknown 2D leaf: shard the largest divisible trailing dim
    spec = param_spec(["something"], leaf(100, 4096), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_indivisible_everything_replicates():
    spec = param_spec(["weird"], leaf(7, 13), MESH1)
    assert spec == jax.sharding.PartitionSpec(None, None)


@pytest.mark.parametrize("mesh,batch,expect", [
    (MESH1, 256, ("data",)),
    (MESH2, 256, ("pod", "data")),
    (MESH2, 2, ("pod",)),
    (MESH1, 1, ()),
    (MESH2, 1, ()),
    (MESH1, 33, ()),                       # not divisible -> replicate
])
def test_batch_axes(mesh, batch, expect):
    assert batch_axes(mesh, batch) == expect
