"""Device-resident round pipeline: scan-path ≡ python-loop equivalence,
traced-vs-numpy selector parity, masked/batched SAO invariance, and the
vmapped seed-cohort runner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build_cohort, build_experiment
from repro.core import selection as sel
from repro.core.sao import solve_sao
from repro.core.wireless import fleet_arrays, sample_fleet
from repro.strategies.traced import (select_divergence_traced,
                                     select_icas_traced,
                                     select_kmeans_random_traced,
                                     select_random_traced, select_rra_traced)

TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=3, devices_per_round=4, num_clusters=4,
            learning_rate=0.05)


def _run_legacy(exp, *args, **kw):
    """Force the round-at-a-time Python loop regardless of traceability."""
    exp.traceable = lambda *a, **k: False
    return exp.run(*args, **kw)


# ---------------------------------------------------------------------------
# scan path ≡ python loop (the tentpole acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_scan_path_matches_python_loop():
    spec = ExperimentSpec(**TINY)
    traced = build_experiment(spec)
    assert traced.traceable()
    h_t = traced.run(rounds=3)

    legacy = build_experiment(spec)
    h_l = _run_legacy(legacy, rounds=3)

    assert h_t.accuracy == h_l.accuracy
    np.testing.assert_allclose(h_t.T_k, h_l.T_k, rtol=1e-6)
    np.testing.assert_allclose(h_t.E_k, h_l.E_k, rtol=1e-6)
    assert len(h_t.selected) == len(h_l.selected) == 4
    for a, b in zip(h_t.selected, h_l.selected):
        np.testing.assert_array_equal(a, b)
    # the synced-back host state matches too (params, clusters, key stream)
    np.testing.assert_array_equal(traced.cluster_labels,
                                  legacy.cluster_labels)
    for lt, ll in zip(jax.tree_util.tree_leaves(traced.global_params),
                      jax.tree_util.tree_leaves(legacy.global_params)):
        np.testing.assert_allclose(np.asarray(lt), np.asarray(ll),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(traced.key),
                                  np.asarray(legacy.key))


@pytest.mark.slow
def test_scan_path_history_is_python_floats():
    exp = build_experiment(ExperimentSpec(**TINY))
    hist = exp.run(rounds=1)
    assert all(type(a) is float for a in hist.accuracy)
    assert all(type(t) is float for t in hist.T_k)
    assert all(type(e) is float for e in hist.E_k)


@pytest.mark.slow
def test_target_accuracy_falls_back_to_python_loop():
    # early stopping needs the host loop; an impossible target runs all
    # rounds there, and history values still land as floats (bugfix)
    exp = build_experiment(ExperimentSpec(**TINY))
    hist = exp.run(rounds=2, target_accuracy=0.01)
    assert hist.rounds_to_target == 1
    assert all(type(t) is float for t in hist.T_k)


# ---------------------------------------------------------------------------
# traced selector parity vs the numpy versions
# ---------------------------------------------------------------------------


def test_traced_divergence_matches_numpy():
    rng = np.random.default_rng(0)
    N, c, s = 12, 3, 2
    div = rng.uniform(0.1, 5.0, N)
    labels = rng.integers(0, c, N)
    clusters = [np.flatnonzero(labels == i) for i in range(c)]
    want = sel.select_divergence(div, clusters, s=s)
    idx, mask = select_divergence_traced(
        jnp.asarray(div, jnp.float32), jnp.asarray(labels),
        num_clusters=c, s=s, num_devices=N)
    got = np.asarray(idx)[np.asarray(mask)]
    np.testing.assert_array_equal(got, want)


def test_traced_divergence_pads_small_clusters():
    div = jnp.asarray([3.0, 1.0, 2.0])
    labels = jnp.asarray([0, 0, 1])           # cluster 2 empty
    idx, mask = select_divergence_traced(div, labels, num_clusters=3, s=2,
                                         num_devices=3)
    assert idx.shape == (6,)
    np.testing.assert_array_equal(np.asarray(mask),
                                  [True, True, True, False, False, False])
    # padding lanes hold the out-of-bounds sentinel (scatters drop them)
    assert np.all(np.asarray(idx)[~np.asarray(mask)] == 3)
    np.testing.assert_array_equal(np.asarray(idx)[np.asarray(mask)],
                                  [0, 1, 2])


def test_traced_random_and_kmeans_random_structural():
    key = jax.random.PRNGKey(0)
    idx, mask = select_random_traced(key, num_devices=20, S=6)
    assert bool(np.all(np.asarray(mask)))
    got = np.asarray(idx)
    assert len(np.unique(got)) == 6 and got.min() >= 0 and got.max() < 20

    labels = jnp.asarray(np.arange(20) % 4)
    idx, mask = select_kmeans_random_traced(key, labels, num_clusters=4,
                                            s=1, num_devices=20)
    got = np.asarray(idx)[np.asarray(mask)]
    assert len(got) == 4
    # one pick per cluster, emitted in cluster order, member of its cluster
    np.testing.assert_array_equal(np.asarray(labels)[got], np.arange(4))


def test_traced_icas_matches_numpy():
    fleet = sample_fleet(16, seed=3)
    arr = fleet_arrays(fleet)
    rng = np.random.default_rng(1)
    div = rng.uniform(0.5, 4.0, 16)
    from repro.core.wireless import rate_mbps
    rates = np.asarray(rate_mbps(20.0 / 16, arr["J"]))
    want = sel.select_icas(div, rates, 5, beta=0.5)
    idx, mask = select_icas_traced(jnp.asarray(div, jnp.float32), arr,
                                   bandwidth_mhz=20.0, num_devices=16, S=5,
                                   beta=0.5)
    assert bool(np.all(np.asarray(mask)))
    np.testing.assert_array_equal(np.asarray(idx), want)


def test_traced_rra_masked_and_nonempty():
    fleet = sample_fleet(30, seed=0)
    arr = fleet_arrays(fleet)
    sizes = set()
    for i in range(8):
        idx, mask = select_rra_traced(jax.random.PRNGKey(i), arr,
                                      bandwidth_mhz=20.0, num_devices=30,
                                      target_mean=15)
        m = np.asarray(mask)
        got = np.asarray(idx)
        assert m.sum() > 0
        np.testing.assert_array_equal(got[m], np.flatnonzero(m))
        assert np.all(got[~m] == 30)           # sentinel on padding
        sizes.add(int(m.sum()))
    assert len(sizes) > 1                      # set size varies per round


# ---------------------------------------------------------------------------
# select_rra regression: target_mean >= N must not degenerate (bugfix)
# ---------------------------------------------------------------------------


def test_select_rra_target_above_population_not_degenerate():
    rng = np.random.default_rng(3)
    e_eq = rng.uniform(0.001, 0.05, 10)
    e_b = rng.uniform(0.03, 0.06, 10)
    sizes = [len(sel.select_rra(rng, e_eq, e_b, target_mean=45))
             for _ in range(30)]
    assert all(s > 0 for s in sizes)
    # pre-fix: the unclamped target_mean/p.sum() factor pushed every
    # participation probability past 1 -> all 10 devices, every round
    assert any(s < 10 for s in sizes)
    assert len(set(sizes)) > 1


# ---------------------------------------------------------------------------
# masked + batched SAO invariance
# ---------------------------------------------------------------------------


def test_solve_sao_masked_padding_matches_unpadded():
    fleet = sample_fleet(6, seed=1)
    arr = fleet_arrays(fleet)
    want = solve_sao(arr, 20.0)
    # pad with two duplicated (masked-out) lanes
    pad = {k: jnp.concatenate([v, v[:2]]) for k, v in arr.items()}
    mask = jnp.asarray([True] * 6 + [False] * 2)
    got = solve_sao(pad, 20.0, mask=mask)
    np.testing.assert_allclose(float(got.T), float(want.T), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got.b[:6]), np.asarray(want.b),
                               rtol=1e-4, atol=1e-5)
    assert np.all(np.asarray(got.b[6:]) == 0.0)
    assert np.all(np.asarray(got.f[6:]) == 0.0)


def test_vmapped_sao_matches_per_fleet_solves():
    arrs = [fleet_arrays(sample_fleet(8, seed=s)) for s in range(3)]
    stacked = {k: jnp.stack([a[k] for a in arrs]) for k in arrs[0]}
    batched = jax.vmap(lambda a: solve_sao(a, 20.0))(stacked)
    for i, a in enumerate(arrs):
        single = solve_sao(a, 20.0)
        np.testing.assert_allclose(float(batched.T[i]), float(single.T),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(batched.b[i]),
                                   np.asarray(single.b), rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(batched.f[i]),
                                   np.asarray(single.f), rtol=1e-4,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# cohort runner: vmapped seeds ≡ per-seed single runs
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_cohort_matches_per_seed_runs():
    spec = ExperimentSpec(**TINY, cohort=2, data_seed=7, test_seed=90_000)
    runner = build_cohort(spec)
    ch = runner.run()
    assert ch.accuracy.shape == (2, TINY["rounds"] + 1)
    for i, seed in enumerate(ch.seeds):
        single = build_experiment(spec.replace(seed=seed)).run()
        hi = ch.history(i)
        assert hi.accuracy == single.accuracy
        np.testing.assert_allclose(hi.T_k, single.T_k, rtol=1e-6)
        np.testing.assert_allclose(hi.E_k, single.E_k, rtol=1e-6)
        for a, b in zip(hi.selected, single.selected):
            np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_cohort_rejects_untraceable_bundle():
    # every built-in allocator is traceable now (FEDL's λ tuning moved into
    # the scan), so pin the rejection path with an ad-hoc host-only one
    from dataclasses import dataclass

    from repro.api import ALLOCATORS, Strategy

    @ALLOCATORS.register("test_host_only")
    @dataclass(frozen=True)
    class HostOnly(Strategy):
        traceable = False

        def allocate(self, arr, B):
            raise NotImplementedError

    try:
        spec = ExperimentSpec(**TINY, cohort=2, allocator="test_host_only")
        with pytest.raises(ValueError, match="all-traceable"):
            build_cohort(spec).run()
    finally:
        ALLOCATORS._classes.pop("test_host_only")
