"""Roofline machinery: HLO collective parsing + cost-analysis semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import collective_bytes, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16", "8,128") == 8 * 128 * 2
    assert _shape_bytes("f32", "4,4,4") == 64 * 4
    assert _shape_bytes("pred", "10") == 10
    assert _shape_bytes("f32", "") == 4          # scalar


def test_collective_parser_on_canned_hlo():
    hlo = """
  %ag.1 = bf16[8,256]{1,0} all-gather(bf16[8,16]{1,0} %p0), replica_groups={}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%add
  %rs = f32[16,8]{1,0} reduce-scatter(f32[16,128]{1,0} %y), dimensions={1}
  %a2a = bf16[4,32]{1,0} all-to-all(bf16[4,32]{1,0} %z), dimensions={0}
  %cp = u32[2]{0} collective-permute(u32[2]{0} %w), source_target_pairs={{0,1}}
  %other = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 256 * 2
    assert out["all-reduce"] == 128 * 4
    assert out["reduce-scatter"] == 16 * 8 * 4
    assert out["all-to-all"] == 4 * 32 * 2
    assert out["collective-permute"] == 2 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_async_start_done_counted_once():
    hlo = """
  %ags = (bf16[8,16]{1,0}, bf16[8,256]{1,0}) all-gather-start(bf16[8,16]{1,0} %p0)
  %agd = bf16[8,256]{1,0} all-gather-done((bf16[8,16]{1,0}, bf16[8,256]{1,0}) %ags)
"""
    out = collective_bytes(hlo)
    # only the -start line is counted (both tuple members)
    assert out["counts"]["all-gather"] == 1


def test_cost_analysis_flops_exact_matmul():
    """cost_analysis flops == 2·M·N·K for a plain matmul."""
    M, N, K = 64, 32, 128
    f = jax.jit(lambda a, b: a @ b)
    lowered = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                      jax.ShapeDtypeStruct((K, N), jnp.float32))
    cost = lowered.compile().cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    assert cost["flops"] == pytest.approx(2 * M * N * K, rel=0.01)


def test_cost_analysis_undercounts_scan_loops():
    """Documents WHY the dry-run needs the unrolled roofline twin: a scan
    body is counted once, not × trip count."""
    M = 64
    w = jax.ShapeDtypeStruct((M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((8, M), jnp.float32)

    def scanned(ws, x):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    def unrolled(ws, x):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws, unroll=10)[0]

    ws = jax.ShapeDtypeStruct((10, M, M), jnp.float32)
    cost_s = jax.jit(scanned).lower(ws, x).compile().cost_analysis()
    cost_u = jax.jit(unrolled).lower(ws, x).compile().cost_analysis()
    if isinstance(cost_s, list):
        cost_s, cost_u = cost_s[0], cost_u[0]
    body = 2 * 8 * M * M
    assert cost_u["flops"] >= 10 * body * 0.99
    assert cost_s["flops"] <= 2 * body            # loop counted ~once


def test_model_flops_formula():
    from repro.configs import get_config
    from repro.configs.base import INPUT_SHAPES
    from repro.roofline.analysis import model_flops
    cfg = get_config("tinyllama-1.1b")
    mf_train = model_flops(cfg, INPUT_SHAPES["train_4k"], include_backward=True)
    n = cfg.num_params()
    assert mf_train == pytest.approx(6 * n * 256 * 4096, rel=1e-6)
    mf_dec = model_flops(cfg, INPUT_SHAPES["decode_32k"], include_backward=False)
    assert mf_dec == pytest.approx(2 * n * 128, rel=1e-6)
    # MoE counts ACTIVE params only
    moe = get_config("mixtral-8x22b")
    assert moe.num_params(active_only=True) < 0.5 * moe.num_params()


def test_roofline_report_bottleneck():
    from repro.roofline.analysis import RooflineReport
    r = RooflineReport(arch="x", shape="train_4k", mesh="single", chips=256,
                       flops_per_device=197e12,            # exactly 1 s
                       bytes_per_device=819e9 * 2,         # 2 s -> memory
                       collective_bytes_per_device=50e9 * 0.5,
                       model_flops_global=197e12 * 256)
    assert r.bottleneck == "memory"
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.useful_ratio == pytest.approx(1.0)
