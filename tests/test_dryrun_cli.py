"""End-to-end dry-run CLI guard: one (arch × shape × mesh) combo lowers and
compiles in a fresh subprocess (the 512-device XLA flag must only ever be
set there, never in this test process)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_dryrun_single_combo(tmp_path):
    out = tmp_path / "dryrun.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "tinyllama-1.1b", "--shape", "decode_32k",
         "--mesh", "single", "--no-twin", "--out", str(out)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["arch"] == "tinyllama-1.1b"
    assert rec["chips"] == 256
    assert rec["peak_memory_per_device"] < 16e9      # decode fits v5e HBM


def test_this_process_sees_one_device():
    """The CPU test environment must never inherit the 512-device flag."""
    import jax
    assert len(jax.devices()) == 1
