"""FL loop integration + aggregation/selection/divergence properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st, HealthCheck

from repro.core import selection as sel
from repro.core.divergence import weight_divergence
from repro.utils.trees import (tree_weighted_mean, tree_weighted_mean_stacked,
                               tree_flatten_vector)

slow = settings(deadline=None, max_examples=15,
                suppress_health_check=list(HealthCheck))


# ---------------------------------------------------------------------------
# eq. (4) aggregation
# ---------------------------------------------------------------------------


@slow
@given(seed=st.integers(0, 30), n=st.integers(2, 8))
def test_weighted_mean_matches_manual(seed, n):
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    trees = [{"a": jax.random.normal(k, (3, 4)), "b": jax.random.normal(k, (2,))}
             for k in keys]
    w = np.abs(np.random.default_rng(seed).uniform(1, 100, n))
    agg = tree_weighted_mean(trees, w)
    manual = sum(wi * np.asarray(t["a"]) for wi, t in zip(w, trees)) / w.sum()
    np.testing.assert_allclose(np.asarray(agg["a"]), manual,
                               rtol=1e-4, atol=1e-5)


def test_stacked_equals_list_aggregation():
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    trees = [{"w": jax.random.normal(k, (4, 4))} for k in keys]
    stacked = {"w": jnp.stack([t["w"] for t in trees])}
    w = np.array([1.0, 2.0, 3.0, 4.0, 5.0])
    a = tree_weighted_mean(trees, w)
    b = tree_weighted_mean_stacked(stacked, w)
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               rtol=1e-5)


def test_aggregation_idempotent_on_identical_models():
    t = {"w": jnp.ones((3, 3)) * 2.5}
    stacked = {"w": jnp.stack([t["w"]] * 4)}
    agg = tree_weighted_mean_stacked(stacked, np.array([1, 7, 3, 2.0]))
    np.testing.assert_allclose(np.asarray(agg["w"]), 2.5, rtol=1e-6)


# ---------------------------------------------------------------------------
# weight divergence (Alg. 4 signal)
# ---------------------------------------------------------------------------


def test_weight_divergence_matches_flat_norm():
    g = {"a": jnp.ones((3, 2)), "b": jnp.zeros((4,))}
    clients = {"a": jnp.stack([jnp.ones((3, 2)), 3 * jnp.ones((3, 2))]),
               "b": jnp.stack([jnp.zeros((4,)), 2 * jnp.ones((4,))])}
    d = weight_divergence(clients, g)
    assert float(d[0]) == pytest.approx(0.0, abs=1e-6)
    want = np.sqrt(6 * 4.0 + 4 * 4.0)
    assert float(d[1]) == pytest.approx(want, rel=1e-5)


# ---------------------------------------------------------------------------
# selection policies
# ---------------------------------------------------------------------------


def test_select_divergence_picks_top_per_cluster():
    div = np.array([0.1, 5.0, 0.2, 9.0, 0.3, 1.0])
    clusters = [np.array([0, 1]), np.array([2, 3]), np.array([4, 5])]
    idx = sel.select_divergence(div, clusters, s=1)
    assert sorted(idx.tolist()) == [1, 3, 5]


def test_select_divergence_top_s():
    div = np.array([3.0, 2.0, 1.0, 9.0])
    idx = sel.select_divergence(div, [np.arange(4)], s=2)
    assert sorted(idx.tolist()) == [0, 3]


def test_select_kmeans_random_one_per_cluster():
    rng = np.random.default_rng(0)
    clusters = [np.array([0, 1, 2]), np.array([3]), np.array([], np.int64)]
    idx = sel.select_kmeans_random(rng, clusters, s=1)
    assert len(idx) == 2
    assert idx[0] in (0, 1, 2) and idx[1] == 3


def test_select_random_no_replacement():
    rng = np.random.default_rng(1)
    idx = sel.select_random(rng, 100, 10)
    assert len(np.unique(idx)) == 10


def test_select_icas_prefers_high_importance_and_rate():
    u = np.array([1.0, 10.0, 1.0, 10.0])
    r = np.array([1.0, 1.0, 10.0, 10.0])
    idx = sel.select_icas(u, r, 1)
    assert idx[0] == 3


def test_select_rra_nonempty_varying():
    rng = np.random.default_rng(2)
    e_eq = np.abs(rng.uniform(0.001, 0.05, 100))
    e_b = np.abs(rng.uniform(0.03, 0.06, 100))
    sizes = {len(sel.select_rra(rng, e_eq, e_b)) for _ in range(10)}
    assert all(s > 0 for s in sizes)
    assert len(sizes) > 1                      # set size varies round-to-round


# ---------------------------------------------------------------------------
# mini end-to-end: divergence selection helps on pathological non-iid
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fl_round_mechanics():
    from repro.configs.base import FLConfig
    from repro.configs.paper_cnn import CNN_CONFIGS
    from repro.core import FLExperiment, sample_fleet
    from repro.data import make_dataset, partition_bias
    ds = make_dataset("fashion", 1200, seed=0)
    fed = partition_bias(ds, 20, 64, 0.8, seed=1)
    fleet = sample_fleet(20, seed=0)
    fl = FLConfig(num_devices=20, devices_per_round=10, local_iters=10,
                  num_clusters=10, learning_rate=0.08)
    exp = FLExperiment(CNN_CONFIGS["fashion"], fed, ds.images[:200],
                       ds.labels[:200], fleet, fl, seed=0)
    hist = exp.run("divergence", rounds=3)
    assert len(hist.accuracy) == 4                 # initial + 3
    assert len(hist.T_k) == 4
    assert all(t > 0 for t in hist.T_k)
    assert all(e > 0 for e in hist.E_k)
    # clusters partition all clients
    assert sorted(np.concatenate(exp.clusters).tolist()) == list(range(20))
    # selected sets have one device per non-empty cluster
    sel_idx = hist.selected[-1]
    assert len(sel_idx) == len([c for c in exp.clusters if len(c)])
