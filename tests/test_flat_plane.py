"""Flat parameter plane: spec round-trips, flat ≡ pytree parity pins
(fedavg bit-identical, fedavgm/compressors tolerance), kernel-vs-ref
parity for ``flat_aggregate``, and donated-carry semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# the explicit use_pallas=True calls below are deliberate interpret-mode
# validation runs — the dispatch guard's off-TPU warning is expected noise
pytestmark = pytest.mark.filterwarnings(
    "ignore:.*interpret mode.*:RuntimeWarning")

from repro.api import ExperimentSpec, build_experiment
from repro.core.clustering import extract_features, extract_features_flat
from repro.core.divergence import weight_divergence, weight_divergence_flat
from repro.kernels import ops, ref
from repro.kernels.flat_aggregate import flat_aggregate
from repro.utils.trees import (flatten_stacked, stack_flatten_spec,
                               tree_flatten_vector,
                               tree_weighted_mean_stacked, unflatten_rows,
                               unflatten_vector)

TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=2, devices_per_round=4, num_clusters=4,
            learning_rate=0.05)


def _stacked_tree(key, n=6):
    ks = jax.random.split(key, 4)
    return {
        "w_a": jax.random.normal(ks[0], (n, 3, 4)),
        "b_a": jax.random.normal(ks[1], (n, 4)),
        "w_b": jax.random.normal(ks[2], (n, 4, 2)),
        "b_b": jax.random.normal(ks[3], (n, 2)),
    }


def _template(stacked):
    return jax.tree_util.tree_map(lambda l: l[0], stacked)


# ---------------------------------------------------------------------------
# spec + flatten/unflatten round-trips
# ---------------------------------------------------------------------------


def test_spec_roundtrip_rows_and_vector():
    stacked = _stacked_tree(jax.random.PRNGKey(0))
    spec = stack_flatten_spec(_template(stacked))
    assert spec.total == 3 * 4 + 4 + 4 * 2 + 2
    rows = flatten_stacked(stacked)
    assert rows.shape == (6, spec.total)
    back = unflatten_rows(spec, rows)
    for a, b in zip(jax.tree_util.tree_leaves(stacked),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    vec = tree_flatten_vector(_template(stacked))
    np.testing.assert_array_equal(np.asarray(rows[0]), np.asarray(vec))
    one = unflatten_vector(spec, vec)
    for a, b in zip(jax.tree_util.tree_leaves(_template(stacked)),
                    jax.tree_util.tree_leaves(one)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spec_nested_names_are_path_unique():
    tree = {"block1": {"w": jnp.zeros((2, 3))},
            "block2": {"w": jnp.zeros((3,))}}
    spec = stack_flatten_spec(tree)
    assert spec.names == ("block1/w", "block2/w")
    assert spec.columns("block1/w") == slice(0, 6)
    assert spec.columns("block2/w") == slice(6, 9)


def test_spec_is_hashable_and_column_slices_match_leaves():
    stacked = _stacked_tree(jax.random.PRNGKey(1))
    spec = stack_flatten_spec(_template(stacked))
    hash(spec)                          # trace-time constant
    rows = flatten_stacked(stacked)
    for name in spec.names:
        want = stacked[name].reshape(6, -1)
        np.testing.assert_array_equal(
            np.asarray(rows[:, spec.columns(name)]), np.asarray(want))


# ---------------------------------------------------------------------------
# flat ops ≡ pytree ops
# ---------------------------------------------------------------------------


def test_flat_aggregate_matches_tree_weighted_mean_bitwise():
    stacked = _stacked_tree(jax.random.PRNGKey(2))
    w = jnp.asarray(np.random.default_rng(0).uniform(1.0, 9.0, 6),
                    jnp.float32)
    tree_avg = tree_weighted_mean_stacked(stacked, w)
    flat_avg = ops.flat_aggregate(flatten_stacked(stacked), w)
    np.testing.assert_array_equal(
        np.asarray(tree_flatten_vector(tree_avg)), np.asarray(flat_avg))


def test_flat_aggregate_mask_drops_padding_lanes():
    stacked = _stacked_tree(jax.random.PRNGKey(3))
    rows = flatten_stacked(stacked)
    w = jnp.arange(1.0, 7.0)
    want = ops.flat_aggregate(rows[:4], w[:4])
    mask = jnp.asarray([True] * 4 + [False] * 2)
    got = ops.flat_aggregate(rows, w, mask=mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_flat_aggregate_all_masked_yields_zeros_not_nan():
    rows = flatten_stacked(_stacked_tree(jax.random.PRNGKey(9)))
    w = jnp.arange(1.0, 7.0)
    out = ops.flat_aggregate(rows, w, mask=jnp.zeros((6,), bool))
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_extract_features_flat_resolves_nested_bare_names():
    tree = {"block": {"w_fc2": jnp.arange(12.0).reshape(2, 2, 3),
                      "b": jnp.zeros((2, 2))}}
    spec = stack_flatten_spec(jax.tree_util.tree_map(lambda l: l[0], tree))
    rows = flatten_stacked(tree)
    got = extract_features_flat(rows, "w_fc2", spec)      # bare name
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(tree["block"]["w_fc2"]
                                             .reshape(2, -1)))
    auto = extract_features_flat(rows, "auto", spec)      # auto -> w_fc2
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(got))
    with pytest.raises(KeyError):
        extract_features_flat(rows, "nope", spec)


def test_weight_divergence_flat_matches_tree():
    stacked = _stacked_tree(jax.random.PRNGKey(4))
    g = _template(_stacked_tree(jax.random.PRNGKey(5)))
    want = weight_divergence(stacked, g)
    got = weight_divergence_flat(flatten_stacked(stacked),
                                 tree_flatten_vector(g))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_extract_features_flat_matches_tree():
    stacked = _stacked_tree(jax.random.PRNGKey(6))
    spec = stack_flatten_spec(_template(stacked))
    rows = flatten_stacked(stacked)
    for layer in ("w_a", "b_b", "all"):
        want = extract_features(stacked, layer)
        got = extract_features_flat(rows, layer, spec)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # auto falls back to the last leaf for non-CNN trees
    np.testing.assert_array_equal(
        np.asarray(extract_features_flat(rows, "auto", spec)),
        np.asarray(rows[:, spec.columns(spec.names[-1])]))


@pytest.mark.parametrize("name", ["int8", "topk:0.05"])
def test_compressor_apply_flat_matches_tree(name):
    from repro.api import COMPRESSORS
    comp = COMPRESSORS.resolve(name)
    stacked = _stacked_tree(jax.random.PRNGKey(7))
    g = _template(_stacked_tree(jax.random.PRNGKey(8)))
    spec = stack_flatten_spec(g)
    want = flatten_stacked(comp.apply(stacked, g))
    got = comp.apply_flat(flatten_stacked(stacked),
                          tree_flatten_vector(g), spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# pairwise dedupe: ops.pairwise_sq_dists is THE implementation
# ---------------------------------------------------------------------------


def test_pairwise_sq_dists_clamped_nonnegative():
    # near-identical points make the ‖x‖²+‖c‖²−2x·c expansion go negative
    # without the clamp
    x = jnp.ones((5, 64)) * 1e3 + jax.random.normal(
        jax.random.PRNGKey(0), (5, 64)) * 1e-4
    d = ops.pairwise_sq_dists(x, x)
    assert float(jnp.min(d)) >= 0.0
    from repro.core.clustering import _pairwise_sq_dists
    assert float(jnp.min(_pairwise_sq_dists(x, x))) >= 0.0
    from repro.core.divergence import pairwise_divergence_matrix
    m = pairwise_divergence_matrix(x)
    assert np.all(np.isfinite(np.asarray(m)))


def test_pairwise_sq_dists_matches_oracle():
    kx, kc = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (17, 33))
    c = jax.random.normal(kc, (5, 33))
    np.testing.assert_allclose(np.asarray(ops.pairwise_sq_dists(x, c)),
                               np.asarray(ref.pairwise_l2_ref(x, c)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flat_aggregate kernel: Pallas (interpret) vs jnp reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,p", [(7, 33), (100, 777), (128, 512),
                                 (65, 1000), (1, 8), (10, 2240)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flat_aggregate_kernel_matches_ref(n, p, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(n * 100 + p))
    flat = jax.random.normal(kx, (n, p), dtype)
    w = jax.random.uniform(kw, (n,), jnp.float32)
    out = flat_aggregate(flat, w)
    want = ref.flat_aggregate_ref(flat, w)
    tol = dict(rtol=3e-2, atol=3e-1) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **tol)


def test_ops_flat_aggregate_pallas_path_interpret():
    flat = jax.random.normal(jax.random.PRNGKey(0), (20, 300))
    w = jnp.arange(1.0, 21.0)
    got = ops.flat_aggregate(flat, w, use_pallas=True)
    want = ops.flat_aggregate(flat, w, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_ops_client_divergence_pallas_path_interpret():
    flat = jax.random.normal(jax.random.PRNGKey(1), (12, 200))
    g = jax.random.normal(jax.random.PRNGKey(2), (200,))
    got = ops.client_divergence(flat, g, use_pallas=True)
    want = ops.client_divergence(flat, g, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# engine-level parity: flat traced pipeline ≡ pytree host loop
# ---------------------------------------------------------------------------


def _legacy(exp):
    exp.traceable = lambda *a, **k: False
    return exp


@pytest.mark.slow
@pytest.mark.parametrize("kw,exact", [
    (dict(), True),                                   # fedavg: bit-identical
    (dict(aggregator="fedavgm:0.9"), False),          # fedavgm: tolerance
    (dict(compressor="int8"), False),                 # compressors: tolerance
    (dict(compressor="topk:0.05"), False),
])
def test_flat_traced_matches_pytree_host_loop(kw, exact):
    spec = ExperimentSpec(**TINY, **kw)
    traced = build_experiment(spec)
    assert traced.traceable()
    h_t = traced.run(rounds=2)
    h_l = _legacy(build_experiment(spec)).run(rounds=2)
    if exact:
        assert h_t.accuracy == h_l.accuracy
    else:
        np.testing.assert_allclose(h_t.accuracy, h_l.accuracy,
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_t.T_k, h_l.T_k, rtol=1e-6)
    np.testing.assert_allclose(h_t.E_k, h_l.E_k, rtol=1e-6)
    for a, b in zip(h_t.selected, h_l.selected):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_host_state_stays_pytree_after_traced_run():
    exp = build_experiment(ExperimentSpec(**TINY))
    exp.run(rounds=1)
    # global params sync back as the named-leaf pytree...
    assert set(exp.global_params.keys()) == {
        "w_c1", "b_c1", "w_c2", "b_c2", "w_fc1", "b_fc1", "w_fc2", "b_fc2"}
    # ...while the client plane is the flat [N, P] buffer
    assert exp.client_params.ndim == 2
    assert exp.client_params.shape[0] == TINY["clients"]
    assert exp.client_params.shape[1] == exp.engine.flat_spec.total
    # and the pytree view round-trips
    tree = exp.client_tree()
    np.testing.assert_array_equal(
        np.asarray(flatten_stacked(tree)), np.asarray(exp.client_params))


@pytest.mark.slow
def test_pre_flat_contract_aggregator_falls_back_to_host_loop():
    # a strategy written against the pre-flat stacked contract (traceable
    # but no aggregate_flat) must fall back to the host loop, not crash
    # mid-trace on a missing flat method
    from dataclasses import dataclass

    from repro.api import AGGREGATORS, Strategy
    from repro.utils.trees import tree_weighted_mean_stacked

    @AGGREGATORS.register("test_stacked_only")
    @dataclass
    class StackedOnly(Strategy):
        traceable = True
        fuses_with_engine = False

        def aggregate(self, global_params, stacked_params, weights):
            return tree_weighted_mean_stacked(stacked_params, weights)

        def reset(self):
            pass

    try:
        exp = build_experiment(
            ExperimentSpec(**TINY, aggregator="test_stacked_only"))
        assert not exp.traceable()
        hist = exp.run(rounds=1)
        assert len(hist.accuracy) == 2
    finally:
        AGGREGATORS._classes.pop("test_stacked_only")


@pytest.mark.slow
def test_client_features_all_survives_next_round():
    exp = build_experiment(ExperimentSpec(**TINY))
    exp.run(rounds=1)
    feats = exp.client_features("all")      # view of the whole plane
    exp.run(rounds=1)                       # donates the old buffer
    assert not feats.is_deleted()
    float(feats[0, 0])


@pytest.mark.slow
def test_round_result_survives_next_donated_round():
    # round_step donates the global params; an earlier RoundResult must
    # hold a COPY, not the buffers the next round consumes — and
    # stacked_params is flat [S, P] rows on every configuration
    exp = _legacy(build_experiment(ExperimentSpec(**TINY)))
    exp.initial_round()
    r1 = exp.round()
    exp.round()
    leaf = jax.tree_util.tree_leaves(r1.params)[0]
    assert not leaf.is_deleted()
    float(leaf.reshape(-1)[0])
    assert r1.stacked_params.ndim == 2
    assert r1.stacked_params.shape[1] == exp.engine.flat_spec.total


@pytest.mark.slow
def test_traced_state_is_donated_and_rebound():
    exp = build_experiment(ExperimentSpec(**TINY))
    buf_before = exp.client_params
    exp.run(rounds=1)
    # the old buffer was consumed by the donated carry...
    assert buf_before.is_deleted()
    # ...and the driver rebound a live result
    assert not exp.client_params.is_deleted()
    float(exp.client_params[0, 0])
