"""Buffered-asynchronous tick engine: fedbuff registry/spec round-trips,
the sync-degeneracy parity pin, staleness accounting, client churn (masked
selection, dynamic active set, the all-departed empty-fire no-op), the
stochastic-sched selector, and the staleness-weight property suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AGGREGATORS, SELECTORS, ExperimentSpec,
                       StrategyError, build_cohort, build_experiment)
from repro.core.async_engine import parse_churn
from repro.core.store import ClientStats
from repro.core.wireless import completion_times, sample_fleet, fleet_arrays
from repro.strategies.traced import select_stochastic_sched_traced
from tests.hypothesis_compat import given, settings, st

TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=2, devices_per_round=4, num_clusters=4,
            learning_rate=0.05)


# ---------------------------------------------------------------------------
# registry / spec plumbing
# ---------------------------------------------------------------------------


def test_fedbuff_resolve_and_validation():
    agg = AGGREGATORS.resolve("fedbuff:4:0.5")
    assert agg.m == 4 and agg.alpha == 0.5
    assert agg.async_capable and agg.traceable
    assert agg.buffer_size == 4 and agg.staleness_alpha == 0.5
    assert AGGREGATORS.resolve("fedbuff:3").alpha == 0.0
    assert AGGREGATORS.resolve("fedbuff").m == 10
    with pytest.raises(StrategyError, match=">= 1"):
        AGGREGATORS.resolve("fedbuff:0")
    with pytest.raises(StrategyError, match=">= 0"):
        AGGREGATORS.resolve("fedbuff:4:-1")
    with pytest.raises(StrategyError, match="M"):
        AGGREGATORS.resolve("fedbuff:x")
    # synchronous aggregators do not advertise the async contract
    assert not getattr(AGGREGATORS.resolve("fedavg"), "async_capable", False)


def test_fedbuff_spec_round_trip():
    spec = ExperimentSpec(**TINY, aggregator="fedbuff:4:0.5",
                          churn_leave=0.1, churn_join=0.2)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert back.aggregator == {"name": "fedbuff",
                               "params": {"m": 4, "alpha": 0.5}}
    assert back.churn_leave == 0.1 and back.churn_join == 0.2


def test_parse_churn():
    assert parse_churn(None) == (0.0, 0.0)
    assert parse_churn("0.3") == (0.3, 0.0)
    assert parse_churn("0.3:0.1") == (0.3, 0.1)
    assert parse_churn((0.2, 0.4)) == (0.2, 0.4)
    assert parse_churn(0.5) == (0.5, 0.0)
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        parse_churn("1.5")
    with pytest.raises(ValueError, match="numeric"):
        parse_churn("often")
    with pytest.raises(ValueError):
        parse_churn((0.1, 0.2, 0.3))


def test_churn_requires_async_aggregator():
    with pytest.raises(ValueError, match="async"):
        build_experiment(ExperimentSpec(**TINY, churn_leave=0.5))


def test_completion_times_masks_to_inf():
    arr = fleet_arrays(sample_fleet(4, seed=0))
    b = jnp.full((4,), 5.0)
    f = jnp.full((4,), 1.0)
    d = np.asarray(completion_times(arr, b, f))
    assert np.isfinite(d).all() and (d > 0).all()
    mask = jnp.array([True, False, True, False])
    dm = np.asarray(completion_times(arr, b, f, mask))
    assert np.isfinite(dm[[0, 2]]).all()
    assert np.isinf(dm[[1, 3]]).all()


# ---------------------------------------------------------------------------
# the sync-degeneracy parity pin
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fedbuff_full_buffer_is_sync_fedavg_bit_identical():
    """Parity pin: fedbuff with the buffer >= the padded selection size,
    alpha=0 and no churn degenerates to the synchronous scanned fedavg
    round — the tick is built from the same phase closures, so the whole
    history matches bit for bit."""
    h_sync = build_experiment(ExperimentSpec(**TINY)).run()
    # M=8 >= pad (num_clusters * selected_per_cluster = 4) on 8 clients
    h_buf = build_experiment(
        ExperimentSpec(**TINY, aggregator="fedbuff:8:0")).run()
    assert h_sync.accuracy == h_buf.accuracy
    assert h_sync.T_k == h_buf.T_k
    assert h_sync.E_k == h_buf.E_k
    assert all(np.array_equal(a, b)
               for a, b in zip(h_sync.selected, h_buf.selected))


# ---------------------------------------------------------------------------
# staleness accounting
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_small_buffer_builds_staleness():
    """M=1 on a pad-4 selection leaves stragglers in flight: the mean
    fired-age trace must become positive, while every tick still folds
    exactly one update and the full fleet stays active (no churn)."""
    spec = ExperimentSpec(**{**TINY, "rounds": 3},
                          aggregator="fedbuff:1:0.5", cohort=2)
    ch = build_cohort(spec).run(transfer_guard=True)
    assert ch.participation.shape == ch.staleness.shape == (2, 3)
    assert (ch.participation >= 1).all()
    assert ch.staleness.max() > 0
    assert (ch.active == TINY["clients"]).all()
    assert np.isfinite(ch.accuracy).all()
    # sync runs don't grow the traces
    ch_sync = build_cohort(ExperimentSpec(**TINY, cohort=2)).run()
    assert ch_sync.participation is None and ch_sync.staleness is None


@pytest.mark.slow
def test_async_state_persists_across_runs():
    """Incremental run() calls continue the virtual clock: the scheduler
    columns ride the store's ClientStats table (the single source of
    per-client truth) across the host boundary."""
    exp = build_experiment(ExperimentSpec(**TINY, aggregator="fedbuff:2"))
    assert isinstance(exp.stats, ClientStats)
    assert exp.stats is exp.store.stats
    assert float(exp.stats.t_now) == 0.0
    exp.run(rounds=1)
    t1 = float(exp.stats.t_now)
    assert t1 > 0.0
    exp.run(rounds=1, include_initial_round=False)
    assert float(exp.stats.t_now) >= t1


# ---------------------------------------------------------------------------
# churn
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_churn_never_selects_unavailable_clients():
    """Masked-selection regression: the engine's post-filter drops
    unavailable (and in-flight) clients from the dispatched set. The churn
    step precedes selection inside the tick and availability does not
    change afterwards, so after each single-tick run the final
    ``stats.avail`` IS the mask the selector saw."""
    exp = build_experiment(ExperimentSpec(
        **TINY, aggregator="fedbuff:2", selection="stochastic-sched",
        churn_leave=0.4, churn_join=0.4))
    hist = exp.run(rounds=1)
    for _ in range(4):
        h = exp.run(rounds=1, include_initial_round=False)
        avail_idx = set(np.flatnonzero(exp.stats.avail).tolist())
        assert {int(i) for i in h.selected[-1]} <= avail_idx
        # in-flight bookkeeping never touches unavailable clients
        assert np.isinf(exp.stats.t_done[~exp.stats.avail]).all()
    assert hist is not None


@pytest.mark.slow
def test_churn_dynamic_active_set():
    spec = ExperimentSpec(**{**TINY, "rounds": 4},
                          aggregator="fedbuff:2", cohort=2,
                          churn_leave=0.3, churn_join=0.3)
    ch = build_cohort(spec).run(transfer_guard=True)
    assert ch.active.shape == (2, 4)
    assert ch.active.min() < TINY["clients"]      # somebody left
    assert np.isfinite(ch.accuracy).all()
    assert np.isfinite(ch.T_k).all() and np.isfinite(ch.E_k).all()


@pytest.mark.slow
def test_empty_fire_is_a_noop():
    """Everyone departs at tick 1 (churn_leave=1, churn_join=0): every
    dispatch is empty, the buffer never fires, and the tick must pass the
    global row through untouched — constant accuracy, zero participation,
    no NaN anywhere in the carried history."""
    spec = ExperimentSpec(**{**TINY, "rounds": 3},
                          aggregator="fedbuff:2", cohort=1,
                          churn_leave=1.0, churn_join=0.0)
    ch = build_cohort(spec).run(transfer_guard=True)
    assert (ch.active == 0).all()
    assert (ch.participation == 0).all()
    assert (ch.staleness == 0).all()
    assert np.isfinite(ch.accuracy).all()
    assert np.isfinite(ch.T_k).all() and np.isfinite(ch.E_k).all()
    # the global model froze after the initial round: accuracy is constant
    assert len(set(ch.accuracy[0][1:].tolist())) == 1


# ---------------------------------------------------------------------------
# stochastic-sched selector
# ---------------------------------------------------------------------------


def test_stochastic_sched_resolve():
    sel = SELECTORS.resolve("stochastic-sched")
    assert sel.traceable and sel.needs_rng and not sel.needs_divergence


def test_stochastic_sched_traced_respects_avail():
    arr = fleet_arrays(sample_fleet(16, seed=3))
    arr = dict(arr)
    avail = np.zeros(16, np.float32)
    avail[[2, 5, 11]] = 1.0
    arr["avail"] = jnp.asarray(avail)
    for s in range(8):
        idx, mask = select_stochastic_sched_traced(
            jax.random.PRNGKey(s), arr, bandwidth_mhz=20.0,
            num_devices=16, S=6)
        assert idx.shape == mask.shape == (16,)
        chosen = np.asarray(idx)[np.asarray(mask)]
        assert set(chosen.tolist()) <= {2, 5, 11}
        assert len(chosen) >= 1                  # never-empty fallback
        # padding lanes hold the OOB sentinel
        assert (np.asarray(idx)[~np.asarray(mask)] == 16).all()


def test_stochastic_sched_host_expected_size():
    """Host form: the expected participating-set size tracks S."""
    from repro.api.protocols import SelectionContext
    fleet = sample_fleet(40, seed=1)
    sel = SELECTORS.resolve("stochastic-sched")
    rng = np.random.default_rng(0)
    ctx = SelectionContext(
        rng=rng, num_devices=40, devices_per_round=10,
        selected_per_cluster=1, bandwidth_mhz=20.0, fleet=fleet,
        clusters=None, divergences=lambda: np.zeros(40))
    counts = [len(sel.select(ctx)) for _ in range(40)]
    mean = float(np.mean(counts))
    assert 5.0 < mean < 15.0
    assert min(counts) >= 1


# ---------------------------------------------------------------------------
# staleness-weight properties (hypothesis)
# ---------------------------------------------------------------------------


@given(ages=st.lists(st.floats(min_value=0.0, max_value=100.0),
                     min_size=2, max_size=32),
       alpha=st.floats(min_value=0.0, max_value=4.0))
@settings(max_examples=50, deadline=None)
def test_staleness_weight_properties(ages, alpha):
    """w ∝ (1+age)^(-alpha): positive, normalizable over any fired buffer,
    monotonically non-increasing in age, and exactly uniform at alpha=0."""
    agg = AGGREGATORS.resolve({"name": "fedbuff",
                               "params": {"m": 2, "alpha": alpha}})
    age = jnp.asarray(np.asarray(ages, np.float64))
    w = np.asarray(agg.staleness_weights(age), np.float64)
    assert (w > 0).all() and (w <= 1.0 + 1e-12).all()
    wn = w / w.sum()
    assert abs(wn.sum() - 1.0) < 1e-9
    order = np.argsort(ages)
    assert (np.diff(w[order]) <= 1e-12).all()    # non-increasing in age
    if alpha == 0.0:
        assert np.array_equal(w, np.ones_like(w))


@given(alpha=st.floats(min_value=1e-3, max_value=4.0))
@settings(max_examples=25, deadline=None)
def test_staleness_weights_discount_strictly(alpha):
    agg = AGGREGATORS.resolve({"name": "fedbuff",
                               "params": {"m": 2, "alpha": alpha}})
    w = np.asarray(agg.staleness_weights(jnp.asarray([0.0, 1.0, 4.0])))
    assert w[0] == 1.0 and w[0] > w[1] > w[2]
