"""Guarded ``hypothesis`` import for the property-based suites.

``hypothesis`` is a dev-only dependency (declared in pyproject's ``dev``
extra). When it is installed, this module is a transparent re-export and
the property tests run normally. When it is missing, ``@given`` tests SKIP
individually (via ``pytest.importorskip`` inside the test body) while the
plain tests in the same module keep running — a whole-module importorskip
would throw away the non-property half of the suite.
"""
try:
    from hypothesis import HealthCheck, given, settings, strategies  # noqa: F401

    st = strategies
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Anything:
        """Stand-in for ``strategies``/``HealthCheck``: any attribute or
        call yields another dummy; iterable so ``list(HealthCheck)`` works.
        Only ever consumed by the skipping ``given`` below."""

        def __getattr__(self, name):
            return _Anything()

        def __call__(self, *args, **kwargs):
            return _Anything()

        def __iter__(self):
            return iter(())

    st = strategies = HealthCheck = _Anything()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def wrapper():          # zero-arg: strategy params aren't fixtures
                pytest.importorskip("hypothesis")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
