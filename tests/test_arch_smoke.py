"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED variant
of each family runs one forward and one train step on CPU with correct
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import TrainConfig
from repro.models import init_model, forward, init_cache, decode_step
from repro.train.train_step import make_train_step

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.full((B, cfg.num_image_tokens,
                                          cfg.d_model), 0.01)
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = jnp.full((B, S, cfg.d_model), 0.01)
    return batch


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    p = init_model(cfg, jax.random.PRNGKey(0))
    logits, aux = forward(cfg, p, _batch(cfg, jax.random.PRNGKey(1)),
                          q_chunk=16, kv_chunk=16)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    p = init_model(cfg, jax.random.PRNGKey(0))
    opt_init, step = make_train_step(cfg, tc, q_chunk=16, kv_chunk=16)
    opt = opt_init(p)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    p2, opt2, metrics = jax.jit(step)(p, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0.0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), p, p2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0
    assert int(opt2.step) == 1


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    p = init_model(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, 16)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda t, c: decode_step(cfg, p, {"tokens": t}, c))(tok, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyper-parameters."""
    cfg = get_config(arch)
    expected = {
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "jamba-1.5-large-398b":
        assert cfg.attn_period == 8 and cfg.moe.num_experts == 16 \
            and cfg.moe.top_k == 2
    if arch == "mixtral-8x22b":
        assert cfg.moe.num_experts == 8 and cfg.moe.top_k == 2 \
            and cfg.sliding_window
    if arch == "granite-moe-3b-a800m":
        assert cfg.moe.num_experts == 40 and cfg.moe.top_k == 8
    if arch == "mamba2-130m":
        assert cfg.ssm.d_state == 128
    if arch.startswith("qwen2"):
        assert cfg.qkv_bias


def test_param_scale_sanity():
    """Analytic parameter counts land near the advertised model scales."""
    import math
    approx = {
        "tinyllama-1.1b": 1.1e9, "qwen2-1.5b": 1.5e9, "minitron-8b": 8e9,
        "qwen2-72b": 72e9, "mamba2-130m": 130e6,
        "mixtral-8x22b": 141e9,                   # 8x22b total
        "jamba-1.5-large-398b": 398e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).num_params()
        assert 0.5 < got / want < 1.7, (arch, got, want)
