"""Registry + ExperimentSpec API: lookup/registration semantics, JSON
round-trip, spec↔legacy equivalence, and a selector × allocator round
smoke over every registered pair."""
import numpy as np
import pytest

from repro.api import (AGGREGATORS, ALLOCATORS, COMPRESSORS, SELECTORS,
                       Allocation, ExperimentSpec, Registry, StrategyError,
                       build_experiment)

# small enough that one round is sub-second on CPU
TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=1, devices_per_round=4, num_clusters=4,
            learning_rate=0.05)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_builtin_strategies_registered():
    assert {"divergence", "kmeans_random", "random", "icas",
            "rra"} <= set(SELECTORS.names())
    assert {"sao", "equal", "fedl"} <= set(ALLOCATORS.names())
    assert {"fedavg", "fedavgm"} <= set(AGGREGATORS.names())
    assert {"none", "int8", "topk"} <= set(COMPRESSORS.names())


def test_duplicate_registration_raises():
    reg = Registry("widget")

    @reg.register("x")
    class A:
        pass

    with pytest.raises(StrategyError, match="duplicate widget 'x'"):
        reg.register("x")(A)


def test_unknown_name_raises_and_lists_known():
    with pytest.raises(StrategyError, match="unknown selector 'nope'"):
        SELECTORS.resolve("nope")
    with pytest.raises(StrategyError, match="divergence"):
        SELECTORS.get("nope")


def test_colon_shorthand_parses_params():
    assert ALLOCATORS.resolve("fedl:2.5").lam == 2.5
    assert COMPRESSORS.resolve("topk:0.05").fraction == 0.05
    assert AGGREGATORS.resolve("fedavgm:0.7").beta == 0.7
    assert ALLOCATORS.resolve("sao:box").box_correct is True


def test_resolve_dict_and_instance():
    inst = ALLOCATORS.resolve({"name": "fedl", "params": {"lam": 3.0}})
    assert inst.lam == 3.0
    assert ALLOCATORS.resolve(inst) is inst
    with pytest.raises(StrategyError):
        ALLOCATORS.resolve(42)
    with pytest.raises(StrategyError, match="must have keys"):
        ALLOCATORS.resolve({"name": "sao", "parameters": {}})   # typo'd key


def test_resolve_rejects_class_and_malformed_shorthand():
    cls = type(ALLOCATORS.resolve("sao"))
    with pytest.raises(StrategyError, match="pass an instance"):
        ALLOCATORS.resolve(cls)
    with pytest.raises(StrategyError, match="expected a number"):
        ALLOCATORS.resolve("fedl:abc")
    with pytest.raises(StrategyError, match="'box'"):
        ALLOCATORS.resolve("sao:garbage")


def test_box_correct_kwarg_applies_to_resolved_allocator():
    from repro.configs.base import FLConfig
    from repro.configs.paper_cnn import CNN_CONFIGS
    from repro.core import FLExperiment, sample_fleet
    from repro.data import make_dataset, partition_bias

    ds = make_dataset("fashion", 96, seed=0)
    fed = partition_bias(ds, 6, 16, 0.8, seed=1)
    fl = FLConfig(num_devices=6, devices_per_round=3, num_clusters=3,
                  local_iters=1)
    args = (CNN_CONFIGS["fashion"], fed, ds.images[:20], ds.labels[:20],
            sample_fleet(6, seed=0), fl)
    for alloc in ("sao", {"name": "sao"}, ALLOCATORS.resolve("sao")):
        exp = FLExperiment(*args, allocator=alloc, box_correct=True,
                           batch_size=8)
        assert exp.allocator.box_correct is True
    with pytest.raises(ValueError, match="only applies to the 'sao'"):
        FLExperiment(*args, allocator="equal", box_correct=True, batch_size=8)


def test_custom_registration_resolves():
    @SELECTORS.register("test_first_s")
    class FirstS:
        def select(self, ctx):
            return np.arange(ctx.devices_per_round)

        def params(self):
            return {}

        def spec(self):
            return {"name": "test_first_s", "params": {}}

    try:
        assert "test_first_s" in SELECTORS
        idx = SELECTORS.resolve("test_first_s")
        assert idx.select.__name__ == "select"
    finally:
        SELECTORS._classes.pop("test_first_s")


# ---------------------------------------------------------------------------
# ExperimentSpec
# ---------------------------------------------------------------------------


def test_spec_json_roundtrip():
    spec = ExperimentSpec(dataset="fashion", clients=12, sigma="H",
                          selection="icas", allocator="fedl:2.0",
                          aggregator="fedavgm:0.8", compressor="topk:0.1",
                          test_seed=90_000)
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.to_json() == spec.to_json()


def test_spec_normalizes_compact_strings():
    spec = ExperimentSpec(allocator="fedl:2.0")
    assert spec.allocator == {"name": "fedl", "params": {"lam": 2.0}}
    assert spec.selection["name"] == "divergence"


def test_spec_rejects_unknown_fields_and_strategies():
    with pytest.raises(ValueError, match="unknown ExperimentSpec fields"):
        ExperimentSpec.from_dict({"no_such_field": 1})
    with pytest.raises(StrategyError):
        ExperimentSpec(selection="not_a_policy")


def test_spec_seed_derivation():
    spec = ExperimentSpec(seed=5)
    assert (spec.resolved_data_seed, spec.resolved_test_seed,
            spec.resolved_partition_seed, spec.resolved_fleet_seed) \
        == (5, 10_005, 6, 5)
    spec = ExperimentSpec(seed=5, data_seed=7, test_seed=90_000)
    assert (spec.resolved_data_seed, spec.resolved_test_seed) == (7, 90_000)


# ---------------------------------------------------------------------------
# spec-built experiment ≡ legacy kwargs path (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_spec_reproduces_legacy_experiment():
    from repro.configs.base import FLConfig
    from repro.configs.paper_cnn import CNN_CONFIGS
    from repro.core import FLExperiment, sample_fleet
    from repro.data import make_dataset, partition_bias

    spec = ExperimentSpec.from_json(ExperimentSpec(**TINY).to_json())
    exp = build_experiment(spec)
    hist = exp.run()

    ds = make_dataset("fashion", 160, seed=0)
    test = make_dataset("fashion", 80, seed=10_000)
    fed = partition_bias(ds, 8, 16, 0.8, seed=1)
    fl = FLConfig(num_devices=8, devices_per_round=4, local_iters=2,
                  num_clusters=4, learning_rate=0.05, max_rounds=1)
    legacy = FLExperiment(CNN_CONFIGS["fashion"], fed, test.images,
                          test.labels, sample_fleet(8, seed=0), fl,
                          allocator="sao", seed=0, batch_size=8)
    legacy_hist = legacy.run("divergence", rounds=1)

    assert hist.accuracy == legacy_hist.accuracy
    assert hist.T_k == legacy_hist.T_k
    assert hist.E_k == legacy_hist.E_k
    np.testing.assert_array_equal(hist.selected[-1], legacy_hist.selected[-1])


def test_engine_shared_across_same_config_experiments():
    spec = ExperimentSpec(**TINY)
    a = build_experiment(spec)
    b = build_experiment(spec.replace(seed=1))
    assert a.engine is b.engine


# ---------------------------------------------------------------------------
# every selector × allocator completes a round (smoke)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_exp():
    exp = build_experiment(ExperimentSpec(**TINY))
    exp.initial_round()
    return exp


@pytest.mark.slow
@pytest.mark.parametrize("allocator", ["sao", "equal", "fedl:1.0"])
@pytest.mark.parametrize("selector", sorted(SELECTORS.names()))
def test_selector_allocator_round(tiny_exp, selector, allocator):
    exp = tiny_exp
    saved = exp.allocator
    exp.allocator = ALLOCATORS.resolve(allocator)
    try:
        res = exp.round(selector)
    finally:
        exp.allocator = saved
    idx = res.selected
    assert idx.ndim == 1 and len(idx) > 0
    assert len(np.unique(idx)) == len(idx)
    assert idx.min() >= 0 and idx.max() < TINY["clients"]
    assert np.isfinite(res.T_k) and res.T_k > 0
    assert np.isfinite(res.E_k) and res.E_k > 0
    assert 0.0 <= res.accuracy <= 1.0


def test_allocation_returns_per_device_solution(tiny_exp):
    alloc = tiny_exp.allocation(np.arange(4))
    assert isinstance(alloc, Allocation)
    assert alloc.b.shape == (4,) and alloc.f.shape == (4,)
    assert np.all(alloc.b > 0) and np.all(alloc.f > 0)
