"""The sharded FL-round step (launch/fl_round.py): selection + aggregation
semantics, independent of any mesh."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.fl_round import fl_round_step
from repro.models import init_model


def _setup(n=8, c=3):
    cfg = get_smoke_config("tinyllama-1.1b")
    g = init_model(cfg, jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), n)
    clients = jax.vmap(lambda k: init_model(cfg, k))(keys)
    feat = clients.get("lm_head", clients["embed"])
    feat_dim = feat.reshape(n, -1).shape[1]
    cent = jax.random.normal(jax.random.PRNGKey(2), (c, feat_dim))
    sizes = jnp.arange(1.0, n + 1.0)
    return cfg, g, clients, cent, sizes


def test_fl_round_selection_is_top_divergence_per_cluster():
    n, c = 8, 3
    cfg, g, clients, cent, sizes = _setup(n, c)
    new_g, div, labels = fl_round_step(clients, g, cent, sizes,
                                       num_clusters=c)
    div = np.asarray(div)
    labels = np.asarray(labels)
    assert div.shape == (n,) and (div > 0).all()
    assert set(labels.tolist()) <= set(range(c))
    # reconstruct the expected winners
    winners = set()
    for k in np.unique(labels):
        members = np.flatnonzero(labels == k)
        winners.add(members[np.argmax(div[members])])
    # aggregate must equal the sizes-weighted mean over exactly the winners
    w = np.zeros(n)
    w[list(winners)] = np.asarray(sizes)[list(winners)]
    w = w / w.sum()
    lead = np.asarray(clients["embed"]).reshape(n, -1)
    want = (w[:, None] * lead).sum(0)
    got = np.asarray(new_g["embed"]).reshape(-1)
    np.testing.assert_allclose(got, want.astype(got.dtype), rtol=2e-2,
                               atol=1e-3)


def test_fl_round_feature_slice_consistency():
    """feature_slice only changes CLUSTERING, never divergence/aggregation
    semantics (it is the paper's w_fc2 dimensionality-reduction lever)."""
    cfg, g, clients, cent, sizes = _setup(8, 3)
    _, div_full, _ = fl_round_step(clients, g, cent, sizes, num_clusters=3)
    cent_small = cent[:, :64]
    _, div_slice, labels = fl_round_step(clients, g, cent_small, sizes,
                                         num_clusters=3, feature_slice=64)
    np.testing.assert_allclose(np.asarray(div_full), np.asarray(div_slice),
                               rtol=1e-6)
    assert labels.shape == (8,)


def test_identical_clients_zero_divergence():
    cfg, g, clients, cent, sizes = _setup(4, 2)
    same = jax.tree_util.tree_map(
        lambda gl: jnp.broadcast_to(gl, (4,) + gl.shape), g)
    _, div, _ = fl_round_step(same, g, cent, sizes, num_clusters=2)
    assert float(jnp.max(div)) < 1e-3
