"""Scenario API: FleetSpec/CellSpec round-trip, build_fleet ≡ sample_fleet
bit-identity, the static-channel pinned pipeline equivalence, per-round
Rayleigh fading inside the scan, multi-cell interference sweeps on the
cohort engine, the traced FEDL λ bisection, and the fl_sim CLI round-trip
through --dump-spec/--spec."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st, HealthCheck

from repro.api import (ALLOCATORS, CHANNELS, CellSpec, ExperimentSpec,
                       FleetSpec, build_cohort, build_experiment,
                       build_fleet, multicell_fleet_spec, register_channel)
from repro.api.registry import StrategyError
from repro.core.baselines import fedl_lambda, tune_fedl_lambda
from repro.core.sao import kkt_residuals, solve_sao
from repro.core.wireless import (Fleet, effective_arrays, fleet_arrays,
                                 sample_fleet)

TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=2, devices_per_round=4, num_clusters=4,
            learning_rate=0.05)

slow_settings = settings(deadline=None, max_examples=10,
                         suppress_health_check=list(HealthCheck))


# ---------------------------------------------------------------------------
# FleetSpec / CellSpec serialization
# ---------------------------------------------------------------------------


def test_fleetspec_json_roundtrip():
    fs = FleetSpec(
        cells=(CellSpec(radius_km=0.2, e_cons_range=(0.02, 0.05)),
               CellSpec(devices=12, center_km=(1.0, 0.5), p_dbm=20.0)),
        channel="multicell-interference:0.5", isd_km=0.8)
    again = FleetSpec.from_json(fs.to_json())
    assert again == fs
    assert again.channel == {"name": "multicell-interference",
                             "params": {"load": 0.5, "shadow_db": 8.0}}
    assert again.cells[1].center_km == (1.0, 0.5)
    assert isinstance(again.cells[0].e_cons_range, tuple)


def test_fleetspec_validation():
    with pytest.raises(ValueError, match="at least one cell"):
        FleetSpec(cells=())
    with pytest.raises(ValueError, match="unknown FleetSpec fields"):
        FleetSpec.from_dict({"no_such": 1})
    with pytest.raises(StrategyError, match="unknown channel"):
        FleetSpec(channel="warp-drive")


def test_experiment_spec_carries_fleet():
    spec = ExperimentSpec(**TINY, fleet=multicell_fleet_spec(2))
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.num_cells == 2
    assert isinstance(again.fleet, FleetSpec)
    # the default (legacy) spec keeps fleet=None and one cell
    assert ExperimentSpec(**TINY).num_cells == 1


def test_channel_registry_and_custom_model():
    assert {"static", "rayleigh-block", "gauss-markov",
            "multicell-interference",
            "multicell-dynamic"} <= set(CHANNELS.names())

    @register_channel("test_mirror")
    class Mirror:
        traceable = True
        needs_rng = False

        def sample_gains(self, rng, d_km):
            return np.ones_like(d_km)

        def apply_traced(self, key, arr):
            return arr

    try:
        assert CHANNELS.resolve("test_mirror").needs_rng is False
    finally:
        CHANNELS._classes.pop("test_mirror")


# ---------------------------------------------------------------------------
# build_fleet: bit-identity with the legacy sampler; multi-cell geometry
# ---------------------------------------------------------------------------


def test_build_fleet_matches_sample_fleet_bit_identical():
    want = sample_fleet(23, seed=7)
    got = build_fleet(FleetSpec(), 7, clients=23)
    for name in ("h", "p", "z", "C", "D", "alpha", "f_min", "f_max",
                 "e_cons"):
        np.testing.assert_array_equal(getattr(got, name),
                                      getattr(want, name), err_msg=name)
    assert got.L == want.L and got.N0 == want.N0
    assert np.all(got.inr == 0.0) and np.all(got.cell == 0)


def test_build_fleet_multicell_interference():
    fl = build_fleet(multicell_fleet_spec(3), 0, clients=10,
                     bandwidth_mhz=20.0)
    assert fl.num_devices == 30 and fl.num_cells == 3
    assert np.all(fl.inr > 0.0)                  # every BS hears other cells
    c1 = fl.cell_fleet(1)
    assert c1.num_devices == 10 and np.all(np.asarray(c1.cell) == 1)
    # interference is per-cell constant
    assert len(np.unique(np.asarray(c1.inr))) == 1
    # wider cell spacing → weaker interference
    far = build_fleet(multicell_fleet_spec(3, isd_km=5.0), 0, clients=10)
    assert float(np.mean(far.inr)) < float(np.mean(fl.inr))
    # cell streams must not alias a neighboring cohort seed's cells:
    # (seed 0, cell 1) and (seed 1, cell 0) draw different populations
    fs2 = multicell_fleet_spec(2)
    a = build_fleet(fs2, 0, clients=10).cell_fleet(1)
    b = build_fleet(fs2, 1, clients=10).cell_fleet(0)
    assert not np.array_equal(a.h, b.h)


def test_interference_raises_optimal_delay():
    fl = build_fleet(multicell_fleet_spec(2), 1, clients=8)
    arr = fleet_arrays(fl.cell_fleet(0))
    clean = dict(arr)
    clean["inr"] = jnp.zeros_like(arr["inr"])
    T_int = float(solve_sao(arr, 20.0).T)
    T_clean = float(solve_sao(clean, 20.0).T)
    assert T_int > T_clean
    # inr == 0 is bit-identical to the pre-scenario solver input
    no_key = {k: v for k, v in clean.items() if k != "inr"}
    assert float(solve_sao(no_key, 20.0).T) == T_clean


def test_sao_allocator_energy_uses_interference_folded_rate():
    """Regression: E_k must be the energy at the interference-degraded
    rate the solver allocated against, not the clean-channel one."""
    fl = build_fleet(multicell_fleet_spec(2), 1, clients=8)
    arr = fleet_arrays(fl.cell_fleet(0))
    T, E, b, f = ALLOCATORS.resolve("sao").allocate_traced(arr, 20.0, None)
    eff = effective_arrays(arr)
    from repro.core.sao import _Q
    e_true = eff["G"] * jnp.square(f) + eff["H"] / _Q(b, eff["J"])
    np.testing.assert_allclose(float(E), float(jnp.sum(e_true)), rtol=1e-6)
    # sanity: the clean-channel accounting would claim strictly less
    e_clean = arr["G"] * jnp.square(f) + arr["H"] / _Q(b, arr["J"])
    assert float(jnp.sum(e_clean)) < float(E)


def test_fleet_is_pytree_and_devicefleet_removed():
    fl = sample_fleet(5, seed=0)
    leaves, treedef = jax.tree_util.tree_flatten(fl)
    again = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(again.h, fl.h)
    assert again.L == fl.L
    assert isinstance(fl.select(np.arange(2)), Fleet)
    # the one-release deprecation alias is gone (PR-3 promise kept)
    import repro.core
    import repro.core.wireless
    assert not hasattr(repro.core.wireless, "DeviceFleet")
    assert not hasattr(repro.core, "DeviceFleet")


# ---------------------------------------------------------------------------
# pinned: static channel ≡ current pipeline, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_static_fleetspec_pipeline_bit_identical():
    legacy = build_experiment(ExperimentSpec(**TINY))
    h_legacy = legacy.run()
    scenario = build_experiment(
        ExperimentSpec(**TINY, fleet=FleetSpec()))
    h_scenario = scenario.run()
    assert h_scenario.accuracy == h_legacy.accuracy
    assert h_scenario.T_k == h_legacy.T_k
    assert h_scenario.E_k == h_legacy.E_k
    for a, b in zip(h_scenario.selected, h_legacy.selected):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# rayleigh-block: per-round fading redrawn inside the scan
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_rayleigh_block_runs_traced_and_refuses_host_loop():
    spec = ExperimentSpec(**{**TINY, "rounds": 3},
                          fleet=FleetSpec(channel="rayleigh-block"))
    exp = build_experiment(spec)
    assert exp.channel.registry_name == "rayleigh-block"
    assert exp.traceable()
    hist = exp.run()                     # scanned path, fading per round
    assert len(hist.T_k) == 4
    assert all(np.isfinite(hist.T_k)) and all(t > 0 for t in hist.T_k)
    # fading redraws must actually vary the round delays
    assert len({round(t, 9) for t in hist.T_k}) > 1

    forced = build_experiment(spec)
    forced.traceable = lambda *a, **k: False
    with pytest.raises(ValueError, match="rayleigh-block"):
        forced.run()


@pytest.mark.slow
def test_static_channel_unchanged_by_channel_hook():
    """The channel hook must not perturb the PRNG stream: a static-channel
    scanned run equals the legacy-loop run exactly (the PR-2 pin, now with
    the channel plumbing in between)."""
    spec = ExperimentSpec(**TINY, fleet=FleetSpec())
    traced = build_experiment(spec)
    h_t = traced.run()
    legacy = build_experiment(spec)
    legacy.traceable = lambda *a, **k: False
    h_l = legacy.run()
    assert h_t.accuracy == h_l.accuracy
    np.testing.assert_allclose(h_t.T_k, h_l.T_k, rtol=1e-6)


# ---------------------------------------------------------------------------
# multicell-interference: ≥2 cells end-to-end on the cohort engine
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multicell_sweep_on_cohort_runner():
    spec = ExperimentSpec(**TINY, fleet=multicell_fleet_spec(2))
    runner = build_cohort(spec)
    ch = runner.run()                    # (1 seed × 2 cells) lanes, one scan
    assert ch.cells == 2
    assert ch.lane_cells == [0, 1]
    assert ch.accuracy.shape == (2, TINY["rounds"] + 1)
    assert np.all(np.isfinite(ch.accuracy))
    assert np.all(np.asarray(ch.T_k) > 0)
    # every lane's experiment really is its own cell with interference
    assert [e.cell for e in runner.experiments] == [0, 1]
    for e in runner.experiments:
        assert np.all(e.fleet.inr > 0.0)
    # cells partition data with decorrelated streams
    assert not np.array_equal(runner.experiments[0].fed.labels,
                              runner.experiments[1].fed.labels)


@pytest.mark.slow
def test_multicell_cohort_stacks_cells_next_to_seeds():
    spec = ExperimentSpec(**TINY, cohort=2, fleet=multicell_fleet_spec(2))
    ch = build_cohort(spec).run()
    assert len(ch.seeds) == 4
    assert ch.seeds == [0, 0, 1, 1]
    assert ch.lane_cells == [0, 1, 0, 1]
    assert ch.accuracy.shape == (4, TINY["rounds"] + 1)


# ---------------------------------------------------------------------------
# property: masked + vmapped SAO keeps the Theorem-1 residuals on
# randomized FleetSpec fleets
# ---------------------------------------------------------------------------


@slow_settings
@given(seed=st.integers(0, 40), n=st.integers(4, 12))
def test_kkt_residuals_masked_vmapped_from_fleetspec(seed, n):
    fs = FleetSpec(cells=(CellSpec(devices=n + 4,
                                   e_cons_range=(0.03, 0.06)),))
    arr = fleet_arrays(build_fleet(fs, seed).select(np.arange(n)))
    # pad with duplicated masked-out lanes, then vmap over two instances
    pad = {k: jnp.concatenate([v, v[:2]]) for k, v in arr.items()}
    mask = jnp.asarray([True] * n + [False] * 2)
    arr_b = fleet_arrays(build_fleet(fs, seed + 1000).select(np.arange(n)))
    pad_b = {k: jnp.concatenate([v, v[:2]]) for k, v in arr_b.items()}
    stacked = {k: jnp.stack([pad[k], pad_b[k]]) for k in pad}
    sols = jax.vmap(lambda a: solve_sao(a, 20.0, mask=mask))(stacked)
    for i, base in enumerate((arr, arr_b)):
        if not bool(sols.converged[i]):
            continue                     # infeasible channel draw
        sol_i = jax.tree_util.tree_map(lambda x, i=i: x[i][:n], sols)
        r = kkt_residuals(sol_i, base, 20.0)
        assert float(jnp.max(-r["energy_slack"])) < 1e-4      # (19a)
        assert float(jnp.sum(sol_i.b)) <= 20.0 * (1 + 1e-4)   # (19c)
        assert bool(jnp.all(sol_i.f >= base["f_min"] - 1e-6)) # (19d)
        assert bool(jnp.all(sol_i.f <= base["f_max"] + 1e-6))
        assert abs(float(jnp.max(r["t"])) - float(sol_i.T)) < 1e-4
        # padded lanes stayed inert
        assert np.all(np.asarray(sols.b[i][n:]) == 0.0)


# ---------------------------------------------------------------------------
# traced FEDL: masked solve ≡ unpadded solve; λ bisection inside jit
# ---------------------------------------------------------------------------


def test_fedl_masked_padding_matches_unpadded():
    arr = fleet_arrays(sample_fleet(6, seed=2))
    want = fedl_lambda(arr, 20.0, 1.0)
    pad = {k: jnp.concatenate([v, v[:2]]) for k, v in arr.items()}
    mask = jnp.asarray([True] * 6 + [False] * 2)
    got = fedl_lambda(pad, 20.0, 1.0, mask=mask)
    np.testing.assert_allclose(float(got.T), float(want.T), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got.b[:6]), np.asarray(want.b),
                               rtol=1e-4, atol=1e-5)
    assert np.all(np.asarray(got.b[6:]) == 0.0)
    assert np.all(np.asarray(got.f[6:]) == 0.0)


def test_tune_fedl_lambda_traces_and_matches_host_protocol():
    arr = fleet_arrays(sample_fleet(30, seed=0).select(np.arange(8)))
    lam = tune_fedl_lambda(arr, 20.0, iters=16, n_grid=60)
    assert np.isfinite(float(lam)) and float(lam) > 0
    # the tuned point satisfies the §VI-A criterion: no device over budget
    res = fedl_lambda(arr, 20.0, lam, n_grid=60)
    assert float(jnp.max(res.e - arr["e_cons"])) <= 1e-4
    # and it really is traced (jit-compiled, no host callbacks)
    jitted = jax.jit(lambda a: tune_fedl_lambda(a, 20.0, iters=4, n_grid=24))
    assert np.isfinite(float(jitted(arr)))


def test_fedl_auto_allocator_traced_contract():
    alloc = ALLOCATORS.resolve("fedl_auto:6")
    assert alloc.iters == 6 and alloc.traceable
    arr = fleet_arrays(sample_fleet(12, seed=1).select(np.arange(5)))
    pad = {k: jnp.concatenate([v, v[:1]]) for k, v in arr.items()}
    mask = jnp.asarray([True] * 5 + [False])
    T, E, b, f = alloc.allocate_traced(pad, 20.0, mask)
    assert np.isfinite(float(T)) and float(T) > 0
    assert np.isfinite(float(E)) and float(E) > 0
    assert float(b[-1]) == 0.0


@pytest.mark.slow
def test_fedl_scanned_run_matches_python_loop():
    """The FEDL baseline now runs inside the scan (ROADMAP item): the
    device-resident path reproduces the host loop exactly."""
    spec = ExperimentSpec(**TINY, allocator="fedl:1.0")
    traced = build_experiment(spec)
    assert traced.traceable()
    h_t = traced.run()
    legacy = build_experiment(spec)
    legacy.traceable = lambda *a, **k: False
    h_l = legacy.run()
    assert h_t.accuracy == h_l.accuracy
    np.testing.assert_allclose(h_t.T_k, h_l.T_k, rtol=1e-5)
    np.testing.assert_allclose(h_t.E_k, h_l.E_k, rtol=1e-5)


# ---------------------------------------------------------------------------
# CLI round-trip: --dump-spec → --spec reproduces the run exactly
# ---------------------------------------------------------------------------

_CLI_TINY = ["--dataset", "fashion", "--clients", "6", "--per-round", "3",
             "--rounds", "1", "--local-iters", "1", "--cells", "1"]


@pytest.mark.slow
def test_fl_sim_dump_spec_roundtrip(tmp_path, capsys):
    from repro.launch import fl_sim

    fl_sim.main(_CLI_TINY + ["--dump-spec"])
    dumped = capsys.readouterr().out
    spec_file = tmp_path / "spec.json"
    spec_file.write_text(dumped)
    # the dumped spec parses back to the exact same value (fleet included)
    spec = ExperimentSpec.from_json(dumped)
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.fleet is not None        # --cells materialized a FleetSpec

    out_a, out_b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    fl_sim.main(_CLI_TINY + ["--out", str(out_a)])
    capsys.readouterr()
    fl_sim.main(["--spec", str(spec_file), "--out", str(out_b)])
    capsys.readouterr()
    rec_a = json.loads(out_a.read_text())
    rec_b = json.loads(out_b.read_text())
    assert rec_a["spec"] == rec_b["spec"]
    assert rec_a["accuracy"] == rec_b["accuracy"]
    assert rec_a["total_T_s"] == rec_b["total_T_s"]
    assert rec_a["total_E_J"] == rec_b["total_E_J"]


def test_cells_flag_builds_interference_fleet():
    from repro.launch.fl_sim import spec_from_args
    import argparse
    ns = argparse.Namespace(spec=None, dataset="mnist",
                            selection="divergence", allocator="sao",
                            box_correct=False, rounds=2, clients=8,
                            per_round=4, sigma="0.8", local_iters=2,
                            lr=0.05, target_acc=0.0, seed=0, cohort=1,
                            fleet_spec=None, cells=2, channel=None)
    spec = spec_from_args(ns)
    assert spec.num_cells == 2
    assert spec.fleet.channel["name"] == "multicell-interference"
