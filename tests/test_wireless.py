"""Wireless system model (eqs. 5-11) unit + property tests."""
import numpy as np
import jax.numpy as jnp
from hypothesis_compat import given, settings, st, HealthCheck

from repro.core import wireless as w

slow = settings(deadline=None, max_examples=25,
                suppress_health_check=list(HealthCheck))


def test_dbm_conversions():
    assert w.dbm_to_watt(30.0) == np.float64(1.0)
    assert abs(w.watt_to_dbm(0.2) - 23.0) < 0.02
    assert abs(w.dbm_to_watt(w.watt_to_dbm(0.123)) - 0.123) < 1e-9


@slow
@given(b=st.floats(0.01, 100.0), j=st.floats(0.1, 1e5))
def test_rate_monotone_in_bandwidth(b, j):
    r1 = float(w.rate_mbps(jnp.asarray(b), jnp.asarray(j)))
    r2 = float(w.rate_mbps(jnp.asarray(b * 1.1), jnp.asarray(j)))
    assert r2 >= r1 * 0.999


@slow
@given(f=st.floats(0.1, 3.0))
def test_compute_energy_quadratic_delay_inverse(f):
    G, U = 5e-3, 0.05
    assert abs(float(w.e_cmp(G, 2 * f)) / float(w.e_cmp(G, f)) - 4.0) < 1e-3
    assert abs(float(w.t_cmp(U, 2 * f)) * 2 - float(w.t_cmp(U, f))) < 1e-6


def test_fleet_units_realistic():
    """§VI scales: delays O(0.01-1 s), energies O(1-100 mJ)."""
    fleet = w.sample_fleet(100, seed=0)
    arr = w.fleet_arrays(fleet)
    b = jnp.full((100,), 0.2)               # 20 MHz / 100 devices
    f = jnp.full((100,), 1.0)               # 1 GHz
    T, E, t, e = w.round_totals(arr, b, f)
    assert 0.01 < float(jnp.median(t)) < 30.0
    assert 1e-4 < float(jnp.median(e)) < 1.0


def test_eq10_eq11_aggregation():
    fleet = w.sample_fleet(10, seed=1)
    arr = w.fleet_arrays(fleet)
    b = jnp.full((10,), 2.0)
    f = jnp.full((10,), 1.5)
    T, E, t, e = w.round_totals(arr, b, f)
    assert float(T) == float(jnp.max(t))            # eq (11)
    assert abs(float(E) - float(jnp.sum(e))) < 1e-6  # eq (10)


def test_select_and_with_power():
    fleet = w.sample_fleet(50, seed=2)
    sub = fleet.select(np.arange(5))
    assert sub.num_devices == 5
    p2 = sub.with_power(0.1)
    assert np.allclose(p2.p, 0.1)
    # J scales linearly with power
    assert np.allclose(p2.J_mhz() / sub.J_mhz(), 0.1 / sub.p)
