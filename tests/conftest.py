# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices.
import os
import sys

# persistent compile cache (pure speed-up; set before jax import)
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
