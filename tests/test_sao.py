"""Property-based tests for the SAO solver (paper §V, Theorem 1 invariants)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st, HealthCheck

from repro.core.wireless import sample_fleet, fleet_arrays, LN2
from repro.core.sao import solve_sao, kkt_residuals
from repro.core.baselines import equal_bandwidth, fedl_lambda

B_MHZ = 20.0


def _arr(seed, n=10, e_lo=0.03, e_hi=0.06):
    fleet = sample_fleet(100, seed=seed, e_cons_range=(e_lo, e_hi))
    return fleet_arrays(fleet.select(np.arange(n)))


slow = settings(deadline=None, max_examples=12,
                suppress_health_check=list(HealthCheck))


@slow
@given(seed=st.integers(0, 50))
def test_solution_is_feasible(seed):
    arr = _arr(seed)
    sol = solve_sao(arr, B_MHZ)
    if not bool(sol.converged):
        # channel draw with a device whose uplink energy exceeds its budget
        # even at full band — problem (19) itself is infeasible
        pytest.skip("infeasible instance")
    r = kkt_residuals(sol, arr, B_MHZ)
    # (19a) energy within budget (small fp tolerance)
    assert float(jnp.max(-r["energy_slack"])) < 1e-4
    # (19c) total bandwidth within budget
    assert float(jnp.sum(sol.b)) <= B_MHZ * (1.0 + 1e-4)
    # (19d) frequency box
    assert bool(jnp.all(sol.f >= arr["f_min"] - 1e-6))
    assert bool(jnp.all(sol.f <= arr["f_max"] + 1e-6))
    # (19b): T* is the max of per-device delays by construction
    assert abs(float(jnp.max(r["t"]) - sol.T)) < 1e-5


@slow
@given(seed=st.integers(0, 50))
def test_theorem1_interior_devices_have_equal_delay(seed):
    """Eq. (20): devices NOT clipped at a frequency-box face finish
    simultaneously at T*."""
    arr = _arr(seed)
    sol = solve_sao(arr, B_MHZ)
    if not bool(sol.converged):
        pytest.skip("instance infeasible for this channel draw")
    r = kkt_residuals(sol, arr, B_MHZ)
    interior = np.asarray((sol.f > arr["f_min"] + 1e-4)
                          & (sol.f < arr["f_max"] - 1e-4))
    t = np.asarray(r["t"])
    if interior.sum() >= 2:
        spread = t[interior].max() - t[interior].min()
        assert spread < 0.05 * float(sol.T), (spread, float(sol.T))


@slow
@given(seed=st.integers(0, 50))
def test_theorem1_energy_tight_for_interior(seed):
    """Eq. (21): interior devices exhaust their energy budget."""
    arr = _arr(seed)
    sol = solve_sao(arr, B_MHZ)
    if not bool(sol.converged):
        pytest.skip("infeasible instance")
    r = kkt_residuals(sol, arr, B_MHZ)
    interior = np.asarray((sol.f > arr["f_min"] + 1e-4)
                          & (sol.f < arr["f_max"] - 1e-4))
    slack = np.asarray(r["energy_slack"])
    if interior.any():
        assert slack[interior].max() < 5e-4


@slow
@given(seed=st.integers(0, 30))
def test_monotone_in_energy_budget(seed):
    """Relaxing every energy budget can only reduce the optimal delay."""
    a1 = _arr(seed, e_lo=0.03, e_hi=0.05)
    a2 = dict(a1)
    a2["e_cons"] = a1["e_cons"] * 1.5
    s1 = solve_sao(a1, B_MHZ)
    s2 = solve_sao(a2, B_MHZ)
    if not (bool(s1.converged) and bool(s2.converged)):
        pytest.skip("infeasible instance")
    assert float(s2.T) <= float(s1.T) * 1.02


@slow
@given(seed=st.integers(0, 30))
def test_monotone_in_bandwidth(seed):
    arr = _arr(seed)
    t1 = float(solve_sao(arr, 15.0).T)
    t2 = float(solve_sao(arr, 30.0).T)
    assert t2 <= t1 * 1.02


@slow
@given(seed=st.integers(0, 30))
def test_sao_beats_equal_bandwidth(seed):
    """Fig. 5/6/7 headline: SAO ≤ Baseline 1 when both are feasible."""
    arr = _arr(seed)
    sol = solve_sao(arr, B_MHZ)
    eq = equal_bandwidth(arr, B_MHZ)
    if bool(sol.converged) and bool(jnp.all(eq.feasible)):
        assert float(sol.T) <= float(eq.T) * 1.02


@slow
@given(seed=st.integers(0, 20))
def test_box_correct_no_worse(seed):
    """The beyond-paper KKT-box completion never hurts."""
    arr = _arr(seed)
    t_paper = float(solve_sao(arr, B_MHZ).T)
    t_fix = float(solve_sao(arr, B_MHZ, box_correct=True).T)
    assert t_fix <= t_paper * 1.02


def test_fedl_tradeoff_direction():
    """Baseline 2: larger λ weights delay more → delay falls, energy rises."""
    arr = _arr(0)
    r_lo = fedl_lambda(arr, B_MHZ, 0.2)
    r_hi = fedl_lambda(arr, B_MHZ, 50.0)
    assert float(r_hi.T) <= float(r_lo.T) * 1.05
    assert float(jnp.sum(r_hi.e)) >= float(jnp.sum(r_lo.e)) * 0.95


def test_lemma2_Q_monotone_bounded():
    from repro.core.sao import _Q
    J = jnp.asarray([5.0, 50.0, 500.0])
    b = jnp.linspace(0.01, 100.0, 200)[:, None]
    q = _Q(b, J[None, :])
    assert bool(jnp.all(jnp.diff(q, axis=0) > -1e-6))       # increasing
    assert bool(jnp.all(q < J[None, :] / LN2))              # bounded


@slow
@given(seed=st.integers(0, 30), s=st.integers(2, 30))
def test_scales_with_selected_set(seed, s):
    arr = _arr(seed, n=s)
    sol = solve_sao(arr, B_MHZ)
    assert np.isfinite(float(sol.T))
    assert sol.b.shape == (s,)
