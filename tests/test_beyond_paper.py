"""Beyond-paper extensions: compression↔SAO coupling, FedProx, FedAvgM,
box-corrected SAO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (apply_compression, compress_int8,
                                    compress_topk, payload_mbit)
from repro.core.algorithms import ServerMomentum
from repro.utils.trees import tree_sub


def test_int8_roundtrip_error_bounded():
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (100, 50))}
    y = compress_int8(x)
    err = float(jnp.max(jnp.abs(x["w"] - y["w"])))
    scale = float(jnp.max(jnp.abs(x["w"]))) / 127.0
    assert err <= scale * 0.5 + 1e-6


def test_topk_keeps_largest():
    x = {"w": jnp.asarray([1.0, -5.0, 0.1, 3.0, -0.2])}
    y = compress_topk(x, 0.4)          # keep 2 of 5
    got = np.asarray(y["w"])
    assert got[1] == -5.0 and got[3] == 3.0
    assert got[0] == 0.0 and got[2] == 0.0 and got[4] == 0.0


def test_payload_sizes_ordered():
    n = 113_744                         # paper MNIST CNN
    full = payload_mbit(n, "none")
    q8 = payload_mbit(n, "int8")
    tk = payload_mbit(n, "topk:0.05")
    assert full == pytest.approx(32 * n / 1e6)
    assert q8 < 0.3 * full
    assert tk < 0.1 * full


def test_compression_reduces_sao_delay():
    """Smaller z_n → lower T_k — but ONLY with the box-corrected allocator.

    Analytic finding (EXPERIMENTS §Perf-sched): when the Alg.-5 cubic pushes
    f above f_max, the paper's energy-tight bandwidth rule (21) gives
    t_com = (e_cons − G·f_max²)/p, which is INDEPENDENT of z — the paper-
    faithful allocator cannot monetize uplink compression in clipped
    regimes. The KKT box completion restores the coupling.
    """
    from repro.core.wireless import sample_fleet, fleet_arrays
    from repro.core.sao import solve_sao
    import dataclasses
    fleet = sample_fleet(100, seed=0).select(np.arange(10))
    arr_full = fleet_arrays(fleet)
    z8 = payload_mbit(113_744, "int8")
    fleet8 = dataclasses.replace(fleet, z=np.full_like(fleet.z, z8))
    arr8 = fleet_arrays(fleet8)

    t_full_paper = float(solve_sao(arr_full, 20.0).T)
    t_int8_paper = float(solve_sao(arr8, 20.0).T)
    t_full_box = float(solve_sao(arr_full, 20.0, box_correct=True).T)
    t_int8_box = float(solve_sao(arr8, 20.0, box_correct=True).T)

    # the paper-faithful allocator is z-blind here (the finding):
    assert abs(t_int8_paper - t_full_paper) < 0.05 * t_full_paper
    # the box-corrected allocator converts compression into latency:
    assert t_int8_box < 0.5 * t_full_box, (t_full_box, t_int8_box)


def test_server_momentum_accelerates_constant_direction():
    opt = ServerMomentum(beta=0.9, lr=1.0)
    w = {"a": jnp.zeros(3)}
    agg = {"a": jnp.full(3, -1.0)}      # constant pseudo-gradient direction
    deltas = []
    for _ in range(5):
        new_w = opt.step(w, {"a": w["a"] - 1.0})
        deltas.append(float(jnp.mean(w["a"] - new_w["a"])))
        w = new_w
    assert deltas[-1] > deltas[0]       # momentum accumulates


def test_fedprox_pulls_toward_global():
    """With huge μ the client barely moves from the global model."""
    from repro.core.algorithms import make_fedprox_local_update
    from repro.core.fedavg import make_local_update
    from repro.configs.paper_cnn import FASHION_CNN
    from repro.models.cnn import init_cnn
    from repro.data import make_dataset
    ds = make_dataset("fashion", 128, seed=0)
    g = init_cnn(FASHION_CNN, jax.random.PRNGKey(0))
    imgs, labs = jnp.asarray(ds.images), jnp.asarray(ds.labels)
    key = jax.random.PRNGKey(1)
    # lr·mu must stay < 2 for the proximal pull to be stable
    plain = make_local_update(FASHION_CNN, 0.05, 10, 32)(g, imgs, labs, key)
    prox = make_fedprox_local_update(FASHION_CNN, 0.05, 10, 32, mu=20.0)(
        g, imgs, labs, key)
    d_plain = sum(float(jnp.sum(jnp.square(a - b)))
                  for a, b in zip(jax.tree_util.tree_leaves(plain),
                                  jax.tree_util.tree_leaves(g)))
    d_prox = sum(float(jnp.sum(jnp.square(a - b)))
                 for a, b in zip(jax.tree_util.tree_leaves(prox),
                                 jax.tree_util.tree_leaves(g)))
    assert d_prox < 0.5 * d_plain
