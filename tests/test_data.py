"""Synthetic data + non-iid partitioner invariants."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st, HealthCheck

from repro.data import make_dataset, partition_bias, partition_dirichlet
from repro.data.synthetic import make_token_stream

slow = settings(deadline=None, max_examples=8,
                suppress_health_check=list(HealthCheck))


def test_dataset_deterministic():
    a = make_dataset("mnist", 100, seed=3)
    b = make_dataset("mnist", 100, seed=3)
    np.testing.assert_array_equal(a.images, b.images)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_train_test_share_class_structure():
    """Different seeds = different samples but SAME class templates."""
    tr = make_dataset("fashion", 400, seed=0)
    te = make_dataset("fashion", 400, seed=123)
    # class-mean images across splits should be highly correlated
    for k in range(3):
        m1 = tr.images[tr.labels == k].mean(0).ravel()
        m2 = te.images[te.labels == k].mean(0).ravel()
        corr = np.corrcoef(m1, m2)[0, 1]
        assert corr > 0.5, (k, corr)       # ≈0 if templates differed
    # negative control: means of DIFFERENT classes correlate less
    m0 = tr.images[tr.labels == 0].mean(0).ravel()
    m1o = te.images[te.labels == 1].mean(0).ravel()
    assert np.corrcoef(m0, m1o)[0, 1] < 0.6


def test_shapes_match_paper_table2():
    assert make_dataset("mnist", 10).images.shape == (10, 28, 28, 1)
    assert make_dataset("cifar10", 10).images.shape == (10, 32, 32, 3)
    assert make_dataset("fashion", 10).images.shape == (10, 28, 28, 1)


@slow
@given(sigma=st.sampled_from([0.5, 0.8]))
def test_bias_partition_majority_fraction(sigma):
    ds = make_dataset("mnist", 3000, seed=0)
    fed = partition_bias(ds, 20, 100, sigma, seed=1)
    for n in range(20):
        frac = float(np.mean(fed.labels[n] == fed.majority[n]))
        assert abs(frac - sigma) < 0.12, (n, frac, sigma)


def test_bias_partition_H_two_classes():
    """σ=H: 80% majority + 20% from ONE secondary class."""
    ds = make_dataset("mnist", 3000, seed=0)
    fed = partition_bias(ds, 10, 100, "H", seed=1)
    for n in range(10):
        classes, counts = np.unique(fed.labels[n], return_counts=True)
        assert len(classes) == 2
        assert counts.max() / counts.sum() == pytest.approx(0.8, abs=0.05)


def test_majorities_cover_all_classes():
    ds = make_dataset("mnist", 2000, seed=0)
    fed = partition_bias(ds, 30, 50, 0.8, seed=2)
    assert set(fed.majority.tolist()) == set(range(10))


def test_dirichlet_partition_shapes():
    ds = make_dataset("fashion", 1000, seed=0)
    fed = partition_dirichlet(ds, 8, 64, alpha=0.3, seed=0)
    assert fed.images.shape == (8, 64, 28, 28, 1)
    assert fed.labels.shape == (8, 64)


def test_token_stream_learnable_structure():
    toks = make_token_stream(1000, 5000, seed=0)
    assert toks.min() >= 0 and toks.max() < 1000
    # Markov structure: conditional entropy < marginal entropy
    from collections import Counter
    pairs = Counter(zip(toks[:-1], toks[1:]))
    uni = Counter(toks)
    assert len(pairs) < 0.5 * len(uni) ** 2
