"""Federated LM subsystem: model registry, LoRA adapter rows, kernel
dispatch at LM shapes, streaming K-means, P-axis plane specs, and the
model="cnn" bit-identity pin."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build_experiment
from repro.core.clustering import (adjusted_rand_index, kmeans_fit,
                                   kmeans_fit_minibatch)
from repro.kernels import ops
from repro.models.lm import (LMConfig, adapter_num_params, base_params,
                             init_adapter, lm_loss, merge_lora)
from repro.models.registry import (model_def_for, workload_config,
                                   workload_names)
from repro.models.transformer import forward
from repro.utils.trees import (flatten_stacked, stack_flatten_spec,
                               tree_num_params, tree_weighted_mean_stacked,
                               tree_flatten_vector, unflatten_vector)

TINY_LM = dict(model="tinyllama", clients=6, train_samples=48,
               test_samples=16, samples_per_client=8, devices_per_round=2,
               num_clusters=2, local_iters=2, batch_size=4, rounds=2,
               learning_rate=0.1, seed=0)


# ---------------------------------------------------------------------------
# model registry
# ---------------------------------------------------------------------------


def test_registry_knows_builtin_workloads():
    assert "tinyllama" in workload_names()
    assert "mamba2-130m" in workload_names()
    cfg = workload_config("tinyllama")
    assert isinstance(cfg, LMConfig)
    assert model_def_for(cfg).name == "lora-lm"
    assert model_def_for(cfg).price_uploads


def test_registry_unknown_raises():
    with pytest.raises(ValueError, match="unknown model"):
        workload_config("gpt-17")
    with pytest.raises(TypeError, match="no ModelDef"):
        model_def_for(object())


def test_spec_json_roundtrip_preserves_model():
    spec = ExperimentSpec(**TINY_LM)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert back.model == "tinyllama"


def test_spec_rejects_unknown_model():
    with pytest.raises(ValueError, match="unknown model"):
        ExperimentSpec(model="no-such-arch")


def test_build_resolves_lm_workload():
    exp = build_experiment(ExperimentSpec(**TINY_LM))
    assert isinstance(exp.model_cfg, LMConfig)
    p_adapter = adapter_num_params(exp.model_cfg)
    assert exp.client_params.shape == (TINY_LM["clients"], p_adapter)
    # uploads priced at P_adapter fp32 bits, never P_base
    assert np.allclose(exp.fleet.z, p_adapter * 32 / 1e6)
    assert tree_num_params(base_params(exp.model_cfg)) > 20 * p_adapter


# ---------------------------------------------------------------------------
# model="cnn" stays on the paper-CNN path, bit-identical to the default
# ---------------------------------------------------------------------------


def test_model_cnn_is_bit_identical_to_auto():
    tiny = dict(dataset="mnist", clients=8, train_samples=64,
                test_samples=32, samples_per_client=8, devices_per_round=4,
                num_clusters=2, local_iters=2, batch_size=4, rounds=2)
    e_auto = build_experiment(ExperimentSpec(**tiny))
    e_cnn = build_experiment(ExperimentSpec(model="cnn", **tiny))
    # same frozen config -> the SAME shared engine (cache key unperturbed)
    assert e_cnn.engine is e_auto.engine
    h_auto = e_auto.run(rounds=2)
    h_cnn = e_cnn.run(rounds=2)
    assert h_auto.accuracy == h_cnn.accuracy
    assert h_auto.T_k == h_cnn.T_k and h_auto.E_k == h_cnn.E_k
    np.testing.assert_array_equal(np.asarray(e_auto.client_params),
                                  np.asarray(e_cnn.client_params))


# ---------------------------------------------------------------------------
# LoRA adapter rows
# ---------------------------------------------------------------------------


def _tinyllama_cfg() -> LMConfig:
    return workload_config("tinyllama")


def test_fresh_adapter_is_exact_noop_on_base():
    cfg = _tinyllama_cfg()
    adapter = init_adapter(cfg, jax.random.PRNGKey(3))
    merged = merge_lora(cfg, adapter)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, cfg.seq_len), 0,
                              cfg.model.vocab_size)
    ref, _ = forward(cfg.model, base_params(cfg), {"tokens": toks})
    out, _ = forward(cfg.model, merged, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_lora_flat_roundtrip_and_aggregation_parity():
    """Adapter rows on the flat plane aggregate bitwise like the stacked
    pytree (the flat≡pytree contract, now for the LM workload)."""
    cfg = _tinyllama_cfg()
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    adapters = [init_adapter(cfg, k) for k in keys]
    # make B factors nonzero so the parity check sees real values
    adapters = [jax.tree_util.tree_map(
        lambda l, s=i: l + 0.01 * (s + 1), a)
        for i, a in enumerate(adapters)]
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *adapters)
    spec = stack_flatten_spec(adapters[0])
    rows = flatten_stacked(stacked)
    assert rows.shape == (4, adapter_num_params(cfg))
    # round-trip: row -> tree -> identical leaves
    back = unflatten_vector(spec, rows[2])
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        adapters[2], back)
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    flat_agg = ops.flat_aggregate(rows, w)
    tree_agg = tree_weighted_mean_stacked(stacked, w)
    np.testing.assert_array_equal(np.asarray(flat_agg),
                                  np.asarray(tree_flatten_vector(tree_agg)))


def test_lm_loss_is_finite_and_differentiable():
    cfg = _tinyllama_cfg()
    adapter = init_adapter(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, cfg.seq_len + 1), 0,
                              cfg.model.vocab_size)
    batch = {"images": toks, "labels": jnp.zeros((4,), jnp.int32)}
    loss, g = jax.value_and_grad(lm_loss)(adapter, batch, cfg)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(l)))
                for l in jax.tree_util.tree_leaves(g))
    assert gnorm > 0.0


# ---------------------------------------------------------------------------
# kernel dispatch at LM shapes
# ---------------------------------------------------------------------------


def test_kernel_dispatch_policy(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert ops.kernel_dispatch(None) is on_tpu
    assert ops.kernel_dispatch(True) is True
    assert ops.kernel_dispatch(False) is False
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    assert ops.kernel_dispatch(None) is True


@pytest.mark.parametrize("arch", ["tinyllama", "mamba2-130m"])
def test_forward_kernel_route_matches_reference(arch):
    """Flash-attention / SSD kernels (interpret mode off-TPU) vs the jnp
    reference at the federated LM shapes."""
    cfg = workload_config(arch)
    params = base_params(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, cfg.seq_len), 0,
                              cfg.model.vocab_size)
    ref, _ = forward(cfg.model, params, {"tokens": toks})
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        ker, _ = forward(cfg.model, params, {"tokens": toks},
                         use_pallas=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               atol=2e-5, rtol=2e-5)


def test_attention_kernel_drift_at_lm_shapes():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 32, 8, 16))
    k = jax.random.normal(k2, (2, 32, 2, 16))
    v = jax.random.normal(k3, (2, 32, 2, 16))
    ref = ops.attention(q, k, v, causal=True, use_pallas=False)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        ker = ops.attention(q, k, v, causal=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker), atol=2e-5,
                               rtol=2e-5)


def test_off_tpu_use_pallas_true_warns():
    if jax.default_backend() == "tpu":
        pytest.skip("warning is off-TPU only")
    cfg = workload_config("tinyllama")
    toks = jnp.zeros((1, cfg.seq_len), jnp.int32)
    with pytest.warns(RuntimeWarning, match="interpret mode"):
        forward(cfg.model, base_params(cfg), {"tokens": toks},
                use_pallas=True)


# ---------------------------------------------------------------------------
# end-to-end federated LM
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["tinyllama", "mamba2-130m"])
def test_lm_federated_run_traced(arch):
    exp = build_experiment(ExperimentSpec(**{**TINY_LM, "model": arch}))
    assert exp.traceable()
    hist = exp.run(rounds=2)
    assert len(hist.accuracy) == 3            # init + 2 scanned rounds
    assert all(np.isfinite(a) for a in hist.accuracy)
    assert all(t > 0 for t in hist.T_k)
    assert exp.cluster_labels is not None


@pytest.mark.slow
def test_lm_run_with_p_sharding_matches_unsharded():
    """p_shards on a single device is layout-only — numerics unchanged."""
    h0 = build_experiment(ExperimentSpec(**TINY_LM)).run(rounds=2)
    h1 = build_experiment(
        ExperimentSpec(**{**TINY_LM, "p_shards": 1})).run(rounds=2)
    assert h0.accuracy == h1.accuracy


# ---------------------------------------------------------------------------
# streaming / minibatch K-means
# ---------------------------------------------------------------------------


def _blobs(n=60, f=8, c=3, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(c, f)) * 20.0
    labels = np.repeat(np.arange(c), n // c)
    return (centers[labels] + rng.normal(size=(n, f))).astype(np.float32), \
        labels


def test_minibatch_kmeans_single_chunk_equals_full_fit():
    x, _ = _blobs()
    key = jax.random.PRNGKey(7)
    _, full_labels, full_inertia = kmeans_fit(key, jnp.asarray(x), 3)
    _, mb_labels, mb_inertia = kmeans_fit_minibatch(
        key, lambda: iter([x]), 3)
    np.testing.assert_array_equal(np.asarray(full_labels),
                                  np.asarray(mb_labels))
    assert np.isclose(float(full_inertia), float(mb_inertia))


def test_minibatch_kmeans_multi_chunk_recovers_clusters():
    x, truth = _blobs(n=90)
    chunks = lambda: iter([x[:30], x[30:60], x[60:]])
    _, labels, _ = kmeans_fit_minibatch(jax.random.PRNGKey(0), chunks, 3)
    assert adjusted_rand_index(np.asarray(labels), truth) > 0.95


def test_minibatch_kmeans_empty_stream_raises():
    with pytest.raises(ValueError, match="empty"):
        kmeans_fit_minibatch(jax.random.PRNGKey(0), lambda: iter([]), 3)


@pytest.mark.slow
def test_cluster_minibatch_spec_matches_full_on_small_fleet():
    """cluster='minibatch' on a single-chunk fleet pins to the full fit."""
    tiny = dict(dataset="mnist", clients=8, train_samples=64,
                test_samples=16, samples_per_client=8, devices_per_round=4,
                num_clusters=2, local_iters=1, batch_size=4, rounds=1)
    e_full = build_experiment(ExperimentSpec(**tiny))
    e_mb = build_experiment(ExperimentSpec(cluster="minibatch", **tiny))
    e_full.initial_round()
    e_mb.initial_round()
    np.testing.assert_array_equal(e_full.cluster_labels, e_mb.cluster_labels)


def test_spec_rejects_bad_cluster_mode():
    with pytest.raises(ValueError, match="cluster"):
        ExperimentSpec(cluster="online")


# ---------------------------------------------------------------------------
# P-axis plane sharding specs
# ---------------------------------------------------------------------------


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _leaf(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_plane_spec_shards_p_axis_when_divisible():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import plane_spec
    mesh = FakeMesh({"model": 4})
    assert plane_spec(_leaf(10, 64), mesh, 64) == P(None, "model")
    assert plane_spec(_leaf(64), mesh, 64) == P("model")
    # N-sized and scalar leaves replicate; so does a non-divisible P
    assert plane_spec(_leaf(10), mesh, 64) == P(None)
    assert plane_spec(_leaf(10, 63), mesh, 63) == P(None, None)
    # rightmost P-sized dim wins (N == P corner)
    assert plane_spec(_leaf(64, 64), mesh, 64) == P(None, "model")


def test_plane_mesh_off_and_degenerate():
    from repro.sharding.specs import plane_mesh
    assert plane_mesh(0) is None
    mesh = plane_mesh(4)            # single-device env: degenerates to 1
    assert mesh is not None
    assert mesh.shape["model"] == 1
