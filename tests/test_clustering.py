"""K-means device clustering (Alg. 2-3) + ARI metric properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st, HealthCheck

from repro.core.clustering import (kmeans_fit, kmeans_predict,
                                   adjusted_rand_index, extract_features,
                                   clusters_from_labels)

slow = settings(deadline=None, max_examples=10,
                suppress_health_check=list(HealthCheck))


def _blobs(key, n_per, c, f, spread=0.05):
    ks = jax.random.split(key, c + 1)
    centers = jax.random.normal(ks[0], (c, f)) * 3.0
    pts = jnp.concatenate([
        centers[i] + spread * jax.random.normal(ks[i + 1], (n_per, f))
        for i in range(c)])
    labels = np.repeat(np.arange(c), n_per)
    return pts, labels


@slow
@given(seed=st.integers(0, 20))
def test_kmeans_recovers_blobs(seed):
    x, truth = _blobs(jax.random.PRNGKey(seed), 20, 5, 8)
    _, labels, _ = kmeans_fit(jax.random.PRNGKey(seed + 1), x, 5)
    ari = adjusted_rand_index(np.asarray(labels), truth)
    assert ari > 0.9, ari


def test_kmeans_predict_matches_fit_labels():
    x, _ = _blobs(jax.random.PRNGKey(0), 30, 4, 6)
    cent, labels, _ = kmeans_fit(jax.random.PRNGKey(1), x, 4)
    pred = kmeans_predict(cent, x)
    assert bool(jnp.all(pred == labels))


def test_ari_bounds():
    a = np.array([0, 0, 1, 1, 2, 2])
    assert adjusted_rand_index(a, a) == pytest.approx(1.0)
    # label permutation keeps ARI = 1
    perm = np.array([2, 2, 0, 0, 1, 1])
    assert adjusted_rand_index(perm, a) == pytest.approx(1.0)
    rng = np.random.default_rng(0)
    scores = [adjusted_rand_index(rng.integers(0, 3, 60),
                                  rng.integers(0, 3, 60)) for _ in range(30)]
    assert abs(float(np.mean(scores))) < 0.12      # ~0 for random labels


def test_extract_features_layer_selection():
    """Paper §IV-B: the feature is the weights of ONE chosen layer."""
    from repro.configs.paper_cnn import MNIST_CNN
    from repro.models.cnn import init_cnn
    N = 5
    stacked = jax.vmap(lambda k: init_cnn(MNIST_CNN, k))(
        jax.random.split(jax.random.PRNGKey(0), N))
    f_fc2 = extract_features(stacked, "w_fc2")
    assert f_fc2.shape == (N, 224 * 10)            # Table II: 2240 weights
    f_all = extract_features(stacked, "all")
    assert f_all.shape == (N, 113744)              # Table II total
    f_auto = extract_features(stacked, "auto")
    np.testing.assert_array_equal(np.asarray(f_auto), np.asarray(f_fc2))


def test_clusters_from_labels_partition():
    labels = np.array([0, 1, 0, 2, 1, 0])
    cl = clusters_from_labels(labels, 3)
    assert sorted(np.concatenate(cl).tolist()) == list(range(6))
    assert [len(c) for c in cl] == [3, 2, 1]


def test_kmeans_feature_layer_separates_majority_classes():
    """The paper's core §IV-A claim, in miniature: clients trained on
    different majority classes become K-means-separable from w_fc2."""
    from repro.configs.base import FLConfig
    from repro.configs.paper_cnn import CNN_CONFIGS
    from repro.core.fedavg import FLExperiment
    from repro.core import sample_fleet
    from repro.data import make_dataset, partition_bias
    ds = make_dataset("fashion", 1500, seed=0)
    fed = partition_bias(ds, 20, 64, 0.9, seed=1)
    fleet = sample_fleet(20, seed=0)
    fl = FLConfig(num_devices=20, devices_per_round=10, local_iters=30,
                  num_clusters=10, learning_rate=0.08)
    exp = FLExperiment(CNN_CONFIGS["fashion"], fed, ds.images[:200],
                       ds.labels[:200], fleet, fl, seed=0)
    exp.initial_round()
    ari = adjusted_rand_index(exp.cluster_labels, fed.majority)
    assert ari > 0.3, ari
