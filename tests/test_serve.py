"""Serving engine: generation determinism, batching, cache reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_model, forward
from repro.serve import ServeEngine


def test_greedy_generation_matches_forward_argmax():
    """Greedy one-step continuation == argmax of forward logits."""
    cfg = get_smoke_config("tinyllama-1.1b")
    p = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, p, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0,
                                 cfg.vocab_size)
    gen = eng.generate(prompts, num_tokens=1)
    logits, _ = forward(cfg, p, {"tokens": prompts}, q_chunk=16, kv_chunk=16)
    want = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    np.testing.assert_array_equal(gen[:, 0], want)


def test_generation_deterministic():
    cfg = get_smoke_config("mamba2-130m")
    p = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, p, max_len=64)
    prompts = jnp.ones((2, 5), jnp.int32)
    a = eng.generate(prompts, num_tokens=8)
    b = eng.generate(prompts, num_tokens=8)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 8)


def test_batch_independence():
    """Each batch row generates independently (no cross-batch leakage)."""
    cfg = get_smoke_config("tinyllama-1.1b")
    p = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, p, max_len=64)
    p1 = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0, cfg.vocab_size)
    both = jnp.concatenate([p1, p2], axis=0)
    g_both = eng.generate(both, num_tokens=4)
    g_1 = eng.generate(p1, num_tokens=4)
    np.testing.assert_array_equal(g_both[0], g_1[0])


def test_encdec_generation_runs():
    cfg = get_smoke_config("seamless-m4t-medium")
    p = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, p, max_len=32)
    src = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model)) * 0.1
    out = eng.generate(jnp.ones((2, 3), jnp.int32), num_tokens=5,
                       src_embeds=src)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_temperature_sampler_topk():
    from repro.serve.sampler import temperature
    logits = jnp.asarray([[10.0, 9.0, -5.0, -5.0]])
    for seed in range(5):
        t = temperature(logits, jax.random.PRNGKey(seed), temp=1.0, top_k=2)
        assert int(t[0]) in (0, 1)
