"""Paged client store (active/cold split) ≡ dense plane parity pins, plus
the population-scale plumbing: lazy partitions, churn on the stats table,
and the guard rails between the paged host loop and the scanned paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.configs.paper_cnn import CNN_CONFIGS
from repro.core import FLExperiment, sample_fleet
from repro.core.store import ClientStats, PagedStore
from repro.data import make_dataset, partition_bias, partition_bias_lazy
from repro.kernels import ops


N_CLIENTS = 12
D_PER_CLIENT = 32
ROUNDS = 3


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("fashion", 600, seed=0)
    fed = partition_bias(ds, N_CLIENTS, D_PER_CLIENT, 0.8, seed=1)
    fleet = sample_fleet(N_CLIENTS, seed=0)
    fl = FLConfig(num_devices=N_CLIENTS, devices_per_round=6, local_iters=4,
                  num_clusters=4, learning_rate=0.08)
    return ds, fed, fleet, fl


def _args(setup):
    ds, fed, fleet, fl = setup
    return (CNN_CONFIGS["fashion"], fed, ds.images[:100], ds.labels[:100],
            fleet, fl)


@pytest.fixture(scope="module")
def dense_run(setup):
    """Dense HOST-loop reference: initial round + ROUNDS divergence rounds
    (the driver the paged loop must reproduce bit for bit)."""
    exp = FLExperiment(*_args(setup), seed=0)
    exp.initial_round()
    selected = [np.asarray(exp.round("divergence").selected)
                for _ in range(ROUNDS)]
    return exp, selected


@pytest.fixture(scope="module")
def paged_run(setup):
    """Paged run through the public driver, exact-refresh policy."""
    exp = FLExperiment(*_args(setup), seed=0, store="paged",
                       div_refresh_every=1)
    hist = exp.run("divergence", rounds=ROUNDS)
    return exp, hist


# ---------------------------------------------------------------------------
# paged ≡ dense bitwise pins
# ---------------------------------------------------------------------------


def test_paged_selections_match_dense(dense_run, paged_run):
    _, dsel = dense_run
    _, hist = paged_run
    for a, b in zip(dsel, hist.selected[1:]):
        assert np.array_equal(np.sort(a), np.sort(np.asarray(b)))


def test_paged_global_params_bitwise(dense_run, paged_run):
    d, _ = dense_run
    p, _ = paged_run
    for x, y in zip(jax.tree_util.tree_leaves(d.global_params),
                    jax.tree_util.tree_leaves(p.global_params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_paged_divergences_bitwise(dense_run, paged_run):
    d, _ = dense_run
    p, _ = paged_run
    assert np.array_equal(d.divergences(), p.divergences())


def test_paged_client_tree_bitwise(dense_run, paged_run):
    d, _ = dense_run
    p, _ = paged_run
    for x, y in zip(jax.tree_util.tree_leaves(d.client_tree()),
                    jax.tree_util.tree_leaves(p.client_tree(chunk_size=5))):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_paged_features_bitwise(dense_run, paged_run):
    d, _ = dense_run
    p, _ = paged_run
    for layer in ("all", "auto", "w_fc2"):
        assert np.array_equal(np.asarray(d.client_features(layer)),
                              np.asarray(p.client_features(layer,
                                                           chunk_size=5)))


def test_iterators_match_materialized(paged_run):
    p, _ = paged_run
    rows = np.concatenate([np.asarray(b)
                           for b in p.store.iter_chunks(5)], axis=0)
    blocks = list(p.iter_client_features("all", chunk_size=5))
    assert blocks[0][0] == 0 and blocks[1][0] == 5
    assert np.array_equal(np.concatenate([b for _, b in blocks]), rows)
    trees = list(p.iter_client_trees(chunk_size=7))
    got = np.concatenate(
        [np.concatenate([l.reshape(l.shape[0], -1)
                         for l in jax.tree_util.tree_leaves(t)], axis=1)
         for _, t in trees], axis=0)
    assert np.array_equal(got, rows)


# ---------------------------------------------------------------------------
# the store itself
# ---------------------------------------------------------------------------


def test_gather_scatter_roundtrip():
    store = PagedStore(np.zeros(7, np.float32), 10, chunk_size=4)
    rows = np.arange(21, dtype=np.float32).reshape(3, 7)
    idx = np.array([9, 0, 5])
    store.scatter(idx, rows)
    assert np.array_equal(np.asarray(store.gather(idx)), rows)
    # untouched rows read the base row; assemble covers mixed ranges
    assert np.array_equal(store.row(3), np.zeros(7, np.float32))
    block = store.assemble(4, 8)
    assert np.array_equal(block[1], rows[2])
    assert np.array_equal(block[0], np.zeros(7))


def test_promotion_to_dense_block():
    store = PagedStore(np.zeros(4, np.float32), 8, chunk_size=4)
    store.scatter(np.array([0, 1]), np.ones((2, 4), np.float32))
    assert 0 in store._blocks and not store._rows     # 2/4 ≥ PROMOTE_FRAC
    store.scatter(np.array([2]), 3 * np.ones((1, 4), np.float32))
    assert np.array_equal(store.row(2), 3 * np.ones(4))
    assert store.num_touched == 3


def test_streaming_divergence_matches_fused_op(paged_run):
    p, _ = paged_run
    gvec = np.asarray(jnp.concatenate(
        [jnp.ravel(l) for l in jax.tree_util.tree_leaves(p.global_params)]))
    dense_rows = np.concatenate(list(p.store.iter_chunks(p.chunk_size)))
    want = np.asarray(ops.client_divergence(jnp.asarray(dense_rows),
                                            jnp.asarray(gvec)))
    got = ops.chunked_client_divergence(p.store.iter_chunks(3),
                                        jnp.asarray(gvec))
    assert np.array_equal(got, want)


def test_chunked_pairwise_matches_fused_op(paged_run):
    p, _ = paged_run
    rows = np.concatenate(list(p.store.iter_chunks(p.chunk_size)))
    cents = rows[:3, :]
    want = np.asarray(jax.jit(ops.pairwise_sq_dists)(jnp.asarray(rows),
                                                     jnp.asarray(cents)))
    got = ops.chunked_pairwise(jnp.asarray(rows), jnp.asarray(cents),
                               chunk_size=5)
    # rows here are ~600k wide: matmul tiling differs between block
    # shapes, so the long-row contraction agrees to accumulation order
    # (the ‖x‖²+‖c‖²−2x·c expansion cancels catastrophically near zero)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=1e-3)
    # single-chunk path IS the jitted fused op
    whole = ops.chunked_pairwise(jnp.asarray(rows), jnp.asarray(cents),
                                 chunk_size=rows.shape[0])
    assert np.array_equal(whole, want)


# ---------------------------------------------------------------------------
# churn on the stats table
# ---------------------------------------------------------------------------


def test_churn_departure_of_cold_client(setup):
    exp = FLExperiment(*_args(setup), seed=0, store="paged",
                       churn=(0.3, 0.5))
    exp.initial_round()
    # freeze a departed client: its cold row must survive untouched
    gone = 3
    frozen = np.array(exp.store.row(gone), copy=True)
    exp.stats.avail[:] = True
    exp.stats.avail[gone] = False
    res = exp.round("divergence")
    assert gone not in np.asarray(res.selected)
    assert np.array_equal(np.array(exp.store.row(gone)), frozen)
    # rejoin: the row is picked up verbatim and selectable again
    exp.stats.avail[gone] = True
    assert np.array_equal(np.asarray(exp.store.gather([gone]))[0], frozen)


def test_churned_out_fleet_is_noop_round(setup):
    exp = FLExperiment(*_args(setup), seed=0, store="paged")
    exp.initial_round()
    before = [np.asarray(l)
              for l in jax.tree_util.tree_leaves(exp.global_params)]
    exp.stats.avail[:] = False
    res = exp.round("divergence")
    assert res.selected.size == 0 and res.T_k == 0.0
    for x, y in zip(before,
                    jax.tree_util.tree_leaves(exp.global_params)):
        assert np.array_equal(x, np.asarray(y))


def test_paged_run_with_churn(setup):
    exp = FLExperiment(*_args(setup), seed=0, store="paged",
                       churn=(0.2, 0.6))
    hist = exp.run("random", rounds=4, include_initial_round=False)
    assert len(hist.accuracy) == 4
    assert all(len(s) <= exp.fl.devices_per_round for s in hist.selected)


# ---------------------------------------------------------------------------
# wave-streamed initial round (k_max < N)
# ---------------------------------------------------------------------------


def test_initial_round_waves(setup):
    """k_max < N streams the all-device round in waves; every client's row
    lands in the cold store and the streamed eq.-(4) mean over the stored
    rows IS the new global model (each wave draws its own PRNG key, so the
    rows themselves are a different — equally valid — training stream)."""
    waved = FLExperiment(*_args(setup), seed=0, store="paged", k_max=5)
    waved.initial_round()
    assert waved.store.num_touched == N_CLIENTS
    assert waved.clusters is not None
    rows = np.concatenate(list(waved.store.iter_chunks(waved.chunk_size)))
    sizes = np.array([float(len(i)) for i in
                      (waved.fed.indices if waved.fed.lazy
                       else waved.fed.images)], np.float32)
    want = np.asarray(ops.flat_aggregate(jnp.asarray(rows),
                                         jnp.asarray(sizes)))
    got = np.concatenate(
        [np.ravel(l) for l in
         jax.tree_util.tree_leaves(waved.global_params)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# lazy (index-backed) federated data
# ---------------------------------------------------------------------------


def test_lazy_data_paged_run_matches_materialized(setup):
    ds, fed, fleet, fl = setup
    lazy = partition_bias_lazy(ds, N_CLIENTS, D_PER_CLIENT, 0.8, seed=1)
    args = (CNN_CONFIGS["fashion"], lazy, ds.images[:100], ds.labels[:100],
            fleet, fl)
    lz = FLExperiment(*args, seed=0, store="paged", div_refresh_every=1)
    hist_l = lz.run("divergence", rounds=2)
    mt = FLExperiment(*_args(setup), seed=0, store="paged",
                      div_refresh_every=1)
    hist_m = mt.run("divergence", rounds=2)
    # same seed + loop-path index parity -> identical gathered batches ->
    # bitwise identical training
    assert hist_l.accuracy == hist_m.accuracy
    for x, y in zip(jax.tree_util.tree_leaves(lz.global_params),
                    jax.tree_util.tree_leaves(mt.global_params)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_lazy_data_requires_paged(setup):
    ds, fed, fleet, fl = setup
    lazy = partition_bias_lazy(ds, N_CLIENTS, D_PER_CLIENT, 0.8, seed=1)
    args = (CNN_CONFIGS["fashion"], lazy, ds.images[:100], ds.labels[:100],
            fleet, fl)
    with pytest.raises(ValueError, match="store='paged'"):
        FLExperiment(*args, seed=0)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_paged_has_no_client_params(setup):
    exp = FLExperiment(*_args(setup), seed=0, store="paged")
    with pytest.raises(AttributeError, match="client_tree"):
        exp.client_params
    assert isinstance(ClientStats.create(5).nbytes, int)


def test_paged_accepts_async_aggregator(setup):
    # the once-rejected combination is now a first-class route: paged
    # store + buffered-async ticks (parity pins in test_async_paged.py)
    exp = FLExperiment(*_args(setup), seed=0, store="paged",
                       aggregator="fedbuff:4")
    assert exp.store.kind == "paged"
    assert exp.stats is exp.store.stats


def test_cohort_rejects_paged():
    from repro.api import ExperimentSpec
    from repro.core.cohort import CohortRunner
    with pytest.raises(ValueError, match="paged"):
        CohortRunner(ExperimentSpec(store="paged"))


def test_spec_paged_builds_and_runs():
    from repro.api import ExperimentSpec, build_experiment
    spec = ExperimentSpec(dataset="micro", clients=30, train_samples=256,
                          test_samples=64, samples_per_client=8,
                          local_iters=1, batch_size=4, devices_per_round=5,
                          num_clusters=5, selection="random", store="paged",
                          chunk_size=8, k_max=16)
    exp = build_experiment(spec)
    assert exp.store.kind == "paged" and exp.chunk_size == 8
    hist = exp.run(rounds=2, include_initial_round=False)
    assert len(hist.accuracy) == 2
    # only the trained cohorts' rows are resident: O(touched·P), not O(N·P)
    assert exp.store.num_touched <= 2 * 5
