"""Backend parity for the buffered-asynchronous engine: the paged-store
host composition (``FLExperiment._run_async_paged``) must be bit-identical
to the dense scanned tick — same PRNG stream (churn → select → train),
same dispatched sets, same fp32 summation order in the fire fold — plus
paged-only churn regressions (in-flight cancellation, the stats table as
the single source of availability truth).

The parity pins use a NON-degenerate config (M=2 < pad=4): a full buffer
with no churn would route the dense engine onto its sync-degeneracy
static branch, which the paged composition deliberately does not mirror.
The icas selector ranks on divergence, so the pins also verify that the
paged per-tick divergence refresh (``div_refresh_every=1``) reproduces the
dense full-plane reduction exactly — a single differing selection would
cascade into every downstream trace.
"""
import numpy as np
import pytest

from repro.api import ExperimentSpec, build_experiment
from repro.core.clustering import clusters_from_labels
from repro.utils.trees import tree_flatten_vector

TINY = dict(dataset="fashion", clients=8, samples_per_client=16,
            train_samples=160, test_samples=80, local_iters=2, batch_size=8,
            rounds=3, devices_per_round=4, num_clusters=4,
            learning_rate=0.05, selection="icas",
            aggregator="fedbuff:2:0.5")

PAGED = dict(store="paged", k_max=8, div_refresh_every=1)


def _preset_clusters(exp):
    """Pin the no-init entry point: the dense traced runner forces the
    Alg.-2 initial round whenever clusters are unset, while the paged
    async loop (cluster-free selectors) skips it — give both drivers the
    same trivial partition so neither consumes the init round's keys."""
    labels = np.zeros(exp.fed.num_clients, np.int32)
    exp.cluster_labels = labels
    exp.clusters = clusters_from_labels(labels, exp.fl.num_clusters)
    return exp


def _run_pair(**extra):
    e_d = _preset_clusters(build_experiment(ExperimentSpec(**TINY, **extra)))
    e_p = _preset_clusters(build_experiment(
        ExperimentSpec(**TINY, **PAGED, **extra)))
    h_d = e_d.run(rounds=TINY["rounds"], include_initial_round=False)
    h_p = e_p.run(rounds=TINY["rounds"], include_initial_round=False)
    return e_d, e_p, h_d, h_p


def _assert_bit_identical(e_d, e_p, h_d, h_p):
    assert h_d.accuracy == h_p.accuracy
    assert h_d.T_k == h_p.T_k
    assert h_d.E_k == h_p.E_k
    assert h_d.participation == h_p.participation
    assert h_d.staleness == h_p.staleness
    assert h_d.active == h_p.active
    assert len(h_d.selected) == len(h_p.selected)
    for a, b in zip(h_d.selected, h_p.selected):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the global row itself, not just its eval summary
    g_d = np.asarray(tree_flatten_vector(e_d.global_params))
    g_p = np.asarray(tree_flatten_vector(e_p.global_params))
    assert np.array_equal(g_d, g_p)
    # scheduler columns fold back into both stats tables identically
    for col in ("age", "t_done", "avail", "t_now"):
        assert np.array_equal(getattr(e_d.stats, col),
                              getattr(e_p.stats, col)), col


# ---------------------------------------------------------------------------
# dense ≡ paged bitwise parity
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_async_dense_paged_bit_identical():
    """The tentpole pin: fedbuff:2 (in-flight stragglers every tick) over
    dense vs paged stores — accuracy, T_k/E_k, dispatched sets, async
    traces and the final global row all match bit for bit."""
    _assert_bit_identical(*_run_pair())


@pytest.mark.slow
def test_async_dense_paged_bit_identical_with_churn():
    """Churn composes: the Bernoulli availability flips consume the same
    key split on both backends, departures cancel the same in-flight
    dispatches, and the whole history stays bit-identical."""
    e_d, e_p, h_d, h_p = _run_pair(churn_leave=0.3, churn_join=0.3)
    _assert_bit_identical(e_d, e_p, h_d, h_p)
    # churn actually did something in this config
    assert min(h_p.active) < TINY["clients"]


@pytest.mark.slow
def test_async_paged_target_accuracy_early_stop():
    """Host-loop dividend: unlike the dense scanned engine, the paged
    composition supports target_accuracy early stopping."""
    exp = _preset_clusters(build_experiment(
        ExperimentSpec(**TINY, **PAGED)))
    h = exp.run(rounds=TINY["rounds"], target_accuracy=0.01,
                include_initial_round=False)
    assert h.rounds_to_target is not None
    assert len(h.accuracy) == h.rounds_to_target


# ---------------------------------------------------------------------------
# paged churn regressions: one availability truth
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_paged_churn_cancels_in_flight():
    """A departure cancels the client's in-flight dispatch on the spot:
    after every tick, no unavailable client may hold a finite completion
    time — the scheduler and ``ClientStats.avail`` can never disagree,
    because both ARE the same table."""
    exp = build_experiment(ExperimentSpec(
        **{**TINY, "selection": "stochastic-sched"}, **PAGED,
        churn_leave=0.4, churn_join=0.4))
    assert exp.stats is exp.store.stats
    exp.run(rounds=1)
    for _ in range(4):
        h = exp.run(rounds=1, include_initial_round=False)
        avail_idx = set(np.flatnonzero(exp.stats.avail).tolist())
        assert {int(i) for i in h.selected[-1]} <= avail_idx
        assert np.isinf(exp.stats.t_done[~exp.stats.avail]).all()


@pytest.mark.slow
def test_paged_async_state_persists_across_runs():
    """Incremental run() calls continue the virtual clock through the
    store's stats table, and fired folds maintain the divergence/drift
    columns (drift resets on fire, grows with the global step for
    stragglers)."""
    exp = build_experiment(ExperimentSpec(**TINY, **PAGED))
    _preset_clusters(exp)
    assert float(exp.stats.t_now) == 0.0
    h1 = exp.run(rounds=2, include_initial_round=False)
    t1 = float(exp.stats.t_now)
    assert t1 > 0.0
    assert sum(h1.participation) > 0          # something actually fired
    assert exp.stats.divergence.max() > 0.0   # fired rows got refreshed
    assert (exp.stats.drift >= 0.0).all()
    assert (exp.stats.drift[~exp.store.touched] == 0.0).all()
    exp.run(rounds=1, include_initial_round=False)
    assert float(exp.stats.t_now) > t1
