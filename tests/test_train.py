"""Optimizer / train-step / checkpoint substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.train.optimizer import make_optimizer, cosine_schedule, clip_by_global_norm
from repro.train.train_step import cross_entropy
from repro.train.checkpoint import save_checkpoint, load_checkpoint, checkpoint_step


def _quadratic_loss(p):
    return jnp.sum(jnp.square(p["w"] - 3.0)) + jnp.sum(jnp.square(p["b"] + 1.0))


@pytest.mark.parametrize("opt", ["adamw", "sgd", "momentum"])
def test_optimizer_decreases_quadratic(opt):
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.0, optimizer=opt,
                     warmup_steps=0, total_steps=1000, grad_clip=100.0)
    init, update = make_optimizer(tc)
    params = {"w": jnp.zeros((4,)), "b": jnp.ones((3,))}
    state = init(params)
    losses = [float(_quadratic_loss(params))]
    for _ in range(60):
        g = jax.grad(_quadratic_loss)(params)
        params, state, _ = update(g, state, params)
        losses.append(float(_quadratic_loss(params)))
    assert losses[-1] < 0.1 * losses[0]


def test_cosine_schedule_shape():
    tc = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lr = cosine_schedule(tc)
    assert float(lr(jnp.asarray(0))) < 0.2
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=0.05)
    assert float(lr(jnp.asarray(99))) < 0.01


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(10), rel=1e-5)
    from repro.utils.trees import tree_global_norm
    assert float(tree_global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cross_entropy_uniform():
    V = 7
    logits = jnp.zeros((2, 5, V))
    targets = jnp.zeros((2, 5), jnp.int32)
    assert float(cross_entropy(logits, targets)) == pytest.approx(
        np.log(V), rel=1e-5)


def test_cross_entropy_mask():
    logits = jnp.zeros((1, 4, 3))
    t = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    assert float(cross_entropy(logits, t, mask)) == pytest.approx(
        np.log(3), rel=1e-5)


def test_checkpoint_roundtrip():
    tree = {"layers": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step_count": jnp.asarray(5, jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_checkpoint(path, tree, step=42)
        assert checkpoint_step(path) == 42
        out = load_checkpoint(path, jax.tree_util.tree_map(jnp.zeros_like, tree))
        np.testing.assert_array_equal(np.asarray(out["layers"]["w"]),
                                      np.asarray(tree["layers"]["w"]))
        assert out["layers"]["b"].dtype == jnp.bfloat16
        assert int(out["step_count"]) == 5


def test_training_reduces_lm_loss():
    """~50 steps of the real train step on a tiny model reduces loss."""
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.train.train_step import make_train_step
    cfg = get_smoke_config("tinyllama-1.1b")
    tc = TrainConfig(learning_rate=3e-3, total_steps=60, warmup_steps=5)
    params = init_model(cfg, jax.random.PRNGKey(0))
    opt_init, step = make_train_step(cfg, tc, q_chunk=16, kv_chunk=16)
    opt = opt_init(params)
    jstep = jax.jit(step, donate_argnums=(0, 1))
    # deterministic repeating pattern => easily learnable
    toks = jnp.tile(jnp.arange(16, dtype=jnp.int32), (4, 4))
    first = last = None
    for i in range(50):
        params, opt, m = jstep(params, opt, {"tokens": toks})
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < 0.5 * first, (first, last)
